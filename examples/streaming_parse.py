"""End-to-end streaming parse (paper §4.4 analogue): partitions flow through
the device-resident ``StreamSession`` engine — the carry-over lives on the
device, results are fetched one partition behind dispatch, and with
``--streams S`` S independent sources parse batched in one dispatch per
round (per-stream carry state, bit-identical to S sequential runs).

    PYTHONPATH=src python examples/streaming_parse.py [--records 20000]
        [--backend pallas] [--streams 4]

``--backend pallas`` streams every partition through the Pallas kernel path
(DFA-scan, radix partition and fused gather+convert kernels; interpret mode
on CPU hosts, so expect it slower here — the point is exercising the kernel
pipeline end to end, bit-identically to the reference).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import Parser, ParserConfig, Schema, available_backends, make_csv_dfa
from repro.core.streaming import StreamSession
from repro.data import synth


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=20000,
                    help="yelp-like records per stream")
    ap.add_argument("--partition-kib", type=int, default=512)
    ap.add_argument("--streams", type=int, default=1,
                    help="independent sources batched per dispatch")
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    args = ap.parse_args()

    datas = []
    for s in range(args.streams):
        rng = np.random.default_rng(s)
        datas.append(synth.yelp_like(rng, args.records))
    total_bytes = sum(len(d) for d in datas)
    print(f"dataset: {args.streams} stream(s) x {len(datas[0])/1e6:.1f} MB "
          f"({args.records} yelp-like records each, quoted text with "
          f"embedded delimiters)")
    print(f"backend: {args.backend}")

    parser = Parser(ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.YELP_SCHEMA),
        max_records=1 << 14, chunk_size=64, backend=args.backend,
        # pin the radix partition kernel so the example (and the CI smoke
        # job) exercises it — interpret-mode "auto" picks the jnp pass
        partition_impl="kernel" if args.backend == "pallas" else "auto",
    ))
    sess = StreamSession(parser, args.partition_kib * 1024,
                         max_carry_bytes=1 << 16, n_streams=args.streams)

    def source(data):
        for i in range(0, len(data), 1 << 20):
            yield data[i : i + (1 << 20)]

    t0 = time.perf_counter()
    stars_sum = 0
    n = 0
    for _stream, result, n_complete in sess.parse_streams([source(d) for d in datas]):
        stars = np.asarray(result.values["stars"].value[:n_complete])
        stars_sum += int(stars.sum())
        n += n_complete
    dt = time.perf_counter() - t0

    st = sess.stats[0]
    print(f"parsed {n} records in {dt:.3f}s "
          f"({total_bytes/dt/1e6:.1f} MB/s on this CPU host)")
    print(f"stream 0: partitions {st.partitions}, max carry-over {st.max_carry} B, "
          f"bytes re-parsed {st.bytes_reparsed} "
          f"({st.bytes_reparsed/max(st.bytes_in,1)*100:.2f}% of input)")
    print(f"mean stars: {stars_sum/n:.3f}")


if __name__ == "__main__":
    main()
