"""End-to-end streaming parse (paper §4.4 analogue): partitions flow through
the device double-buffered, incomplete trailing records carry over, and
throughput statistics are reported.

    PYTHONPATH=src python examples/streaming_parse.py [--records 20000]
        [--backend pallas]

``--backend pallas`` streams every partition through the Pallas kernel path
(DFA-scan, radix partition and fused gather+convert kernels; interpret mode
on CPU hosts, so expect it slower here — the point is exercising the kernel
pipeline end to end, bit-identically to the reference).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import Parser, ParserConfig, Schema, available_backends, make_csv_dfa
from repro.core.streaming import StreamingParser
from repro.data import synth


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=20000)
    ap.add_argument("--partition-kib", type=int, default=512)
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    data = synth.yelp_like(rng, args.records)
    print(f"dataset: {len(data)/1e6:.1f} MB, {args.records} yelp-like records "
          f"(quoted text with embedded delimiters)")
    print(f"backend: {args.backend}")

    parser = Parser(ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.YELP_SCHEMA),
        max_records=1 << 14, chunk_size=64, backend=args.backend,
        # pin the radix partition kernel so the example (and the CI smoke
        # job) exercises it — interpret-mode "auto" picks the jnp pass
        partition_impl="kernel" if args.backend == "pallas" else "auto",
    ))
    sp = StreamingParser(parser, args.partition_kib * 1024, max_carry_bytes=1 << 16)

    def source():
        for i in range(0, len(data), 1 << 20):
            yield data[i : i + (1 << 20)]

    t0 = time.perf_counter()
    stars_sum = 0
    n = 0
    for result, n_complete in sp.parse_stream(source()):
        stars = np.asarray(result.values["stars"].value[:n_complete])
        stars_sum += int(stars.sum())
        n += n_complete
    dt = time.perf_counter() - t0

    print(f"parsed {n} records in {dt:.3f}s "
          f"({len(data)/dt/1e6:.1f} MB/s on this CPU host)")
    print(f"partitions: {sp.stats.partitions}, max carry-over: {sp.stats.max_carry} B")
    print(f"mean stars: {stars_sum/n:.3f}")


if __name__ == "__main__":
    main()
