"""Format tour: parse JSON-Lines and a DNS zone file through the format
registry — same FSM engine, different transition tables (ROADMAP item 4).

    PYTHONPATH=src python examples/format_tour.py [--backend pallas]

Each format is looked up by name in ``repro.core.formats``; the registry
supplies the DFA and default tagging mode, ``repro.configs`` supplies the
per-format tuning (chunk size, typeconv widths).  ``--backend pallas`` runs
the kernel path (interpret mode on CPU hosts) with bit-identical outputs.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import tuned_parser_config
from repro.core import Parser, available_backends, formats

JSONL = (
    b'{"id": 7, "name": "ok", "score": 1.5}\n'
    b'{"id": 8, "name": "x\\"y", "score": -2}\n'
    b'\n'
    b'{"id": 9, "name": {"first": "a", "last": "b"}, "score": 0.25}\n'
)

ZONE = (
    b'example.com 3600 IN A 93.184.216.34\n'
    b'www 600 IN CNAME example.com; alias for the apex\n'
    b'; full-line comment: produces no record\n'
    b'mail 7200 ( IN\n'
    b'   MX ) 10mail.example.com\n'
)


def tour(fmt: str, data: bytes, backend: str) -> None:
    spec = formats.get_format(fmt)
    parser = Parser(tuned_parser_config(
        fmt, max_records=16, backend=backend,
        partition_impl="kernel" if backend == "pallas" else "auto",
    ))
    result = parser.parse(data)
    n = int(result.validation.n_records)
    print(f"{fmt}: {n} records  ({spec.doc.split(':')[0]})")

    arrow = parser.to_arrow(result)
    for column in spec.default_schema.columns[:5]:
        col = column.name
        a = arrow[col]
        if column.dtype == "str":
            vals = [bytes(a["data"][a["offsets"][r]: a["offsets"][r + 1]])
                    for r in range(n)]
            print(f"  {col:>6}: {[v.decode('utf-8', 'replace') for v in vals]}")
        else:
            print(f"  {col:>6}: {a['values'][:n].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    args = ap.parse_args()
    print(f"backend: {args.backend}")
    print(f"registered formats: {', '.join(formats.available_formats())}")
    tour("jsonl", JSONL, args.backend)
    tour("zone", ZONE, args.backend)


if __name__ == "__main__":
    main()
