"""Quickstart: parse RFC4180 CSV (quotes, embedded delimiters, comments)
on-device with ParPaRaw and read back Arrow-layout columns.

    PYTHONPATH=src python examples/quickstart.py [--backend pallas]

``--backend pallas`` runs the Pallas kernel path (DFA-scan, radix partition
and windowed fused gather+convert kernels, in interpret mode on CPU hosts)
instead of the jnp reference — the outputs are bit-identical.  See the
top-level README.md for the backend matrix and docs/ARCHITECTURE.md for the
paper→module map.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import Parser, ParserConfig, Schema, available_backends, make_csv_dfa

CSV = (
    b'# inventory export 2026-07-15\n'
    b'1,"Apples, ""Gala""",0.89,2026-07-01\n'
    b'2,"Pears\n(two-line note)",1.25,2026-07-02\n'
    b'3,,0.50,2026-07-03\n'
)

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    args = ap.parse_args()

    schema = Schema.of(("id", "int32"), ("name", "str"),
                       ("price", "float32"), ("updated", "date"))
    parser = Parser(ParserConfig(
        dfa=make_csv_dfa(comment=b"#"),   # line comments — beyond quote-parity tricks
        schema=schema,
        max_records=16,
        backend=args.backend,
        # pin the radix partition kernel so the example (and the CI smoke
        # job) exercises it — interpret-mode "auto" picks the jnp pass
        partition_impl="kernel" if args.backend == "pallas" else "auto",
    ))
    result = parser.parse(CSV)
    assert bool(result.validation.ok), "input should validate"
    n = int(result.validation.n_records)
    print(f"backend: {args.backend}")
    print(f"records: {n}  (comment line produced none)")

    arrow = parser.to_arrow(result)
    ids = arrow["id"]["values"][:n]
    prices = arrow["price"]["values"][:n]
    names = arrow["name"]
    for r in range(n):
        s = bytes(names["data"][names["offsets"][r]: names["offsets"][r + 1]])
        print(f"  id={ids[r]} name={s.decode()!r} price={prices[r]:.2f}")

    # empty field -> NULL (validity bit clear)
    validity = np.unpackbits(arrow["name"]["validity"], bitorder="little")[:n]
    print("name validity:", validity.tolist())


if __name__ == "__main__":
    main()
