"""Quickstart: parse RFC4180 CSV (quotes, embedded delimiters, comments)
on-device with ParPaRaw and read back Arrow-layout columns.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa

CSV = (
    b'# inventory export 2026-07-15\n'
    b'1,"Apples, ""Gala""",0.89,2026-07-01\n'
    b'2,"Pears\n(two-line note)",1.25,2026-07-02\n'
    b'3,,0.50,2026-07-03\n'
)

def main():
    schema = Schema.of(("id", "int32"), ("name", "str"),
                       ("price", "float32"), ("updated", "date"))
    parser = Parser(ParserConfig(
        dfa=make_csv_dfa(comment=b"#"),   # line comments — beyond quote-parity tricks
        schema=schema,
        max_records=16,
    ))
    result = parser.parse(CSV)
    assert bool(result.validation.ok), "input should validate"
    n = int(result.validation.n_records)
    print(f"records: {n}  (comment line produced none)")

    arrow = parser.to_arrow(result)
    ids = arrow["id"]["values"][:n]
    prices = arrow["price"]["values"][:n]
    names = arrow["name"]
    for r in range(n):
        s = bytes(names["data"][names["offsets"][r]: names["offsets"][r + 1]])
        print(f"  id={ids[r]} name={s.decode()!r} price={prices[r]:.2f}")

    # empty field -> NULL (validity bit clear)
    validity = np.unpackbits(arrow["name"]["validity"], bitorder="little")[:n]
    print("name validity:", validity.tolist())


if __name__ == "__main__":
    main()
