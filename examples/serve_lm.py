"""Batched serving example: continuous batching over fixed decode slots.

Submits a burst of prompts against a reduced-config model, runs the engine
until drained, and verifies each response against an unbatched greedy-decode
oracle (correctness of slot-masked caches under mixed admission).

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def greedy_oracle(model, params, prompt, n_new):
    state = model.init_decode_state(1, max_seq=64)
    step = jax.jit(model.decode_step)
    logits = None
    for t in prompt:
        logits, state = step(params, jnp.asarray([t], jnp.int32), state)
    out = []
    tok = int(jnp.argmax(logits[0]))
    for _ in range(n_new):
        out.append(tok)
        logits, state = step(params, jnp.asarray([tok], jnp.int32), state)
        tok = int(jnp.argmax(logits[0]))
    return out


def main():
    cfg = dataclasses.replace(get_config("qwen2-1.5b", reduced=True),
                              param_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=4, max_seq=64)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab, size=int(rng.integers(2, 9))).astype(np.int32)
               for _ in range(7)]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=6))

    finished = engine.run_until_done()
    print(f"served {len(finished)} requests on {engine.slots} slots "
          f"(continuous batching)")
    ok = 0
    for rid, toks in sorted(finished.items()):
        want = greedy_oracle(model, params, prompts[rid].tolist(), len(toks) - 1)
        match = list(toks[1:]) == want[: len(toks) - 1]
        ok += match
        print(f"  req {rid}: {list(map(int, toks))} "
              f"{'== oracle' if match else f'!= oracle {want}'}")
    print(f"{ok}/{len(finished)} match the unbatched greedy oracle")


if __name__ == "__main__":
    main()
