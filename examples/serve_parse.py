"""Multi-tenant parse service demo: four tenants with mixed schemas share
one :class:`~repro.serve.ParseService`, one tenant is fed a record longer
than its carry capacity, and the service proves ISSUE-7's contract —

  * tenants with equal plan keys (the two well-behaved yelp tenants plus
    the faulty one) batch into ONE vmapped streaming session; the taxi
    tenant compiles its own plan and runs in a separate tier-1 batch;
  * the induced overflow surfaces as a typed ``TenantOverflow`` on the
    faulty tenant's channel only — the other tenants of the same batched
    session finish bit-identical to solo runs;
  * a second wave of tenants is admitted onto the SAME session object
    (no recompile), i.e. the failed tenant's lane is reclaimed within one
    service lifetime.

    PYTHONPATH=src python examples/serve_parse.py [--records 200]
        [--backend pallas]

Exits nonzero if any of the above fails — CI runs this as the serving
smoke.
"""
import argparse
import sys
import threading

sys.path.insert(0, "src")

import numpy as np

from repro.core import ParserConfig, Schema, available_backends, make_csv_dfa
from repro.data import synth
from repro.serve import ParseService, TenantOverflow, TenantResult


def drain(tenant, out):
    """Consumer thread body: split a tenant's channel by result type."""
    res, ovf = [], []
    for item in tenant.results():
        (res if isinstance(item, TenantResult) else ovf).append(item)
    out[tenant.name] = (res, ovf)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=200,
                    help="records per well-behaved tenant")
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    args = ap.parse_args()

    rng = np.random.default_rng(7)
    yelp_cfg = ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.YELP_SCHEMA),
        max_records=128, backend=args.backend)
    taxi_cfg = ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.TAXI_SCHEMA),
        max_records=128, backend=args.backend)
    pb, mcb = 8192, 16384

    yelp_a = synth.yelp_like(rng, args.records)
    yelp_b = synth.yelp_like(rng, args.records)
    taxi = synth.taxi_like(rng, args.records)
    # one record longer than max_carry_bytes: no partitioning can ever
    # complete it, so its lane must overflow (and only its lane)
    bad = (synth.yelp_like(rng, 5)
           + b'9,5,0,"' + b"x" * (2 * mcb) + b'",2020-01-01\n')

    svc = ParseService()          # threaded front end (dispatcher + workers)
    out, consumers = {}, []
    with svc:
        tenants = [
            svc.submit(yelp_cfg, [yelp_a], partition_bytes=pb,
                       max_carry_bytes=mcb, name="yelp-a"),
            svc.submit(yelp_cfg, [yelp_b], partition_bytes=pb,
                       max_carry_bytes=mcb, name="yelp-b"),
            svc.submit(yelp_cfg, [bad], partition_bytes=pb,
                       max_carry_bytes=mcb, name="yelp-bad"),
            svc.submit(taxi_cfg, [taxi], partition_bytes=pb,
                       max_carry_bytes=mcb, name="taxi"),
        ]
        for t in tenants:
            th = threading.Thread(target=drain, args=(t, out), daemon=True)
            th.start()
            consumers.append(th)
        for t in tenants:
            t.wait(timeout=600)
        for th in consumers:
            th.join(timeout=60)

        # -- fault isolation ------------------------------------------------
        assert len(out["yelp-bad"][1]) == 1, "expected exactly one overflow"
        ovf = out["yelp-bad"][1][0]
        assert isinstance(ovf, TenantOverflow)
        assert "record longer than capacity" in str(ovf.error)
        for name in ("yelp-a", "yelp-b", "taxi"):
            assert not out[name][1], f"{name} must not see the overflow"

        # healthy tenants completed in full (the bit-identical-to-solo
        # pinning lives in tests/test_serving.py's acceptance test)
        for name in ("yelp-a", "yelp-b"):
            got = sum(r.n_records for r in out[name][0])
            assert got == args.records, (name, got)
        got = sum(r.n_records for r in out["taxi"][0])
        assert got == args.records, ("taxi", got)

        # plan-key sharing: yelp×3 share one parser, taxi adds a second
        assert svc.registry.parser_builds == 2, svc.registry.parser_builds
        yelp_key = tenants[0].session_key
        assert tenants[1].session_key == yelp_key
        assert tenants[2].session_key == yelp_key
        assert tenants[3].session_key != yelp_key

        # -- lane reclaim ---------------------------------------------------
        # a second 3-wide yelp wave lands on the SAME session (same tier,
        # same plan key) — including the lane the faulty tenant burned
        builds = svc.registry.session_builds
        wave2 = [svc.submit(yelp_cfg, [synth.yelp_like(rng, 20)],
                            partition_bytes=pb, max_carry_bytes=mcb,
                            name=f"wave2-{i}") for i in range(3)]
        out2 = {}
        ths = [threading.Thread(target=drain, args=(t, out2), daemon=True)
               for t in wave2]
        for th in ths:
            th.start()
        for t in wave2:
            t.wait(timeout=600)
        for th in ths:
            th.join(timeout=60)
        for t in wave2:
            assert t.session_key == yelp_key, "wave 2 must reuse the session"
            res, ovf2 = out2[t.name]
            assert not ovf2 and sum(r.n_records for r in res) == 20
        assert svc.registry.session_builds == builds, "no recompile on reuse"

    gbs = {t.name: t.stats.bytes_in for t in tenants}
    print(f"backend: {args.backend}")
    print(f"parsers compiled: {svc.registry.parser_builds}  "
          f"sessions built: {svc.registry.session_builds}")
    for t in tenants:
        tag = "OVERFLOW (isolated)" if t.failed else "ok"
        print(f"  {t.name:9s} bytes_in={gbs[t.name]:8d} "
              f"records={t.stats.records:5d} {tag}")
    print("wave 2: 3 tenants reclaimed the same session — no recompile")
    print("OK")


if __name__ == "__main__":
    main()
