"""End-to-end training driver: raw CSV bytes → ParPaRaw on-device parse →
byte-token batches → sharded train step with checkpointing + fault tolerance.

Default invocation trains a small qwen2-family model for a few hundred steps
on this CPU host; ``--arch/--size 100m`` scales to the ~100M-parameter
configuration (same code path, longer wall-clock):

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import Schema
from repro.data import synth
from repro.data.pipeline import CSVTokenPipeline, PipelineConfig
from repro.models.model import build_model
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import FailureInjector, run_training
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

SIZES = {
    # byte-vocab variants of the qwen2 family
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512),
    "20m": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=2, d_ff=1536),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--records", type=int, default=20000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (fault-tolerance demo)")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"bytelm-{args.size}", family="dense", vocab=512,
        qkv_bias=True, tie_embeddings=True, remat=False,
        param_dtype=jax.numpy.float32, **SIZES[args.size],
    )
    model = build_model(cfg)
    print(f"model: {cfg.name}  params≈{cfg.param_count()/1e6:.1f}M")

    # --- data: ParPaRaw-parsed synthetic yelp CSV -> byte tokens ------------
    data = synth.yelp_like(np.random.default_rng(0), args.records)
    schema = Schema.of(*synth.YELP_SCHEMA)
    pipe = CSVTokenPipeline(schema, PipelineConfig(
        seq_len=args.seq_len, batch_size=args.batch,
        partition_bytes=1 << 18, max_carry_bytes=1 << 16,
    ))

    def data_factory(start_step):
        def forever():
            while True:
                yield from pipe.batches([data], start_step=0)
        it = forever()
        for _ in range(start_step):
            next(it)
        return it

    # --- training ------------------------------------------------------------
    ocfg = opt_mod.OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt = opt_mod.make_optimizer(ocfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    tc = TrainConfig(optimizer=ocfg, microbatches=args.microbatches)
    step_fn = jax.jit(make_train_step(model, opt, tc), donate_argnums=(0,))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    injector = FailureInjector(args.fail_at) if args.fail_at else None

    state, hist = run_training(
        step_fn, state, data_factory, total_steps=args.steps,
        ckpt=ckpt, ckpt_every=50, log_every=10, injector=injector,
    )
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
