"""Checkpointing: atomic sharded save / elastic restore / resume-latest.

Fault-tolerance contract (DESIGN.md §4):
  * saves are atomic (write to ``step_N.tmp`` then rename) — a failure mid-
    save never corrupts the latest checkpoint;
  * ``restore_latest`` picks the newest complete checkpoint, so a training
    job restarted after a node failure resumes from the last good step;
  * arrays are saved as full logical tensors (gathered), so a restart may
    use a *different* mesh/device count — elastic rescaling falls out of
    ``jax.device_put`` with the new sharding at load time;
  * saving runs on a background thread (async) double-buffered against the
    training loop, overlapping I/O with compute like the paper's streaming
    overlap of transfers with parsing.

Production deployments would swap the .npz backend for Orbax/OCDBT; the
interface (save/restore/resume) is the same.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional, Tuple

import jax
import numpy as np

_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "float16", "float32", "float64",
    "complex64", "complex128",
}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[dict] = None) -> None:
        """Snapshot device arrays to host, then write (possibly async)."""
        flat, _ = _flatten_with_paths(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {"step": int(step), "extra": extra or {}}
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, meta)
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict) -> None:
        tmp = os.path.join(self.dir, f"step_{step:012d}.tmp")
        final = os.path.join(self.dir, f"step_{step:012d}")
        os.makedirs(tmp, exist_ok=True)
        # npz cannot hold ml_dtypes (bfloat16 etc.): store raw bits + dtype map
        dtypes = {}
        packed = {}
        for k, v in host.items():
            if v.dtype.kind == "V" or v.dtype.name not in _NATIVE_DTYPES:
                dtypes[k] = v.dtype.name
                packed[k] = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
            else:
                packed[k] = v
        meta = dict(meta, dtypes=dtypes)
        np.savez(os.path.join(tmp, "arrays.npz"), **packed)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d{12})", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Tuple[Any, dict]:
        """Restore into the structure of ``target``; reshard if given
        ``shardings`` (elastic restore onto a different mesh)."""
        path = os.path.join(self.dir, f"step_{step:012d}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        import ml_dtypes  # bundled with jax
        dtypes = meta.get("dtypes", {})
        flat, treedef = _flatten_with_paths(target)
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten_with_paths(shardings)
        out = {}
        for key, ref in flat.items():
            arr = arrays[key]
            if key in dtypes:
                arr = arr.view(np.dtype(getattr(ml_dtypes, dtypes[key])))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref.shape}")
            arr = arr.astype(ref.dtype)
            if shard_flat is not None:
                out[key] = jax.device_put(arr, shard_flat[key])
            else:
                out[key] = jax.device_put(arr)
        leaves = [out[k] for k in flat.keys()]
        return jax.tree_util.tree_unflatten(treedef, leaves), meta

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        state, meta = self.restore(step, target, shardings)
        return step, state, meta
