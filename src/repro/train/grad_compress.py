"""Int8-quantised gradient all-reduce with error feedback (optional).

A distributed-optimisation trick for bandwidth-bound DP meshes: gradients
are per-tensor scaled to int8, summed across the data axes in int32, and
dequantised.  The quantisation residual is fed back into the next step's
gradient (error feedback), which keeps convergence within noise for
momentum-based optimizers (1-bit Adam / PowerSGD literature).

Implemented as a shard_map over the DP axes so the collective is explicit
(and visible to the roofline's collective-bytes parser).  Off by default;
enabled via ``TrainConfig.compress_grads``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quantize(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_grads(grads, sh, mesh):
    """Quantise → psum(int32) → dequantise, per gradient leaf, over dp axes.

    NOTE: with standard GSPMD data parallelism gradients are already summed
    by the autodiff transpose; this path is for explicitly DP-replicated
    setups (examples/train_lm.py --compress-grads) and for demonstrating the
    collective-compression machinery at dry-run scale.
    """
    axes = sh.dp

    def one(g):
        def body(gl):
            q, scale = _quantize(gl)
            qsum = jax.lax.psum(q.astype(jnp.int32), axes)
            ssum = jax.lax.pmax(scale, axes)  # conservative shared scale
            return qsum.astype(jnp.float32) * ssum

        spec = P()  # replicated view per dp rank
        return shard_map(
            body, mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False
        )(g)

    return jax.tree.map(one, grads)


class ErrorFeedback:
    """Host-side wrapper carrying the error-feedback residual tree."""

    def __init__(self):
        self.residual = None

    def apply(self, grads):
        if self.residual is not None:
            grads = jax.tree.map(lambda g, r: g + r, grads, self.residual)
        quantised = jax.tree.map(lambda g: _dequant(*_quantize(g)), grads)
        self.residual = jax.tree.map(lambda g, q: g - q, grads, quantised)
        return quantised


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale
