"""Sharded training step: loss → grads → optimizer update, with optional
microbatch gradient accumulation and int8-compressed DP gradient reduction.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
in/out shardings from ``train_state_specs``; the same function lowers for
the 1-device smoke tests and the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.train import optimizer as opt_mod
from repro.train.optimizer import Optimizer, OptimizerConfig


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1            # gradient accumulation steps
    compress_grads: bool = False     # int8 DP all-reduce (train/grad_compress)


def init_train_state(model: Model, key, opt: Optimizer) -> TrainState:
    params = model.init(key)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def train_state_specs(model: Model, opt_cfg: OptimizerConfig) -> TrainState:
    pspecs = model.param_specs()
    return TrainState(
        params=pspecs,
        opt=opt_mod.opt_state_specs(opt_cfg, pspecs),
        step=P(),
    )


def make_train_step(model: Model, opt: Optimizer, tc: TrainConfig, mesh=None):
    def loss_fn(params, batch):
        total, (nll, aux) = model.loss(params, batch, mesh=mesh)
        return total, (nll, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_grads(params, batch):
        (loss, (nll, aux)), grads = grad_fn(params, batch)
        return loss, nll, aux, grads

    def accumulated_grads(params, batch):
        """lax.scan over microbatches: memory-bounded gradient accumulation."""
        n = tc.microbatches

        def reshape(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, l_acc, n_acc, a_acc = carry
            (loss, (nll, aux)), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, l_acc + loss, n_acc + nll, a_acc + aux), None

        (gsum, loss, nll, aux), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0, 0.0), micro
        )
        inv = 1.0 / n
        return loss * inv, nll * inv, aux * inv, jax.tree.map(lambda g: g * inv, gsum)

    def step_fn(state: TrainState, batch: Dict[str, jax.Array]):
        if tc.microbatches > 1:
            loss, nll, aux, grads = accumulated_grads(state.params, batch)
        else:
            loss, nll, aux, grads = single_grads(state.params, batch)
        if tc.compress_grads and mesh is not None:
            from repro.train.grad_compress import compressed_psum_grads
            grads = compressed_psum_grads(grads, model.sh, mesh)
        new_params, new_opt = opt.update(grads, state.opt, state.params, state.step)
        metrics = {
            "loss": loss,
            "nll": nll,
            "aux": aux,
            "grad_norm": opt_mod.global_norm(grads),
            "lr": opt_mod.lr_schedule(tc.optimizer, state.step),
        }
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn


def jit_train_step(model: Model, opt: Optimizer, tc: TrainConfig, mesh,
                   batch_specs: Dict[str, P]):
    """jit with explicit in/out shardings for the production mesh."""
    step_fn = make_train_step(model, opt, tc, mesh)
    state_specs = train_state_specs(model, tc.optimizer)
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        step_fn,
        in_shardings=(to_sh(state_specs), to_sh(batch_specs)),
        out_shardings=(to_sh(state_specs), None),
        donate_argnums=(0,),
    )
