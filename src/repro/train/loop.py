"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler monitoring.

The loop is deliberately host-driven and restartable: all state lives in
(TrainState, data-offset) and both are checkpointed, so killing the process
at any step and re-running resumes bit-exact (modulo async-save lag).  A
``FailureInjector`` exercises that path in tests — the restart machinery is
load-bearing, not decorative.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker flagging slow steps (the CPU-host stand-in for
    per-host straggler detection; on a real pod this would feed the
    coordinator's slow-host eviction)."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


class FailureInjector:
    """Deterministically raises at a given step (tests/fault tolerance)."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


def run_training(
    step_fn: Callable,
    init_state,
    data_iter_factory: Callable[[int], Iterator],
    *,
    total_steps: int,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    injector: Optional[FailureInjector] = None,
    log_fn: Callable[[str], None] = print,
):
    """Run (or resume) training.  ``data_iter_factory(start_step)`` must
    return an iterator positioned at ``start_step`` — the pipeline offset is
    part of the checkpointed state contract."""
    state = init_state
    start = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(init_state)
        if restored is not None:
            start, state, meta = restored
            log_fn(f"[resume] restored checkpoint at step {start}")
    monitor = StragglerMonitor()
    data = data_iter_factory(start)
    metrics_hist = []
    for step in range(start, total_steps):
        if injector is not None:
            injector.maybe_fail(step)
        batch = next(data)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)
        if slow:
            log_fn(f"[straggler] step {step} took {dt*1e3:.1f} ms "
                   f"(ewma {monitor.ewma*1e3:.1f} ms)")
        if step % log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            metrics_hist.append({"step": step, **m, "dt": dt})
            log_fn(f"step {step:6d} loss {m['loss']:.4f} nll {m['nll']:.4f} "
                   f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} {dt*1e3:.0f} ms")
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(total_steps, state)
        ckpt.wait()
    return state, metrics_hist
