"""Optimizers: AdamW and a factored-second-moment Adafactor variant.

Self-contained (no optax dependency).  State trees mirror the param tree, so
GSPMD shards optimizer state exactly like the parameters (ZeRO by
construction once params are FSDP-sharded).

``adafactor_lite`` keeps a bf16 first moment and factored (row/col fp32)
second moment — the configuration that lets kimi-k2's 1T parameters train
within pod HBM (DESIGN.md §5): 2 bytes (param) + 2 (m) + ~0 (factored v)
per parameter instead of Adam's 2 + 4 + 4.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def make_adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 / (1.0 - cfg.b1 ** t)
        c2 = 1.0 / (1.0 - cfg.b2 ** t)

        def upd(p, g, m, v):
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            step_d = (m * c1) / (jnp.sqrt(v * c2) + cfg.eps)
            decay = cfg.weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr * (step_d + decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def make_adafactor(cfg: OptimizerConfig) -> Optimizer:
    """bf16 first moment + factored fp32 second moment (Shazeer & Stern)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def v_init(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"full": jnp.zeros(p.shape, jnp.float32)}

        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            "v": jax.tree.map(v_init, params),
        }

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step)

        def upd(p, g, m, v):
            g2 = g * g + 1e-30
            if _factored(p):
                r = cfg.b2 * v["r"] + (1 - cfg.b2) * g2.mean(axis=-1)
                c = cfg.b2 * v["c"] + (1 - cfg.b2) * g2.mean(axis=-2)
                denom = (
                    r[..., :, None] * c[..., None, :]
                    / jnp.maximum(r.mean(axis=-1, keepdims=True)[..., None], 1e-30)
                )
                vhat = denom
                new_v = {"r": r, "c": c}
            else:
                vhat = cfg.b2 * v["full"] + (1 - cfg.b2) * g2
                new_v = {"full": vhat}
            update_d = g / (jnp.sqrt(vhat) + cfg.eps)
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * update_d
            decay = cfg.weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr * (m32 + decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m32.astype(jnp.bfloat16), new_v

        is_v_leaf = lambda x: isinstance(x, dict) and ("r" in x or "full" in x)
        out = jax.tree.map(upd, params, grads, state["m"],
                           jax.tree.map(lambda x: x, state["v"], is_leaf=is_v_leaf),
                           is_leaf=None)
        # tree of 3-tuples → three trees
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return make_adamw(cfg)
    if cfg.name == "adafactor":
        return make_adafactor(cfg)
    raise ValueError(cfg.name)


def opt_state_specs(opt_cfg: OptimizerConfig, param_specs):
    """Optimizer-state PartitionSpecs mirroring the param specs."""
    from jax.sharding import PartitionSpec as P
    if opt_cfg.name == "adamw":
        return {"m": param_specs, "v": param_specs}

    def v_spec(s):
        # factored moments for rank≥2; scalars/vectors keep a full moment
        return {"r": P(*s[:-1]), "c": P(*(s[:-2] + s[-1:]))} if len(s) >= 2 else {"full": s}

    is_p = lambda x: isinstance(x, __import__("jax").sharding.PartitionSpec)
    return {
        "m": param_specs,
        "v": jax.tree.map(v_spec, param_specs, is_leaf=is_p),
    }
