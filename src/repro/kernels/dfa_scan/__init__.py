from repro.kernels.dfa_scan.ops import chunk_vectors, parse_classes, replay, replay_fused

__all__ = ["chunk_vectors", "parse_classes", "replay", "replay_fused"]
