"""Pure-jnp oracle for the dfa_scan kernels — thin wrappers over the
reference implementations in repro.core.transition."""
from __future__ import annotations

import jax

from repro.core import transition as tr
from repro.core.dfa import Dfa


def chunk_vectors(chunks: jax.Array, dfa: Dfa) -> jax.Array:
    groups = tr.byte_groups(chunks, dfa)
    return tr.chunk_transition_vectors(groups, dfa)


def replay(chunks: jax.Array, start_states: jax.Array, dfa: Dfa):
    groups = tr.byte_groups(chunks, dfa)
    classes, ends, _ = tr.replay(groups, start_states, dfa)
    return classes, ends
