"""Pallas TPU kernels for ParPaRaw's per-chunk DFA simulation (paper §3.1).

Two kernels:

  * ``chunk_vectors_kernel`` — the |S|-simultaneous-DFA pass: every chunk
    folds its symbols into a state-transition vector.  Chunks ride the VPU
    lanes (``block_chunks`` per grid step); the state axis (|S| ≤ 8) is a
    short trailing axis.
  * ``replay_kernel`` — the second pass: one DFA per chunk from its true
    start state, emitting the symbol-class code stream.

TPU adaptation notes (DESIGN.md §3):
  * Symbol→group matching is branchless broadcast-compare against the DFA's
    distinguished bytes — the VPU-native analogue of the paper's SWAR
    LU-register trick.  No 256-entry LUT gather in the hot loop.
  * The state-transition table is applied via one-hot select chains
    (``Σ_g (g==g')·T[:,g']`` then ``Σ_s (v==s')·row[s']``): TPU vector lanes
    cannot dynamically index VMEM per-lane (the role MFIRA's BFI/BFE played
    on GPU), but |S|·|G| ≤ 64 makes select chains cheap and fully vector.
  * The symbol loop is a ``fori_loop`` over the chunk byte axis with dynamic
    slicing — VMEM-resident, no HBM traffic inside the loop.

Weak-scaling shape contract: ``chunks (C, K) uint8`` with C a multiple of
``block_chunks``; callers pad (identity vectors / PAD bytes are inert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.dfa import Dfa

DEFAULT_BLOCK_CHUNKS = 256


def _group_select(bytes_i32, group_bytes, n_groups):
    """Branchless group id for a vector of bytes (SWAR analogue).

    Shared with the whole-pipeline megakernel
    (``kernels/fused_pipeline``), whose in-kernel replay must classify
    bytes exactly like the staged replay kernels here.
    """
    g = jnp.full(bytes_i32.shape, n_groups - 1, jnp.int32)  # catch-all
    for gi, b in enumerate(group_bytes):
        g = jnp.where(bytes_i32 == b, gi, g)
    return g


def _make_chunk_vectors_kernel(dfa: Dfa, block_chunks: int, chunk_bytes: int):
    S, G = dfa.n_states, dfa.n_groups
    group_bytes = dfa.group_bytes

    def kernel(chunks_ref, tt_ref, out_ref):
        data = chunks_ref[...].astype(jnp.int32)  # (BC, K)
        tt = tt_ref[...]  # (S, G) int32, VMEM-resident across the whole loop

        def body(k, vec):
            byte = jax.lax.dynamic_slice(data, (0, k), (block_chunks, 1))[:, 0]
            g = _group_select(byte, group_bytes, G)  # (BC,)
            # Tg[c, s'] = T[s', g[c]]  via one-hot select over groups.
            tg = jnp.zeros((block_chunks, S), jnp.int32)
            for gi in range(G):
                tg = jnp.where((g == gi)[:, None], tt[:, gi][None, :], tg)
            # new_vec[c, s] = Tg[c, vec[c, s]]  via one-hot select over states.
            new = jnp.zeros_like(vec)
            for si in range(S):
                new = jnp.where(vec == si, tg[:, si][:, None], new)
            return new

        init = jax.lax.broadcasted_iota(jnp.int32, (block_chunks, S), 1)
        vec = jax.lax.fori_loop(0, chunk_bytes, body, init)
        out_ref[...] = vec

    return kernel


def chunk_vectors(
    chunks: jax.Array,
    dfa: Dfa,
    *,
    block_chunks: int = DEFAULT_BLOCK_CHUNKS,
    interpret: bool = True,
) -> jax.Array:
    """``(C, K) uint8`` → per-chunk state-transition vectors ``(C, S) int32``."""
    c, k = chunks.shape
    bc = min(block_chunks, c)
    if c % bc:
        raise ValueError(f"n_chunks {c} not a multiple of block_chunks {bc}")
    kernel = _make_chunk_vectors_kernel(dfa, bc, k)
    tt = jnp.asarray(dfa.transition.astype(np.int32))
    return pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((dfa.n_states, dfa.n_groups), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, dfa.n_states), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, dfa.n_states), jnp.int32),
        interpret=interpret,
    )(chunks, tt)


def _make_replay_kernel(dfa: Dfa, block_chunks: int, chunk_bytes: int):
    S, G = dfa.n_states, dfa.n_groups
    group_bytes = dfa.group_bytes
    t_flat = tuple(int(x) for x in dfa.transition.reshape(-1))
    e_flat = tuple(int(x) for x in dfa.emission.reshape(-1))

    def kernel(chunks_ref, start_ref, cls_ref, end_ref):
        data = chunks_ref[...].astype(jnp.int32)  # (BC, K)
        state0 = start_ref[...].astype(jnp.int32).reshape(block_chunks)

        def body(k, carry):
            state = carry
            byte = jax.lax.dynamic_slice(data, (0, k), (block_chunks, 1))[:, 0]
            g = _group_select(byte, group_bytes, G)
            idx = state * G + g  # (BC,) in [0, S*G)
            new = jnp.zeros_like(state)
            cls = jnp.zeros_like(state)
            for j in range(S * G):
                hit = idx == j
                new = jnp.where(hit, t_flat[j], new)
                cls = jnp.where(hit, e_flat[j], cls)
            cls_ref[:, pl.dslice(k, 1)] = cls.astype(jnp.int32)[:, None]
            return new

        state = jax.lax.fori_loop(0, chunk_bytes, body, state0)
        end_ref[...] = state[:, None]

    return kernel


def _make_replay_fused_kernel(dfa: Dfa, block_chunks: int, chunk_bytes: int):
    """Replay that ALSO accumulates the paper-§3.2 per-chunk summaries
    (record count, abs/rel column offset) inside the same VMEM pass —
    the structural optimisation identified in EXPERIMENTS §Perf A: the
    separate jnp ``chunk_summaries`` pass over the class stream disappears.
    """
    from repro.core.dfa import FIELD_DELIM, RECORD_DELIM

    S, G = dfa.n_states, dfa.n_groups
    group_bytes = dfa.group_bytes
    t_flat = tuple(int(x) for x in dfa.transition.reshape(-1))
    e_flat = tuple(int(x) for x in dfa.emission.reshape(-1))

    def kernel(chunks_ref, start_ref, cls_ref, end_ref, summ_ref):
        data = chunks_ref[...].astype(jnp.int32)
        state0 = start_ref[...].astype(jnp.int32).reshape(block_chunks)
        zeros = jnp.zeros((block_chunks,), jnp.int32)

        def body(k, carry):
            state, rec_cnt, fld_since = carry
            byte = jax.lax.dynamic_slice(data, (0, k), (block_chunks, 1))[:, 0]
            g = _group_select(byte, group_bytes, G)
            idx = state * G + g
            new = jnp.zeros_like(state)
            cls = jnp.zeros_like(state)
            for j in range(S * G):
                hit = idx == j
                new = jnp.where(hit, t_flat[j], new)
                cls = jnp.where(hit, e_flat[j], cls)
            cls_ref[:, pl.dslice(k, 1)] = cls[:, None]
            is_rec = cls == RECORD_DELIM
            is_fld = cls == FIELD_DELIM
            rec_cnt = rec_cnt + is_rec.astype(jnp.int32)
            # field delimiters since the last record delimiter (abs offset)
            fld_since = jnp.where(is_rec, 0, fld_since + is_fld.astype(jnp.int32))
            return new, rec_cnt, fld_since

        state, rec_cnt, fld_since = jax.lax.fori_loop(
            0, chunk_bytes, body, (state0, zeros, zeros)
        )
        end_ref[...] = state[:, None]
        has_rec = rec_cnt > 0
        # paper Fig. 4: ABS(=1) offset counts after the last record delim;
        # REL(=0) chunks report their total field-delim count — identical
        # here because fld_since never reset when has_rec is False.
        summ_ref[:, 0:1] = rec_cnt[:, None]
        summ_ref[:, 1:2] = has_rec.astype(jnp.int32)[:, None]
        summ_ref[:, 2:3] = fld_since[:, None]

    return kernel


def replay_fused(
    chunks: jax.Array,
    start_states: jax.Array,
    dfa: Dfa,
    *,
    block_chunks: int = DEFAULT_BLOCK_CHUNKS,
    interpret: bool = True,
):
    """Fused replay: ``(C,K) bytes + (C,) starts → (classes (C,K) uint8,
    end states (C,), summaries (C,3) int32 [rec_count, col_tag, col_off])``.
    """
    c, k = chunks.shape
    bc = min(block_chunks, c)
    if c % bc:
        raise ValueError(f"n_chunks {c} not a multiple of block_chunks {bc}")
    kernel = _make_replay_fused_kernel(dfa, bc, k)
    classes, ends, summ = pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, k), jnp.int32),
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
            jax.ShapeDtypeStruct((c, 3), jnp.int32),
        ],
        interpret=interpret,
    )(chunks, start_states.astype(jnp.int32)[:, None])
    return classes.astype(jnp.uint8), ends[:, 0], summ


def replay(
    chunks: jax.Array,
    start_states: jax.Array,
    dfa: Dfa,
    *,
    block_chunks: int = DEFAULT_BLOCK_CHUNKS,
    interpret: bool = True,
):
    """Replay pass: ``(C, K) bytes + (C,) start states → (C, K) classes,
    (C,) end states``."""
    c, k = chunks.shape
    bc = min(block_chunks, c)
    if c % bc:
        raise ValueError(f"n_chunks {c} not a multiple of block_chunks {bc}")
    kernel = _make_replay_kernel(dfa, bc, k)
    classes, ends = pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, k), jnp.int32),
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
        ],
        interpret=interpret,
    )(chunks, start_states.astype(jnp.int32)[:, None])
    return classes.astype(jnp.uint8), ends[:, 0]
