"""Jit'd public wrappers for the dfa_scan kernels.

``parse_classes`` is the kernel-backed equivalent of
``repro.core.transition.transition_pipeline``: Pallas kernels for the two
chunk-local passes, XLA ``associative_scan`` for the O(C·S) composite scan
between them (the scan is bandwidth-trivial next to the byte passes and XLA
already emits a work-efficient tree for it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import transition as tr
from repro.core.dfa import Dfa
from repro.kernels.dfa_scan import dfa_scan


@functools.partial(
    jax.jit, static_argnames=("dfa", "block_chunks", "interpret")
)
def chunk_vectors(chunks, dfa: Dfa, block_chunks: int = dfa_scan.DEFAULT_BLOCK_CHUNKS,
                  interpret: bool = True):
    return dfa_scan.chunk_vectors(chunks, dfa, block_chunks=block_chunks,
                                  interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("dfa", "block_chunks", "interpret")
)
def replay(chunks, start_states, dfa: Dfa,
           block_chunks: int = dfa_scan.DEFAULT_BLOCK_CHUNKS,
           interpret: bool = True):
    return dfa_scan.replay(chunks, start_states, dfa,
                           block_chunks=block_chunks, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("dfa", "block_chunks", "interpret", "use_matmul")
)
def parse_classes(chunks, dfa: Dfa,
                  block_chunks: int = dfa_scan.DEFAULT_BLOCK_CHUNKS,
                  interpret: bool = True, use_matmul: bool = False):
    """Kernel-backed context determination + replay (paper §3.1 end to end)."""
    vecs = dfa_scan.chunk_vectors(chunks, dfa, block_chunks=block_chunks,
                                  interpret=interpret)
    scanned = tr.exclusive_scan_vectors(vecs, use_matmul=use_matmul)
    start = tr.start_states(scanned, dfa)
    classes, ends = dfa_scan.replay(chunks, start, dfa,
                                    block_chunks=block_chunks,
                                    interpret=interpret)
    return classes, ends


@functools.partial(
    jax.jit, static_argnames=("dfa", "block_chunks", "interpret")
)
def replay_fused(chunks, start_states, dfa: Dfa,
                 block_chunks: int = dfa_scan.DEFAULT_BLOCK_CHUNKS,
                 interpret: bool = True):
    """Fused replay + paper-§3.2 chunk summaries in one VMEM pass."""
    return dfa_scan.replay_fused(chunks, start_states, dfa,
                                 block_chunks=block_chunks,
                                 interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("dfa", "block_chunks", "interpret", "use_matmul")
)
def parse_contexts(chunks, dfa: Dfa,
                   block_chunks: int = dfa_scan.DEFAULT_BLOCK_CHUNKS,
                   interpret: bool = True, use_matmul: bool = False,
                   initial_state=None):
    """Kernel-backed §3.1 + fused §3.2: context determination, replay, and
    per-chunk offset summaries — ``parse_classes`` upgraded to the fused
    replay so the downstream record/column scan consumes kernel-produced
    summaries with no separate jnp ``chunk_summaries`` pass.  Chunk counts
    that do not divide ``block_chunks`` are padded with inert PAD chunks and
    sliced back (same contract as ``backend="pallas"``).

    Returns ``(classes (C,K) uint8, end_states (C,) int32,
    summaries (C,3) int32 [rec_count, col_tag, col_off])``.
    """
    from repro.core.backends import pad_to_block
    from repro.core.dfa import PAD_BYTE

    bc = min(block_chunks, chunks.shape[0])
    padded, n = pad_to_block(chunks, bc, PAD_BYTE)
    vecs = dfa_scan.chunk_vectors(padded, dfa, block_chunks=bc,
                                  interpret=interpret)[:n]
    scanned = tr.exclusive_scan_vectors(vecs, use_matmul=use_matmul)
    start = tr.start_states(scanned, dfa, initial_state=initial_state)
    start_p, _ = pad_to_block(start, bc, dfa.start_state)
    classes, ends, summ = dfa_scan.replay_fused(
        padded, start_p, dfa, block_chunks=bc, interpret=interpret
    )
    return classes[:n], ends[:n], summ[:n]
