"""Pallas TPU kernel for the §3.3 stable partition (paper's radix pass).

The paper partitions the tagged symbol stream with one stable radix-sort
pass over column tags (CUB): per-block column histograms, an exclusive
prefix over (block, column), then a scatter to each symbol's destination.
On TPU the whole counting half collapses into ONE kernel, because Pallas
grids execute *sequentially*: a VMEM carry of per-column counts persists
across grid steps, so each step can histogram its blocks, take the
exclusive running prefix (the decoupled-lookback analogue — no second
global pass), rank every tag inside its block, and emit each symbol's
*column-relative* destination in a single sweep:

    rel[i] = (# earlier symbols with the same column tag)

All per-step work is a handful of wide vector ops on a 3D one-hot
(``(block_rows, block_tags, n_cols+1)``), never a per-column loop, so cost
is independent of schema width up to VMEM.  The column axis is tiny
(≤ a few dozen) and rides the trailing one-hot dimension.

What stays in XLA glue (``ops.partition_tags``): turning the carry's final
value into global column starts (an ``n_cols+1``-sized exclusive cumsum),
``dest = start[tag] + rel``, and the one global scatter that materialises
the permutation — TPU vector lanes cannot scatter to HBM per-lane, so the
irregular write is the one step the kernel cannot own (same division of
labour as the CSS gather in ``kernels.numparse``).

Shape contract: ``tags (NB, BN) int32`` with NB a multiple of
``block_rows``; callers pad with the sentinel column ``n_cols`` (inert:
trailing sentinel padding ranks past every real sentinel symbol).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Tags per partition block (the paper's thread-block tile).
DEFAULT_BLOCK_TAGS = 256
#: Blocks per grid step (bench-tuned with DEFAULT_BLOCK_TAGS against the
#: jnp impls at yelp/taxi sizes — smaller blocks keep the one-hot cumsum
#: cheap, more rows per step amortise dispatch; BENCH_parser.json).
DEFAULT_BLOCK_ROWS = 64


def _onehot(tags, n_parts):
    """``(BR, BN, n_parts) int32`` column one-hot — one dense 3D op, not a
    per-column loop, so the work stays a handful of wide vector ops however
    many columns the schema has (scatter2's structure, VMEM-resident)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, tags.shape + (n_parts,), 2)
    return (tags[:, :, None] == cols).astype(jnp.int32)


def _make_partition_kernel(n_parts: int, block_rows: int, block_tags: int):
    def kernel(tags_ref, rel_ref, count_ref, carry_ref):
        # carry_ref (1, n_parts) VMEM scratch: per-column count of all tags
        # in earlier grid steps.  Grids run sequentially on TPU (and in the
        # interpreter), which is what makes the single-pass scan sound.
        @pl.when(pl.program_id(0) == 0)
        def _():
            carry_ref[...] = jnp.zeros((1, n_parts), jnp.int32)

        tags = tags_ref[...]                          # (BR, BN)
        onehot = _onehot(tags, n_parts)               # (BR, BN, C+1)
        block_hist = jnp.sum(onehot, axis=1)          # (BR, C+1)
        # Exclusive running count per column at each block: earlier grid
        # steps (carry) + earlier blocks within this step.
        block_excl = (jnp.cumsum(block_hist, axis=0) - block_hist
                      + carry_ref[...])               # (BR, C+1)
        # Stable intra-block rank: exclusive prefix along the tag axis,
        # selected at each tag's own column.
        ranks = jnp.cumsum(onehot, axis=1) - onehot   # (BR, BN, C+1)
        own_rank = jnp.sum(ranks * onehot, axis=2)    # (BR, BN)
        own_excl = jnp.einsum("rnc,rc->rn", onehot, block_excl)
        rel_ref[...] = own_excl + own_rank

        carry_ref[...] += jnp.sum(block_hist, axis=0, keepdims=True)
        count_ref[...] = carry_ref[...]               # last step's write wins

    return kernel


def partition_blocks(
    tags: jax.Array,
    n_cols: int,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(NB, BN) int32`` blocked tags → column-relative destinations
    ``(NB, BN) int32`` plus total per-column counts ``(n_cols+1,) int32``
    (sentinel drop column included)."""
    nb, bn = tags.shape
    br = min(block_rows, nb)
    if nb % br:
        raise ValueError(f"blocks {nb} not a multiple of block_rows {br}")
    n_parts = n_cols + 1
    kernel = _make_partition_kernel(n_parts, br, bn)
    rel, count = pl.pallas_call(
        kernel,
        grid=(nb // br,),
        in_specs=[pl.BlockSpec((br, bn), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, bn), lambda i: (i, 0)),
            pl.BlockSpec((1, n_parts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bn), jnp.int32),
            jax.ShapeDtypeStruct((1, n_parts), jnp.int32),
        ],
        scratch_shapes=[pltpu_vmem((1, n_parts), jnp.int32)],
        interpret=interpret,
    )(tags)
    return rel, count[0]


def pltpu_vmem(shape, dtype):
    """VMEM scratch spec; the deferred import keeps ``pallas.tpu`` off the
    module-import path (it is only touched when a kernel is actually built).
    """
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
