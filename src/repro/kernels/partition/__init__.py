from repro.kernels.partition import ops, partition, ref  # noqa: F401
