"""Pure-jnp oracle for the partition kernels.

``core.partition.partition_scatter2`` is the structural twin (two-level
counting pass: per-block histogram → inter-block scan → intra-block
ranks); any stable partition impl is a behavioural oracle because the
stable permutation is unique.
"""
from __future__ import annotations

from repro.core import partition as partition_mod


def partition_tags(col_tag, n_cols) -> partition_mod.Partitioned:
    """Same contract as ``ops.partition_tags``."""
    return partition_mod.partition_scatter2(col_tag, n_cols)
