"""Jit'd wrapper for the partition kernel: the §3.3 stable partition.

``partition_tags`` is the ``ParseBackend.partition`` entry point for
``backend="pallas"`` (``partition_impl="kernel"``): pad the tag stream to
a whole number of blocks with the sentinel column, run the single-pass
Pallas radix kernel (per-block histograms + running carry + intra-block
ranks → column-relative destinations), lift the relative destinations to
global ones with the tiny ``(n_cols+1,)`` exclusive prefix, and invert the
destination map into the gather-form permutation every ``partition_impl``
returns — so the kernel path is drop-in interchangeable with the jnp impls
and bit-identical to them (a stable partition's permutation is unique).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.partition import Partitioned
from repro.kernels.partition import partition as kernels


@functools.partial(
    jax.jit, static_argnames=("n_cols", "block_tags", "block_rows", "interpret")
)
def partition_tags(
    col_tag: jax.Array,
    n_cols: int,
    *,
    block_tags: int = kernels.DEFAULT_BLOCK_TAGS,
    block_rows: int = kernels.DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> Partitioned:
    """Kernel-backed equivalent of ``core.partition.partition_scatter2``."""
    n = col_tag.shape[0]
    if n == 0:  # degenerate but public: match the jnp impls' empty output
        zeros = jnp.zeros((n_cols + 1,), jnp.int32)
        return Partitioned(jnp.zeros((0,), jnp.int32), zeros, zeros)
    bn = min(block_tags, n) or 1
    nb = -(-n // bn)
    br = min(block_rows, nb)              # don't pad small streams up to a
                                          # full grid step of sentinel blocks
    nbp = -(-nb // br) * br               # pad blocks up to the grid step
    pad = nbp * bn - n
    tags = col_tag.astype(jnp.int32)
    if pad:
        tags = jnp.concatenate([tags, jnp.full((pad,), n_cols, jnp.int32)])

    rel, count = kernels.partition_blocks(
        tags.reshape(nbp, bn), n_cols, block_rows=br, interpret=interpret
    )

    # Tiny glue: global column starts from the totals, then lift the
    # column-relative destinations.  Sentinel padding is trailing, so it
    # only inflates the last column's count (corrected below) and no real
    # symbol's start or rank.
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(count)[:-1]])
    count = count.at[-1].add(-pad)
    dest = (start[tags] + rel.reshape(-1))[:n]

    # The radix pass's scatter: invert dest into gather form (XLA owns the
    # irregular write — see kernels/partition/partition.py docstring).
    # This is the staged path's one remaining HBM round-trip; the fused
    # whole-pipeline megakernel (kernels/fused_pipeline/) never builds the
    # permutation at all — it consumes dest directly in apply form
    # (css[dest[i]] = sym[i]), which is equivalent because dest is the
    # inverse of perm by construction.
    perm = jnp.zeros((n,), jnp.int32).at[dest].set(jnp.arange(n, dtype=jnp.int32))
    return Partitioned(perm, start.astype(jnp.int32), count.astype(jnp.int32))
