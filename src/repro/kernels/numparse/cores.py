"""Shared per-dtype arithmetic cores for every typed-conversion kernel.

One definition of the §3.3 field arithmetic — int (branchless Horner with
pre-step overflow detection), float (sign/mantissa/dot/exponent sections,
statically unrolled masked Horner), date (digit/separator/civil-calendar
validation + Hinnant days-from-civil) — imported by all four conversion
paths:

  * the unfused rowwise kernels (``numparse.parse_*_fields``),
  * the whole-CSS fused gather+convert kernels (``parse_*_fields_fused``),
  * the windowed-DMA kernels (``parse_*_fields_windowed``),
  * the whole-pipeline megakernel (``kernels/fused_pipeline``).

All run on the VPU with the width axis statically unrolled (W ≤ ~24) and
only read lanes ``< length`` (or mask them), so every consumer is
bit-identical to the jnp reference (``typeconv.parse_int`` /
``parse_float`` / ``parse_date``) by construction — a single core means
no copy-paste drift between the staged and fused pipelines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod

DEFAULT_BLOCK_ROWS = 512
#: Gather width for date fields — ``YYYY-MM-DD HH:MM:SS`` is exactly 19 bytes.
DATE_WIDTH = 19
#: CSS window starts are aligned down to this many bytes (the TPU lane
#: count) so the windowed BlockSpec DMA is lane-aligned on real hardware;
#: window tiles are sized in multiples of it.
WINDOW_ALIGN = 128
_ZERO = ord("0")
# Plain Python int: pallas kernels may not capture traced module constants.
_I32_MAX = typeconv_mod.INT32_MAX


def _int_arith(b, ln, block_rows: int, width: int):
    """``(BR, W) int32`` field bytes + ``(BR,) int32`` lengths →
    ``(value (BR,) int32, ok (BR,) bool)``.  Only lanes ``< ln`` are read."""
    first = b[:, 0]
    neg = first == ord("-")
    has_sign = neg | (first == ord("+"))
    sign = jnp.where(neg, -1, 1)

    acc = jnp.zeros((block_rows,), jnp.int32)
    bad = jnp.zeros((block_rows,), jnp.bool_)
    ndig = jnp.zeros((block_rows,), jnp.int32)
    for w in range(width):
        d = b[:, w] - _ZERO
        # lane w is a live digit if it is inside the field and not the sign
        live = (w < ln) & ~(has_sign & (w == 0))
        is_digit = (d >= 0) & (d <= 9)
        bad |= live & ~is_digit
        use = live & is_digit
        # magnitude overflow: acc*10+d would exceed INT32_MAX
        bad |= use & (acc > (_I32_MAX - d) // 10)
        acc = jnp.where(use, acc * 10 + d, acc)
        ndig += use.astype(jnp.int32)

    ok = ~bad & (ndig > 0) & (ln <= width)
    return sign * acc, ok


def _float_arith(raw, ln, block_rows: int, width: int):
    """Masked float32 parse over ``(BR, W) int32`` bytes — mirrors
    ``typeconv.parse_float`` operation-for-operation."""
    br, w = block_rows, width
    lane = jax.lax.broadcasted_iota(jnp.int32, (br, w), 1)
    m = lane < ln[:, None]
    raw = jnp.where(m, raw, 0)

    # Optional leading sign: shift the lane window left by one where
    # present (same trick as typeconv._sign_and_digits).
    first = raw[:, 0]
    has_sign = (first == ord("-")) | (first == ord("+"))
    sign = jnp.where(first == ord("-"), -1, 1).astype(jnp.int32)
    shifted = jnp.concatenate(
        [raw[:, 1:], jnp.zeros((br, 1), jnp.int32)], axis=1)
    shifted_m = jnp.concatenate(
        [m[:, 1:], jnp.zeros((br, 1), jnp.bool_)], axis=1)
    b = jnp.where(has_sign[:, None], shifted, raw)
    bm = jnp.where(has_sign[:, None], shifted_m, m)

    is_dot = (b == ord(".")) & bm
    is_e = ((b == ord("e")) | (b == ord("E"))) & bm
    dot_pos = jnp.min(jnp.where(is_dot, lane, w), axis=1)   # (BR,)
    e_pos = jnp.min(jnp.where(is_e, lane, w), axis=1)

    d = b - _ZERO
    is_digit = (d >= 0) & (d <= 9)

    in_mant = bm & (lane < e_pos[:, None])
    mant_digit = in_mant & ~is_dot
    ok = (jnp.sum(is_dot, axis=1) <= 1) & ((dot_pos <= e_pos) | (dot_pos >= w))
    ok &= jnp.all(is_digit | ~mant_digit, axis=1)
    ok &= jnp.any(mant_digit & is_digit, axis=1)

    # Mantissa Horner, statically unrolled over the width.
    active = mant_digit & is_digit
    dm = jnp.where(active, d, 0).astype(jnp.float32)
    macc = jnp.zeros((br,), jnp.float32)
    for k in range(w):
        macc = jnp.where(active[:, k], macc * 10.0 + dm[:, k], macc)
    frac_digits = jnp.sum(active & (lane > dot_pos[:, None]), axis=1)

    # Exponent section.
    after_e = bm & (lane > e_pos[:, None])
    e_sign_lane = jnp.clip(e_pos + 1, 0, w - 1)
    e_first = jnp.sum(jnp.where(lane == e_sign_lane[:, None], b, 0), axis=1)
    has_e = e_pos < w
    e_neg = has_e & (e_first == ord("-"))
    e_signed = has_e & ((e_first == ord("-")) | (e_first == ord("+")))
    exp_digit = after_e & (lane > (e_pos + jnp.where(e_signed, 1, 0))[:, None])
    ok &= jnp.all(is_digit | ~exp_digit, axis=1)
    ok &= ~has_e | jnp.any(exp_digit, axis=1)
    de = jnp.where(exp_digit & is_digit, d, 0)
    eacc = jnp.zeros((br,), jnp.int32)
    for k in range(w):
        eacc = jnp.where(exp_digit[:, k], eacc * 10 + de[:, k], eacc)

    exp = jnp.where(e_neg, -eacc, eacc) - frac_digits
    value = (sign.astype(jnp.float32) * macc *
             jnp.power(jnp.float32(10.0), exp.astype(jnp.float32)))
    ok &= ln <= w
    return value, ok


def _date_arith(raw, ln, block_rows: int):
    """``YYYY-MM-DD[ HH:MM:SS]`` over ``(BR, 19) int32`` bytes — mirrors
    ``typeconv.parse_date`` (civil-calendar + time-range validation)."""
    br, w = block_rows, DATE_WIDTH
    lane = jax.lax.broadcasted_iota(jnp.int32, (br, w), 1)
    raw = jnp.where(lane < ln[:, None], raw, 0)
    d = raw - _ZERO

    def num(*lanes):
        acc = jnp.zeros((br,), jnp.int32)
        for k in lanes:
            acc = acc * 10 + d[:, k]
        return acc

    year, mon, day = num(0, 1, 2, 3), num(5, 6), num(8, 9)
    has_time = ln >= 19
    hh = jnp.where(has_time, num(11, 12), 0)
    mm = jnp.where(has_time, num(14, 15), 0)
    ss = jnp.where(has_time, num(17, 18), 0)

    dd = (d >= 0) & (d <= 9)
    ok = (dd[:, 0] & dd[:, 1] & dd[:, 2] & dd[:, 3] &
          dd[:, 5] & dd[:, 6] & dd[:, 8] & dd[:, 9])
    ok &= (raw[:, 4] == ord("-")) & (raw[:, 7] == ord("-"))
    ok &= (ln == 10) | (ln == 19)
    time_ok = (dd[:, 11] & dd[:, 12] & dd[:, 14] & dd[:, 15] &
               dd[:, 17] & dd[:, 18] &
               (raw[:, 13] == ord(":")) & (raw[:, 16] == ord(":")) &
               ((raw[:, 10] == ord(" ")) | (raw[:, 10] == ord("T"))))
    ok &= jnp.where(has_time, time_ok, True)
    ok &= ((mon >= 1) & (mon <= 12) & (day >= 1) &
           (day <= typeconv_mod._days_in_month(year, mon)))
    ok &= jnp.where(has_time, (hh <= 23) & (mm <= 59) & (ss <= 59), True)

    secs = (typeconv_mod._days_from_civil(year, mon, day) * 86400 +
            hh * 3600 + mm * 60 + ss)
    return secs, ok
