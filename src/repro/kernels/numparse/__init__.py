from repro.kernels.numparse.ops import parse_int_fields

__all__ = ["parse_int_fields"]
