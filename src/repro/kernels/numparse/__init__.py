from repro.kernels.numparse.ops import (
    parse_date_fields,
    parse_float_fields,
    parse_int_fields,
)

__all__ = ["parse_int_fields", "parse_float_fields", "parse_date_fields"]
