"""Jit'd wrappers for the numparse kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod
from repro.core.backends import pad_to_block
from repro.kernels.numparse import numparse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_int_fields(field_bytes, lengths,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    return numparse.parse_int_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_int_column(css, offset, length, width: int = 11,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True) -> typeconv_mod.Parsed:
    """Field-index entry point: gather a column's field bytes out of the CSS
    (XLA gather — TPU lanes cannot index HBM per-lane) and hand the dense
    ``(R, W)`` matrix to the Pallas arithmetic kernel.

    This is the kernel-backed equivalent of ``typeconv.parse_int`` and what
    ``backend="pallas"`` routes int32 columns through; row counts that do not
    divide the block are padded with zero-length fields and sliced off.
    """
    raw, _ = typeconv_mod.gather_field_bytes(css, offset, length, width)
    br = min(block_rows, raw.shape[0])
    padded, n = pad_to_block(raw, br, 0)
    len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
    val, ok = numparse.parse_int_fields(
        padded, len_p, block_rows=br, interpret=interpret
    )
    val, ok = val[:n], ok[:n]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)
