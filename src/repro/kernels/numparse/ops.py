"""Jit'd wrappers for the numparse kernels.

Two families of field-index entry points:

  * ``parse_*_column_fused`` — the default ``backend="pallas"`` path
    (``cfg.fuse_typeconv=True``): hand the CSS plus ``(offset, length)``
    straight to a fused Pallas kernel, which indexes the symbol buffer
    inside the kernel block.  No XLA ``take``/gather and no ``(R, W)``
    row-padded byte matrix between the field index and type conversion.
    By default the fused path is *windowed*: :func:`plan_css_windows`
    derives one contiguous, 128-byte-aligned CSS window per ``window_rows``
    row block (offsets within a column are sorted after the stable
    partition, so a block's fields always share a window), rebases the
    offsets window-relative, and the kernel DMAs only a static
    ``window_bytes`` tile per grid step — VMEM holds ``O(window_bytes)``,
    not the whole CSS, so per-parse input size is no longer capped by
    VMEM capacity.  When the plan detects a window the static tile cannot
    hold (a mega-field longer than the tile) or offsets that are not
    monotone (the sortedness contract violated by a hand-built index),
    the column falls back under ``lax.cond`` — to the whole-CSS fused
    kernel while the CSS is statically small
    (:data:`WHOLECSS_FALLBACK_MAX_BYTES`), else to per-row windows
    (``rows_per_block=1`` — correct for arbitrary offsets, still
    ``O(width)`` VMEM), so the windowed path never *compiles* a kernel
    whose VMEM block grows with the CSS.  Same arithmetic, same results
    on every branch.  ``window_rows=WHOLE_CSS`` (−1) disables windowing
    outright (the benchmark baseline for the window DMA).
  * ``parse_*_column``       — the unfused path: gather a column's field
    bytes out of the CSS with XLA's gather and hand the dense ``(R, W)``
    matrix to the arithmetic kernel.  Kept as the ``cfg.fuse_typeconv=False``
    fallback and the benchmark baseline for the fusion.

All share the per-dtype arithmetic (``numparse._*_arith``), so they are
bit-identical.  Row counts that do not divide the kernel block are padded
with zero-length fields and sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod
from repro.core.backends import pad_to_block
from repro.kernels.numparse import numparse

#: ``window_rows`` sentinel: disable windowing, run the whole-CSS fused
#: kernels unconditionally (PR-3 behaviour; the windowed path's baseline).
WHOLE_CSS = -1


def auto_window_bytes(rows_per_block: int, width: int) -> int:
    """Static CSS window tile (bytes) for ``rows_per_block`` fields.

    Offsets of consecutive fields in one column differ by at most
    ``field length (+1 terminator byte in the inline/vector tagging
    modes)``, so a row block whose fields all fit ``width`` spans at most
    ``rows_per_block * (width + 1) + width`` CSS bytes; one extra
    :data:`numparse.WINDOW_ALIGN` granule absorbs the align-down of the
    window start, and the total rounds up to the alignment.  Fields longer
    than ``width`` (unparseable anyway) may exceed this and take the
    whole-CSS fallback at run time.
    """
    need = rows_per_block * (width + 1) + width + numparse.WINDOW_ALIGN
    a = numparse.WINDOW_ALIGN
    return -(-need // a) * a


def plan_css_windows(offset, length, *, rows_per_block: int, width: int,
                     window_bytes: int, css_len: int):
    """Per-block CSS window plan: ``(win_start, rel_offset, fits)``.

    All jnp (traced, gather-free).  ``offset``/``length`` are ``(R,)`` with
    ``R`` a multiple of ``rows_per_block``.  Empty fields (``length == 0``)
    carry meaningless offsets (the field index emits 0), so each takes the
    running maximum of the non-empty offsets before it — keeping the
    per-block window tight and the effective offsets monotone.  Empties
    *before* the first non-empty field seed from the column's first
    non-empty offset (its minimum, given sortedness) rather than 0, so a
    missing value in record 0 cannot drag an otherwise-tight window back
    to the start of the CSS.

    Returns:
      win_start: ``(R // rows_per_block,) int32`` element offsets into the
        CSS, aligned down to :data:`numparse.WINDOW_ALIGN`.
      rel_offset: ``(R,) int32`` window-relative offsets, clamped to
        ``[0, window_bytes - width]`` (the clamp only matters when ``fits``
        is False and the windowed result is discarded).
      fits: ``() bool`` — True iff every block's fields live inside its
        static ``window_bytes`` tile AND non-empty offsets are monotone
        non-decreasing (the §3.3 sortedness contract).  When False the
        caller must use a fallback path (see ``_fused_column``).
    """
    r = offset.shape[0]
    nb = r // rows_per_block
    nonempty = length > 0
    off_c = jnp.clip(offset.astype(jnp.int32), 0, css_len)
    # Seed for empty rows: the first (= minimum, offsets sorted) non-empty
    # offset, so leading empties inherit forward; css_len if all empty.
    seed = jnp.min(jnp.where(nonempty, off_c, css_len))
    eff = jax.lax.cummax(jnp.where(nonempty, off_c, seed))
    monotone = jnp.all(jnp.where(nonempty, off_c == eff, True))
    eff_blocks = eff.reshape(nb, rows_per_block)
    a = numparse.WINDOW_ALIGN
    win_start = (eff_blocks[:, 0] // a) * a
    need = eff_blocks[:, -1] + width - win_start
    fits = monotone & (jnp.max(need) <= window_bytes)
    start_rep = jnp.broadcast_to(
        win_start[:, None], (nb, rows_per_block)).reshape(-1)
    rel = jnp.clip(jnp.where(nonempty, off_c, eff) - start_rep,
                   0, window_bytes - width)
    return win_start, rel, fits


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_int_fields(field_bytes, lengths,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    return numparse.parse_int_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_float_fields(field_bytes, lengths,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    return numparse.parse_float_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_fields(field_bytes, lengths,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True):
    return numparse.parse_date_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


def _gather_and_run(kernel_fn, css, offset, length, width, block_rows, interpret):
    raw, _ = typeconv_mod.gather_field_bytes(css, offset, length, width)
    br = min(block_rows, raw.shape[0])
    padded, n = pad_to_block(raw, br, 0)
    len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
    val, ok = kernel_fn(padded, len_p, block_rows=br, interpret=interpret)
    val, ok = val[:n], ok[:n]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_int_column(css, offset, length, width: int = 11,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_int``."""
    return _gather_and_run(numparse.parse_int_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_float_column(css, offset, length, width: int = 24,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_float`` (bit-identical)."""
    return _gather_and_run(numparse.parse_float_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_column(css, offset, length,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_date`` (bit-identical)."""
    return _gather_and_run(numparse.parse_date_fields, css, offset, length,
                           numparse.DATE_WIDTH, block_rows, interpret)


# ---------------------------------------------------------------------------
# fused gather+convert entry points (the kernel owns the CSS indexing)
# ---------------------------------------------------------------------------

def _resolve_window(window_rows, window_bytes, block_rows, width, r):
    """Static window geometry: (rows per window block, window tile bytes)."""
    br = min(window_rows or block_rows, r)
    wt = window_bytes or auto_window_bytes(br, width)
    a = numparse.WINDOW_ALIGN
    wt = max(-(-wt // a) * a, -(-(width + a) // a) * a)  # ≥ width + align
    return br, wt


#: Static ceiling (bytes) for the whole-CSS *fallback* of the windowed
#: path.  A CSS at most this big may be compiled as a single VMEM block for
#: the mega-field/non-monotone fallback branch (fast, one grid sweep);
#: beyond it the fallback switches to per-row windows (``rows_per_block=1``
#: — correct for arbitrary offsets, VMEM-bounded, slower), so no kernel
#: with an unbounded VMEM block is ever *compiled*, keeping the windowed
#: path's VMEM usage bounded regardless of CSS size.  Well under the
#: ~16 MB/core VMEM budget, leaving room for outputs and double-buffering.
WHOLECSS_FALLBACK_MAX_BYTES = 4 << 20


def _per_row_windows(css_len, offset, width):
    """Degenerate per-row window plan: one window per field.

    Each row's window depends only on its own offset — no sortedness, no
    mega-field sensitivity (only ``width`` bytes are ever read per field) —
    so this plan is correct for *arbitrary* ``(offset, length)`` while
    keeping the VMEM block at ``O(width)``.  The universal fallback when
    the CSS is too large for the whole-CSS fallback kernel.
    """
    a = numparse.WINDOW_ALIGN
    wt1 = -(-(width + a) // a) * a          # align slop + width fits
    off_c = jnp.clip(offset.astype(jnp.int32), 0, css_len)
    ws1 = (off_c // a) * a
    return ws1, off_c - ws1, wt1


def _fused_column(whole_fn, windowed_fn, css, offset, length, width,
                  block_rows, window_rows, window_bytes, interpret,
                  wholecss_max=None):
    """Shared fused-column body: windowed by default, bounded fallback.

    ``whole_fn(css, off, len, block_rows=, interpret=)`` and
    ``windowed_fn(css, rel, len, win_start, block_rows=, window_bytes=,
    interpret=)`` arrive with any dtype-specific ``width`` already bound.
    ``window_rows == WHOLE_CSS`` skips planning entirely; otherwise the
    window plan decides at run time (``lax.cond``) between the windowed
    kernel and a fallback for degenerate shapes (mega-fields overflowing
    the static tile, non-monotone offsets).  The fallback itself is chosen
    *statically* by CSS size so no unbounded-VMEM kernel is ever compiled:
    at most ``wholecss_max`` bytes (default
    :data:`WHOLECSS_FALLBACK_MAX_BYTES`) the whole-CSS kernel; above that,
    per-row windows (:func:`_per_row_windows` — correct for arbitrary
    offsets, ``O(width)`` VMEM, one grid step per field).  Every branch
    shares the arithmetic, so the choice never changes results, only
    footprint and speed.
    """
    if wholecss_max is None:
        wholecss_max = WHOLECSS_FALLBACK_MAX_BYTES
    r0 = offset.shape[0]
    if r0 == 0:  # degenerate but public: no fields to convert
        zb = jnp.zeros((0,), bool)
        return typeconv_mod.Parsed(
            whole_fn(css, offset, length, block_rows=block_rows,
                     interpret=interpret)[0], zb, zb)
    if window_rows == WHOLE_CSS:
        br = min(block_rows, r0)
        off_p, r = pad_to_block(offset.astype(jnp.int32), br, 0)
        len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
        val, ok = whole_fn(css, off_p, len_p, block_rows=br,
                           interpret=interpret)
    else:
        br, wt = _resolve_window(window_rows, window_bytes, block_rows,
                                 width, r0)
        off_p, r = pad_to_block(offset.astype(jnp.int32), br, 0)
        len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
        win_start, rel, fits = plan_css_windows(
            off_p, len_p, rows_per_block=br, width=width, window_bytes=wt,
            css_len=css.shape[0],
        )
        if css.shape[0] + width <= wholecss_max:  # static: shapes, not data
            fallback = lambda: whole_fn(css, off_p, len_p, block_rows=br,
                                        interpret=interpret)
        else:
            ws1, rel1, wt1 = _per_row_windows(css.shape[0], off_p, width)
            fallback = lambda: windowed_fn(css, rel1, len_p, ws1,
                                           block_rows=1, window_bytes=wt1,
                                           interpret=interpret)
        val, ok = jax.lax.cond(
            fits,
            lambda: windowed_fn(css, rel, len_p, win_start, block_rows=br,
                                window_bytes=wt, interpret=interpret),
            fallback,
        )
    val, ok = val[:r], ok[:r]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)


_FUSED_STATICS = ("width", "block_rows", "window_rows", "window_bytes",
                  "interpret")


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def parse_int_column_fused(css, offset, length, width: int = 11,
                           block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                           window_rows: int = 0, window_bytes: int = 0,
                           interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_int_column`` (bit-identical, no XLA
    gather); windowed per-block CSS DMA unless ``window_rows=WHOLE_CSS``."""
    return _fused_column(
        functools.partial(numparse.parse_int_fields_fused, width=width),
        functools.partial(numparse.parse_int_fields_windowed, width=width),
        css, offset, length, width, block_rows, window_rows, window_bytes,
        interpret)


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def parse_float_column_fused(css, offset, length, width: int = 24,
                             block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                             window_rows: int = 0, window_bytes: int = 0,
                             interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_float_column`` (bit-identical, no XLA
    gather); windowed per-block CSS DMA unless ``window_rows=WHOLE_CSS``."""
    return _fused_column(
        functools.partial(numparse.parse_float_fields_fused, width=width),
        functools.partial(numparse.parse_float_fields_windowed, width=width),
        css, offset, length, width, block_rows, window_rows, window_bytes,
        interpret)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "window_rows",
                                    "window_bytes", "interpret"))
def parse_date_column_fused(css, offset, length,
                            block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                            window_rows: int = 0, window_bytes: int = 0,
                            interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_date_column`` (bit-identical, no XLA
    gather); windowed per-block CSS DMA unless ``window_rows=WHOLE_CSS``."""
    return _fused_column(numparse.parse_date_fields_fused,
                         numparse.parse_date_fields_windowed,
                         css, offset, length, numparse.DATE_WIDTH, block_rows,
                         window_rows, window_bytes, interpret)
