"""Jit'd wrappers for the numparse kernels.

``parse_*_column`` are the field-index entry points ``backend="pallas"``
routes typed columns through: gather a column's field bytes out of the CSS
(XLA gather — TPU lanes cannot index HBM per-lane), pad the row count to the
kernel block, and hand the dense ``(R, W)`` matrix to the Pallas arithmetic
kernel.  Row counts that do not divide the block are padded with zero-length
fields and sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod
from repro.core.backends import pad_to_block
from repro.kernels.numparse import numparse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_int_fields(field_bytes, lengths,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    return numparse.parse_int_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_float_fields(field_bytes, lengths,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    return numparse.parse_float_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_fields(field_bytes, lengths,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True):
    return numparse.parse_date_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


def _gather_and_run(kernel_fn, css, offset, length, width, block_rows, interpret):
    raw, _ = typeconv_mod.gather_field_bytes(css, offset, length, width)
    br = min(block_rows, raw.shape[0])
    padded, n = pad_to_block(raw, br, 0)
    len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
    val, ok = kernel_fn(padded, len_p, block_rows=br, interpret=interpret)
    val, ok = val[:n], ok[:n]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_int_column(css, offset, length, width: int = 11,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_int``."""
    return _gather_and_run(numparse.parse_int_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_float_column(css, offset, length, width: int = 24,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_float`` (bit-identical)."""
    return _gather_and_run(numparse.parse_float_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_column(css, offset, length,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_date`` (bit-identical)."""
    return _gather_and_run(numparse.parse_date_fields, css, offset, length,
                           numparse.DATE_WIDTH, block_rows, interpret)
