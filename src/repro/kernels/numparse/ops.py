"""Jit'd wrappers for the numparse kernels.

Two families of field-index entry points:

  * ``parse_*_column_fused`` — the default ``backend="pallas"`` path
    (``cfg.fuse_typeconv=True``): hand the CSS plus ``(offset, length)``
    straight to the fused Pallas kernel, which indexes the symbol buffer
    inside the kernel block.  No XLA ``take``/gather and no ``(R, W)``
    row-padded byte matrix between the field index and type conversion.
  * ``parse_*_column``       — the unfused path: gather a column's field
    bytes out of the CSS with XLA's gather and hand the dense ``(R, W)``
    matrix to the arithmetic kernel.  Kept as the ``cfg.fuse_typeconv=False``
    fallback and the benchmark baseline for the fusion.

Both share the per-dtype arithmetic (``numparse._*_arith``), so they are
bit-identical.  Row counts that do not divide the kernel block are padded
with zero-length fields and sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod
from repro.core.backends import pad_to_block
from repro.kernels.numparse import numparse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_int_fields(field_bytes, lengths,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    return numparse.parse_int_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_float_fields(field_bytes, lengths,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    return numparse.parse_float_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_fields(field_bytes, lengths,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True):
    return numparse.parse_date_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )


def _gather_and_run(kernel_fn, css, offset, length, width, block_rows, interpret):
    raw, _ = typeconv_mod.gather_field_bytes(css, offset, length, width)
    br = min(block_rows, raw.shape[0])
    padded, n = pad_to_block(raw, br, 0)
    len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
    val, ok = kernel_fn(padded, len_p, block_rows=br, interpret=interpret)
    val, ok = val[:n], ok[:n]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_int_column(css, offset, length, width: int = 11,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_int``."""
    return _gather_and_run(numparse.parse_int_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_float_column(css, offset, length, width: int = 24,
                       block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                       interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_float`` (bit-identical)."""
    return _gather_and_run(numparse.parse_float_fields, css, offset, length,
                           width, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_column(css, offset, length,
                      block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                      interpret: bool = True) -> typeconv_mod.Parsed:
    """Kernel-backed equivalent of ``typeconv.parse_date`` (bit-identical)."""
    return _gather_and_run(numparse.parse_date_fields, css, offset, length,
                           numparse.DATE_WIDTH, block_rows, interpret)


# ---------------------------------------------------------------------------
# fused gather+convert entry points (the kernel owns the CSS indexing)
# ---------------------------------------------------------------------------

def _fused_column(kernel_fn, css, offset, length, block_rows, interpret, **kw):
    br = min(block_rows, offset.shape[0])
    off_p, r = pad_to_block(offset.astype(jnp.int32), br, 0)
    len_p, _ = pad_to_block(length.astype(jnp.int32), br, 0)
    val, ok = kernel_fn(css, off_p, len_p, block_rows=br, interpret=interpret,
                        **kw)
    val, ok = val[:r], ok[:r]
    empty = length == 0
    return typeconv_mod.Parsed(val, ok & ~empty, empty)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_int_column_fused(css, offset, length, width: int = 11,
                           block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                           interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_int_column`` (bit-identical, no XLA gather)."""
    return _fused_column(numparse.parse_int_fields_fused, css, offset, length,
                         block_rows, interpret, width=width)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def parse_float_column_fused(css, offset, length, width: int = 24,
                             block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                             interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_float_column`` (bit-identical, no XLA gather)."""
    return _fused_column(numparse.parse_float_fields_fused, css, offset, length,
                         block_rows, interpret, width=width)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_date_column_fused(css, offset, length,
                            block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                            interpret: bool = True) -> typeconv_mod.Parsed:
    """Fused equivalent of ``parse_date_column`` (bit-identical, no XLA gather)."""
    return _fused_column(numparse.parse_date_fields_fused, css, offset, length,
                         block_rows, interpret)
