"""Jit'd wrappers for the numparse kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.numparse import numparse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def parse_int_fields(field_bytes, lengths,
                     block_rows: int = numparse.DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    return numparse.parse_int_fields(
        field_bytes, lengths, block_rows=block_rows, interpret=interpret
    )
