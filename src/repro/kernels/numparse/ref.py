"""Pure-jnp oracle for numparse — delegates to repro.core.typeconv."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import typeconv


def _as_column(field_bytes):
    """Reconstruct a css/offset view: fields are the rows themselves."""
    r, w = field_bytes.shape
    css = field_bytes.reshape(-1)
    offsets = jnp.arange(r, dtype=jnp.int32) * w
    return css, offsets, w


def parse_int_fields(field_bytes, lengths):
    """Same contract as the kernel: gathered (R, W) bytes + lengths."""
    css, offsets, w = _as_column(field_bytes)
    parsed = typeconv.parse_int(css, offsets, lengths, width=w)
    return parsed.value, parsed.valid


def parse_float_fields(field_bytes, lengths):
    css, offsets, w = _as_column(field_bytes)
    parsed = typeconv.parse_float(css, offsets, lengths, width=w)
    return parsed.value, parsed.valid


def parse_date_fields(field_bytes, lengths):
    r, w = field_bytes.shape
    if w < 19:  # parse_date always gathers 19 bytes; keep rows self-contained
        pad = jnp.zeros((r, 19 - w), field_bytes.dtype)
        field_bytes = jnp.concatenate([field_bytes, pad], axis=1)
    css, offsets, _ = _as_column(field_bytes)
    parsed = typeconv.parse_date(css, offsets, lengths)
    return parsed.value, parsed.valid


# The fused kernels' contract IS the typeconv contract (css, offset, length),
# so their oracles are the typeconv parsers verbatim.

def parse_int_fields_fused(css, offsets, lengths, width):
    parsed = typeconv.parse_int(css, offsets, lengths, width=width)
    return parsed.value, parsed.valid


def parse_float_fields_fused(css, offsets, lengths, width):
    parsed = typeconv.parse_float(css, offsets, lengths, width=width)
    return parsed.value, parsed.valid


def parse_date_fields_fused(css, offsets, lengths):
    parsed = typeconv.parse_date(css, offsets, lengths)
    return parsed.value, parsed.valid
