"""Pure-jnp oracle for numparse — delegates to repro.core.typeconv."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import typeconv


def parse_int_fields(field_bytes, lengths):
    """Same contract as the kernel: gathered (R, W) bytes + lengths."""
    r, w = field_bytes.shape
    # Reconstruct a css/offset view: fields are the rows themselves.
    css = field_bytes.reshape(-1)
    offsets = jnp.arange(r, dtype=jnp.int32) * w
    parsed = typeconv.parse_int(css, offsets, lengths, width=w)
    return parsed.value, parsed.valid
