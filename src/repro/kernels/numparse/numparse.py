"""Pallas TPU kernels for typed field conversion (paper §3.3 type conversion).

Two kernel families share one arithmetic core per dtype (``_int_arith`` /
``_float_arith`` / ``_date_arith`` — all on the VPU, width axis statically
unrolled, W ≤ ~24):

  * ``parse_*_fields``        — unfused: the caller gathers each field's
    bytes out of the CSS with XLA's gather and hands the kernel a dense
    ``(R, W)`` byte matrix.  One grid step processes ``block_rows`` fields.
  * ``parse_*_fields_fused``  — fused gather+convert: the kernel receives
    the CSS itself plus ``(offset, length)`` from the field index and owns
    the indexing (``css[offset[r] + w]`` against the VMEM-resident buffer),
    so no ``(R, W)`` byte matrix ever round-trips through HBM between the
    field index and conversion — the memory-movement fusion the paper's
    device pipeline relies on.  Mosaic lowers the in-kernel index as a
    VMEM dynamic gather, and the CSS block rides whole in VMEM — so on
    real hardware this variant caps the per-parse CSS at VMEM capacity
    (~16 MB/core).  Kept as the mega-field fallback and benchmark
    baseline of the windowed family below.
  * ``parse_*_fields_windowed`` — the scalable default: offsets within a
    column are sorted after the stable partition, so each ``block_rows``
    row block's fields live in ONE contiguous CSS window.  The op layer
    (``ops.plan_css_windows``) precomputes a 128-byte-aligned
    ``window_start`` per grid step plus window-relative offsets, and a
    scalar-prefetched element-offset BlockSpec (``pl.unblocked``) DMAs
    only that static ``window_bytes`` tile into VMEM per step.  The
    in-kernel index then runs over the window, never the whole buffer:
    VMEM footprint is ``O(window_bytes)`` regardless of CSS size, and the
    dynamic gather Mosaic must lower is window-sized — the same locality
    trick GPU decompressors use for coalesced access (Sitaridi et al.,
    arXiv:1606.00519).  Degenerate shapes (a mega-field stretching a
    window past its static tile, or non-monotone offsets that violate the
    sortedness contract) are detected at plan time and the column falls
    back via ``lax.cond`` — to the whole-CSS variant for statically small
    CSS, else to per-row windows (``block_rows=1``, correct for arbitrary
    offsets, still ``O(width)`` VMEM; see ``ops._fused_column``) — so
    correctness never depends on the window invariant, and no compiled
    kernel's VMEM block grows with the CSS.
    Interpret mode (this container) is exact and uncapped either way;
    ``fuse_typeconv=False`` remains the escape hatch that avoids fused
    CSS indexing entirely.

Because both families run the same arithmetic on the same live lanes, they
are bit-identical to each other and to the jnp reference
(``typeconv.parse_int`` / ``parse_float`` / ``parse_date``).  Dead lanes
(beyond ``length``) may differ between families — the unfused gather
pre-masks them to 0, the fused path reads whatever follows the field — but
every dtype's arithmetic either masks on ``lane < length`` itself or never
consumes dead lanes.

Kernels cover every non-string dtype the schema layer knows:

  * int   — sign detection, digit validation, branchless Horner with
    pre-step overflow detection (``acc*10+d > MAX ⇔ acc > (MAX-d)//10`` —
    no wider accumulator needed).
  * float — sign/mantissa/dot/exponent sections with statically-unrolled
    masked Horner, mirroring ``typeconv.parse_float`` op-for-op.
  * date  — per-lane digit/separator validation (including days-in-month
    and time-range semantics) + Hinnant days-from-civil, mirroring
    ``typeconv.parse_date``.

This is the thread-exclusive collaboration level of the paper; the skew-
robust fallback (segmented-scan Horner over the raw CSS) lives in
``repro.core.typeconv.parse_int_segmented``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import typeconv as typeconv_mod

DEFAULT_BLOCK_ROWS = 512
#: Gather width for date fields — ``YYYY-MM-DD HH:MM:SS`` is exactly 19 bytes.
DATE_WIDTH = 19
#: CSS window starts are aligned down to this many bytes (the TPU lane
#: count) so the windowed BlockSpec DMA is lane-aligned on real hardware;
#: window tiles are sized in multiples of it.
WINDOW_ALIGN = 128
_ZERO = ord("0")
# Plain Python int: pallas kernels may not capture traced module constants.
_I32_MAX = typeconv_mod.INT32_MAX


# ---------------------------------------------------------------------------
# per-dtype arithmetic (shared by the unfused and fused kernels)
# ---------------------------------------------------------------------------

def _int_arith(b, ln, block_rows: int, width: int):
    """``(BR, W) int32`` field bytes + ``(BR,) int32`` lengths →
    ``(value (BR,) int32, ok (BR,) bool)``.  Only lanes ``< ln`` are read."""
    first = b[:, 0]
    neg = first == ord("-")
    has_sign = neg | (first == ord("+"))
    sign = jnp.where(neg, -1, 1)

    acc = jnp.zeros((block_rows,), jnp.int32)
    bad = jnp.zeros((block_rows,), jnp.bool_)
    ndig = jnp.zeros((block_rows,), jnp.int32)
    for w in range(width):
        d = b[:, w] - _ZERO
        # lane w is a live digit if it is inside the field and not the sign
        live = (w < ln) & ~(has_sign & (w == 0))
        is_digit = (d >= 0) & (d <= 9)
        bad |= live & ~is_digit
        use = live & is_digit
        # magnitude overflow: acc*10+d would exceed INT32_MAX
        bad |= use & (acc > (_I32_MAX - d) // 10)
        acc = jnp.where(use, acc * 10 + d, acc)
        ndig += use.astype(jnp.int32)

    ok = ~bad & (ndig > 0) & (ln <= width)
    return sign * acc, ok


def _float_arith(raw, ln, block_rows: int, width: int):
    """Masked float32 parse over ``(BR, W) int32`` bytes — mirrors
    ``typeconv.parse_float`` operation-for-operation."""
    br, w = block_rows, width
    lane = jax.lax.broadcasted_iota(jnp.int32, (br, w), 1)
    m = lane < ln[:, None]
    raw = jnp.where(m, raw, 0)

    # Optional leading sign: shift the lane window left by one where
    # present (same trick as typeconv._sign_and_digits).
    first = raw[:, 0]
    has_sign = (first == ord("-")) | (first == ord("+"))
    sign = jnp.where(first == ord("-"), -1, 1).astype(jnp.int32)
    shifted = jnp.concatenate(
        [raw[:, 1:], jnp.zeros((br, 1), jnp.int32)], axis=1)
    shifted_m = jnp.concatenate(
        [m[:, 1:], jnp.zeros((br, 1), jnp.bool_)], axis=1)
    b = jnp.where(has_sign[:, None], shifted, raw)
    bm = jnp.where(has_sign[:, None], shifted_m, m)

    is_dot = (b == ord(".")) & bm
    is_e = ((b == ord("e")) | (b == ord("E"))) & bm
    dot_pos = jnp.min(jnp.where(is_dot, lane, w), axis=1)   # (BR,)
    e_pos = jnp.min(jnp.where(is_e, lane, w), axis=1)

    d = b - _ZERO
    is_digit = (d >= 0) & (d <= 9)

    in_mant = bm & (lane < e_pos[:, None])
    mant_digit = in_mant & ~is_dot
    ok = (jnp.sum(is_dot, axis=1) <= 1) & ((dot_pos <= e_pos) | (dot_pos >= w))
    ok &= jnp.all(is_digit | ~mant_digit, axis=1)
    ok &= jnp.any(mant_digit & is_digit, axis=1)

    # Mantissa Horner, statically unrolled over the width.
    active = mant_digit & is_digit
    dm = jnp.where(active, d, 0).astype(jnp.float32)
    macc = jnp.zeros((br,), jnp.float32)
    for k in range(w):
        macc = jnp.where(active[:, k], macc * 10.0 + dm[:, k], macc)
    frac_digits = jnp.sum(active & (lane > dot_pos[:, None]), axis=1)

    # Exponent section.
    after_e = bm & (lane > e_pos[:, None])
    e_sign_lane = jnp.clip(e_pos + 1, 0, w - 1)
    e_first = jnp.sum(jnp.where(lane == e_sign_lane[:, None], b, 0), axis=1)
    has_e = e_pos < w
    e_neg = has_e & (e_first == ord("-"))
    e_signed = has_e & ((e_first == ord("-")) | (e_first == ord("+")))
    exp_digit = after_e & (lane > (e_pos + jnp.where(e_signed, 1, 0))[:, None])
    ok &= jnp.all(is_digit | ~exp_digit, axis=1)
    ok &= ~has_e | jnp.any(exp_digit, axis=1)
    de = jnp.where(exp_digit & is_digit, d, 0)
    eacc = jnp.zeros((br,), jnp.int32)
    for k in range(w):
        eacc = jnp.where(exp_digit[:, k], eacc * 10 + de[:, k], eacc)

    exp = jnp.where(e_neg, -eacc, eacc) - frac_digits
    value = (sign.astype(jnp.float32) * macc *
             jnp.power(jnp.float32(10.0), exp.astype(jnp.float32)))
    ok &= ln <= w
    return value, ok


def _date_arith(raw, ln, block_rows: int):
    """``YYYY-MM-DD[ HH:MM:SS]`` over ``(BR, 19) int32`` bytes — mirrors
    ``typeconv.parse_date`` (civil-calendar + time-range validation)."""
    br, w = block_rows, DATE_WIDTH
    lane = jax.lax.broadcasted_iota(jnp.int32, (br, w), 1)
    raw = jnp.where(lane < ln[:, None], raw, 0)
    d = raw - _ZERO

    def num(*lanes):
        acc = jnp.zeros((br,), jnp.int32)
        for k in lanes:
            acc = acc * 10 + d[:, k]
        return acc

    year, mon, day = num(0, 1, 2, 3), num(5, 6), num(8, 9)
    has_time = ln >= 19
    hh = jnp.where(has_time, num(11, 12), 0)
    mm = jnp.where(has_time, num(14, 15), 0)
    ss = jnp.where(has_time, num(17, 18), 0)

    dd = (d >= 0) & (d <= 9)
    ok = (dd[:, 0] & dd[:, 1] & dd[:, 2] & dd[:, 3] &
          dd[:, 5] & dd[:, 6] & dd[:, 8] & dd[:, 9])
    ok &= (raw[:, 4] == ord("-")) & (raw[:, 7] == ord("-"))
    ok &= (ln == 10) | (ln == 19)
    time_ok = (dd[:, 11] & dd[:, 12] & dd[:, 14] & dd[:, 15] &
               dd[:, 17] & dd[:, 18] &
               (raw[:, 13] == ord(":")) & (raw[:, 16] == ord(":")) &
               ((raw[:, 10] == ord(" ")) | (raw[:, 10] == ord("T"))))
    ok &= jnp.where(has_time, time_ok, True)
    ok &= ((mon >= 1) & (mon <= 12) & (day >= 1) &
           (day <= typeconv_mod._days_in_month(year, mon)))
    ok &= jnp.where(has_time, (hh <= 23) & (mm <= 59) & (ss <= 59), True)

    secs = (typeconv_mod._days_from_civil(year, mon, day) * 86400 +
            hh * 3600 + mm * 60 + ss)
    return secs, ok


def _make_int_kernel(block_rows: int, width: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        b = bytes_ref[...].astype(jnp.int32)       # (BR, W)
        ln = len_ref[...][:, 0]                     # (BR,)
        val, ok = _int_arith(b, ln, block_rows, width)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_float_kernel(block_rows: int, width: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        raw = bytes_ref[...].astype(jnp.int32)      # (BR, W)
        ln = len_ref[...][:, 0]                      # (BR,)
        val, ok = _float_arith(raw, ln, block_rows, width)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_date_kernel(block_rows: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        raw = bytes_ref[...].astype(jnp.int32)      # (BR, 19)
        ln = len_ref[...][:, 0]                      # (BR,)
        val, ok = _date_arith(raw, ln, block_rows)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


# ---------------------------------------------------------------------------
# fused gather+convert kernels: index the CSS inside the kernel block
# ---------------------------------------------------------------------------

def _make_fused_kernel(arith, block_rows: int, width: int):
    """Wrap a per-dtype arithmetic in the in-kernel CSS gather.

    ``arith(b (BR, W) int32, ln (BR,)) -> (val, ok)``.  The CSS arrives
    width-padded (see ``_fused_call``) so every ``offset + w`` index is in
    range without per-lane clamping.
    """

    def kernel(css_ref, off_ref, len_ref, val_ref, ok_ref):
        css = css_ref[...][0]                       # (NP,) uint8, VMEM-resident
        offs = off_ref[...][:, 0]                   # (BR,)
        ln = len_ref[...][:, 0]                     # (BR,)
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
        b = css[offs[:, None] + lane].astype(jnp.int32)   # in-kernel gather
        val, ok = arith(b, ln)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_windowed_kernel(arith, block_rows: int, width: int):
    """Wrap a per-dtype arithmetic in the windowed in-kernel CSS gather.

    Identical arithmetic to the fused kernel, but the first input ref holds
    only this grid step's ``(1, window_bytes)`` CSS window (selected by the
    scalar-prefetched element-offset BlockSpec) and the offsets arrive
    window-relative, pre-clamped by the op layer to ``[0, WT - width]`` so
    ``rel + w`` never leaves the tile.
    """

    def kernel(win_start_ref, win_ref, off_ref, len_ref, val_ref, ok_ref):
        del win_start_ref  # consumed by the BlockSpec index_map only
        win = win_ref[...][0]                      # (WT,) uint8 window
        offs = off_ref[...][:, 0]                  # (BR,) window-relative
        ln = len_ref[...][:, 0]                    # (BR,)
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
        b = win[offs[:, None] + lane].astype(jnp.int32)  # window-sized gather
        val, ok = arith(b, ln)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


# ---------------------------------------------------------------------------
# pallas_call plumbing (shared by all kernels)
# ---------------------------------------------------------------------------

def _call_rowwise(kernel, field_bytes, lengths, block_rows, val_dtype, interpret):
    r, w = field_bytes.shape
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    val, ok = pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(field_bytes, lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def _fused_call(arith, css, offsets, lengths, width, block_rows, val_dtype,
                interpret):
    n = css.shape[0]
    r = offsets.shape[0]
    if r == 0:  # degenerate but public: no fields to convert
        return jnp.zeros((0,), val_dtype), jnp.zeros((0,), bool)
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    # Width-pad the CSS so offset + lane never indexes past the buffer
    # (offsets of empty/padding rows are clamped to [0, n]); O(W), not an
    # N- or R·W-sized materialisation.
    css_p = jnp.concatenate([css, jnp.zeros((width,), css.dtype)])[None, :]
    offs = jnp.clip(offsets.astype(jnp.int32), 0, n)
    np_ = n + width
    kernel = _make_fused_kernel(arith, br, width)
    val, ok = pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(css_p, offs[:, None], lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def _windowed_call(arith, css, rel_off, lengths, win_start, width, block_rows,
                   window_bytes, val_dtype, interpret):
    """Run a windowed kernel over pre-planned windows.

    ``rel_off``/``lengths`` are ``(R,)`` with ``R`` a multiple of
    ``block_rows``; ``win_start`` is ``(R // block_rows,)`` element offsets
    (multiples of :data:`WINDOW_ALIGN`) from :func:`ops.plan_css_windows`.
    The CSS is tile-padded so every ``win_start + window_bytes`` slice is in
    range; each grid step DMAs exactly one ``(1, window_bytes)`` tile.
    """
    r = rel_off.shape[0]
    br = block_rows
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    css_p = jnp.concatenate([css, jnp.zeros((window_bytes,), css.dtype)])[None, :]
    kernel = _make_windowed_kernel(arith, br, width)
    val, ok = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r // br,),
            in_specs=[
                # element-offset (unblocked) window: start = win_start[i]
                pl.BlockSpec((1, window_bytes), lambda i, ws: (0, ws[i]),
                             indexing_mode=pl.unblocked),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(win_start.astype(jnp.int32), css_p, rel_off.astype(jnp.int32)[:, None],
      lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def parse_int_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, W) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(value (R,) int32, ok (R,) bool)``."""
    r, w = field_bytes.shape
    kernel = _make_int_kernel(min(block_rows, r), w)
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.int32, interpret)


def parse_float_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, W) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(value (R,) float32, ok (R,) bool)`` — bit-identical to
    ``typeconv.parse_float`` on every field."""
    r, w = field_bytes.shape
    kernel = _make_float_kernel(min(block_rows, r), w)
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.float32, interpret)


def parse_date_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, 19) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(epoch_secs (R,) int32, ok (R,) bool)`` — bit-identical to
    ``typeconv.parse_date`` on every field."""
    r, w = field_bytes.shape
    if w != DATE_WIDTH:
        raise ValueError(f"date fields must be gathered at width {DATE_WIDTH}, got {w}")
    kernel = _make_date_kernel(min(block_rows, r))
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.int32, interpret)


def parse_int_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    width: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(N,) uint8`` CSS + ``(R,) int32`` field offsets/lengths →
    ``(value (R,) int32, ok (R,) bool)`` with the gather inside the kernel."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _int_arith(b, ln, br, width)
    return _fused_call(arith, css, offsets, lengths, width, block_rows,
                       jnp.int32, interpret)


def parse_float_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    width: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Fused float32 twin of ``parse_float_fields`` — bit-identical."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _float_arith(b, ln, br, width)
    return _fused_call(arith, css, offsets, lengths, width, block_rows,
                       jnp.float32, interpret)


def parse_date_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Fused date twin of ``parse_date_fields`` — bit-identical."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _date_arith(b, ln, br)
    return _fused_call(arith, css, offsets, lengths, DATE_WIDTH, block_rows,
                       jnp.int32, interpret)


def parse_int_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    width: int,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_int_fields_fused``: per-block window DMA
    with window-relative offsets (see ``ops.plan_css_windows``)."""
    arith = lambda b, ln: _int_arith(b, ln, block_rows, width)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start, width,
                          block_rows, window_bytes, jnp.int32, interpret)


def parse_float_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    width: int,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_float_fields_fused`` — bit-identical."""
    arith = lambda b, ln: _float_arith(b, ln, block_rows, width)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start, width,
                          block_rows, window_bytes, jnp.float32, interpret)


def parse_date_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_date_fields_fused`` — bit-identical."""
    arith = lambda b, ln: _date_arith(b, ln, block_rows)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start,
                          DATE_WIDTH, block_rows, window_bytes, jnp.int32,
                          interpret)
