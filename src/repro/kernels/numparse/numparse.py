"""Pallas TPU kernel for numeric field conversion (paper §3.3 type conversion).

The memory-irregular step (gathering each field's bytes out of the CSS) is
done by XLA's gather — TPU lanes cannot index HBM per-lane.  What the kernel
owns is the arithmetic hot loop over the gathered ``(R, W)`` byte matrix:
sign detection, digit validation, and branchless Horner accumulation, all on
the VPU with the byte matrix VMEM-resident.  One grid step processes
``block_rows`` fields; the width axis is statically unrolled (W ≤ ~24).

This is the thread-exclusive collaboration level of the paper; the skew-
robust fallback (segmented-scan Horner over the raw CSS) lives in
``repro.core.typeconv.parse_int_segmented``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 512
_ZERO = ord("0")


def _make_int_kernel(block_rows: int, width: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        b = bytes_ref[...].astype(jnp.int32)       # (BR, W)
        ln = len_ref[...][:, 0]                     # (BR,)

        first = b[:, 0]
        neg = first == ord("-")
        has_sign = neg | (first == ord("+"))
        sign = jnp.where(neg, -1, 1)

        acc = jnp.zeros((block_rows,), jnp.int32)
        bad = jnp.zeros((block_rows,), jnp.bool_)
        ndig = jnp.zeros((block_rows,), jnp.int32)
        for w in range(width):
            d = b[:, w] - _ZERO
            # lane w is a live digit if it is inside the field and not the sign
            live = (w < ln) & ~(has_sign & (w == 0))
            is_digit = (d >= 0) & (d <= 9)
            bad |= live & ~is_digit
            use = live & is_digit
            acc = jnp.where(use, acc * 10 + d, acc)
            ndig += use.astype(jnp.int32)

        ok = ~bad & (ndig > 0) & (ln <= width)
        val_ref[...] = (sign * acc)[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def parse_int_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, W) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(value (R,) int32, ok (R,) bool)``."""
    r, w = field_bytes.shape
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    kernel = _make_int_kernel(br, w)
    val, ok = pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(field_bytes, lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)
