"""Pallas TPU kernels for typed field conversion (paper §3.3 type conversion).

Two kernel families share one arithmetic core per dtype (``cores._int_arith``
/ ``cores._float_arith`` / ``cores._date_arith`` — all on the VPU, width
axis statically unrolled, W ≤ ~24; the whole-pipeline megakernel in
``kernels/fused_pipeline`` imports the same cores):

  * ``parse_*_fields``        — unfused: the caller gathers each field's
    bytes out of the CSS with XLA's gather and hands the kernel a dense
    ``(R, W)`` byte matrix.  One grid step processes ``block_rows`` fields.
  * ``parse_*_fields_fused``  — fused gather+convert: the kernel receives
    the CSS itself plus ``(offset, length)`` from the field index and owns
    the indexing (``css[offset[r] + w]`` against the VMEM-resident buffer),
    so no ``(R, W)`` byte matrix ever round-trips through HBM between the
    field index and conversion — the memory-movement fusion the paper's
    device pipeline relies on.  Mosaic lowers the in-kernel index as a
    VMEM dynamic gather, and the CSS block rides whole in VMEM — so on
    real hardware this variant caps the per-parse CSS at VMEM capacity
    (~16 MB/core).  Kept as the mega-field fallback and benchmark
    baseline of the windowed family below.
  * ``parse_*_fields_windowed`` — the scalable default: offsets within a
    column are sorted after the stable partition, so each ``block_rows``
    row block's fields live in ONE contiguous CSS window.  The op layer
    (``ops.plan_css_windows``) precomputes a 128-byte-aligned
    ``window_start`` per grid step plus window-relative offsets, and a
    scalar-prefetched element-offset BlockSpec (``pl.unblocked``) DMAs
    only that static ``window_bytes`` tile into VMEM per step.  The
    in-kernel index then runs over the window, never the whole buffer:
    VMEM footprint is ``O(window_bytes)`` regardless of CSS size, and the
    dynamic gather Mosaic must lower is window-sized — the same locality
    trick GPU decompressors use for coalesced access (Sitaridi et al.,
    arXiv:1606.00519).  Degenerate shapes (a mega-field stretching a
    window past its static tile, or non-monotone offsets that violate the
    sortedness contract) are detected at plan time and the column falls
    back via ``lax.cond`` — to the whole-CSS variant for statically small
    CSS, else to per-row windows (``block_rows=1``, correct for arbitrary
    offsets, still ``O(width)`` VMEM; see ``ops._fused_column``) — so
    correctness never depends on the window invariant, and no compiled
    kernel's VMEM block grows with the CSS.
    Interpret mode (this container) is exact and uncapped either way;
    ``fuse_typeconv=False`` remains the escape hatch that avoids fused
    CSS indexing entirely.

Because both families run the same arithmetic on the same live lanes, they
are bit-identical to each other and to the jnp reference
(``typeconv.parse_int`` / ``parse_float`` / ``parse_date``).  Dead lanes
(beyond ``length``) may differ between families — the unfused gather
pre-masks them to 0, the fused path reads whatever follows the field — but
every dtype's arithmetic either masks on ``lane < length`` itself or never
consumes dead lanes.

Kernels cover every non-string dtype the schema layer knows:

  * int   — sign detection, digit validation, branchless Horner with
    pre-step overflow detection (``acc*10+d > MAX ⇔ acc > (MAX-d)//10`` —
    no wider accumulator needed).
  * float — sign/mantissa/dot/exponent sections with statically-unrolled
    masked Horner, mirroring ``typeconv.parse_float`` op-for-op.
  * date  — per-lane digit/separator validation (including days-in-month
    and time-range semantics) + Hinnant days-from-civil, mirroring
    ``typeconv.parse_date``.

This is the thread-exclusive collaboration level of the paper; the skew-
robust fallback (segmented-scan Horner over the raw CSS) lives in
``repro.core.typeconv.parse_int_segmented``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared per-dtype arithmetic + constants live in ``cores`` (one definition
# for the unfused / fused / windowed families here AND the whole-pipeline
# megakernel); re-exported under the historical names for callers that
# import them from this module.
from repro.kernels.numparse.cores import (  # noqa: F401
    DATE_WIDTH,
    DEFAULT_BLOCK_ROWS,
    WINDOW_ALIGN,
    _date_arith,
    _float_arith,
    _int_arith,
)


def _make_int_kernel(block_rows: int, width: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        b = bytes_ref[...].astype(jnp.int32)       # (BR, W)
        ln = len_ref[...][:, 0]                     # (BR,)
        val, ok = _int_arith(b, ln, block_rows, width)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_float_kernel(block_rows: int, width: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        raw = bytes_ref[...].astype(jnp.int32)      # (BR, W)
        ln = len_ref[...][:, 0]                      # (BR,)
        val, ok = _float_arith(raw, ln, block_rows, width)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_date_kernel(block_rows: int):
    def kernel(bytes_ref, len_ref, val_ref, ok_ref):
        raw = bytes_ref[...].astype(jnp.int32)      # (BR, 19)
        ln = len_ref[...][:, 0]                      # (BR,)
        val, ok = _date_arith(raw, ln, block_rows)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


# ---------------------------------------------------------------------------
# fused gather+convert kernels: index the CSS inside the kernel block
# ---------------------------------------------------------------------------

def _make_fused_kernel(arith, block_rows: int, width: int):
    """Wrap a per-dtype arithmetic in the in-kernel CSS gather.

    ``arith(b (BR, W) int32, ln (BR,)) -> (val, ok)``.  The CSS arrives
    width-padded (see ``_fused_call``) so every ``offset + w`` index is in
    range without per-lane clamping.
    """

    def kernel(css_ref, off_ref, len_ref, val_ref, ok_ref):
        css = css_ref[...][0]                       # (NP,) uint8, VMEM-resident
        offs = off_ref[...][:, 0]                   # (BR,)
        ln = len_ref[...][:, 0]                     # (BR,)
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
        b = css[offs[:, None] + lane].astype(jnp.int32)   # in-kernel gather
        val, ok = arith(b, ln)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


def _make_windowed_kernel(arith, block_rows: int, width: int):
    """Wrap a per-dtype arithmetic in the windowed in-kernel CSS gather.

    Identical arithmetic to the fused kernel, but the first input ref holds
    only this grid step's ``(1, window_bytes)`` CSS window (selected by the
    scalar-prefetched element-offset BlockSpec) and the offsets arrive
    window-relative, pre-clamped by the op layer to ``[0, WT - width]`` so
    ``rel + w`` never leaves the tile.
    """

    def kernel(win_start_ref, win_ref, off_ref, len_ref, val_ref, ok_ref):
        del win_start_ref  # consumed by the BlockSpec index_map only
        win = win_ref[...][0]                      # (WT,) uint8 window
        offs = off_ref[...][:, 0]                  # (BR,) window-relative
        ln = len_ref[...][:, 0]                    # (BR,)
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
        b = win[offs[:, None] + lane].astype(jnp.int32)  # window-sized gather
        val, ok = arith(b, ln)
        val_ref[...] = val[:, None]
        ok_ref[...] = ok.astype(jnp.int32)[:, None]

    return kernel


# ---------------------------------------------------------------------------
# pallas_call plumbing (shared by all kernels)
# ---------------------------------------------------------------------------

def _call_rowwise(kernel, field_bytes, lengths, block_rows, val_dtype, interpret):
    r, w = field_bytes.shape
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    val, ok = pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(field_bytes, lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def _fused_call(arith, css, offsets, lengths, width, block_rows, val_dtype,
                interpret):
    n = css.shape[0]
    r = offsets.shape[0]
    if r == 0:  # degenerate but public: no fields to convert
        return jnp.zeros((0,), val_dtype), jnp.zeros((0,), bool)
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    # Width-pad the CSS so offset + lane never indexes past the buffer
    # (offsets of empty/padding rows are clamped to [0, n]); O(W), not an
    # N- or R·W-sized materialisation.
    css_p = jnp.concatenate([css, jnp.zeros((width,), css.dtype)])[None, :]
    offs = jnp.clip(offsets.astype(jnp.int32), 0, n)
    np_ = n + width
    kernel = _make_fused_kernel(arith, br, width)
    val, ok = pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(css_p, offs[:, None], lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def _windowed_call(arith, css, rel_off, lengths, win_start, width, block_rows,
                   window_bytes, val_dtype, interpret):
    """Run a windowed kernel over pre-planned windows.

    ``rel_off``/``lengths`` are ``(R,)`` with ``R`` a multiple of
    ``block_rows``; ``win_start`` is ``(R // block_rows,)`` element offsets
    (multiples of :data:`WINDOW_ALIGN`) from :func:`ops.plan_css_windows`.
    The CSS is tile-padded so every ``win_start + window_bytes`` slice is in
    range; each grid step DMAs exactly one ``(1, window_bytes)`` tile.
    """
    r = rel_off.shape[0]
    br = block_rows
    if r % br:
        raise ValueError(f"rows {r} not a multiple of block_rows {br}")
    css_p = jnp.concatenate([css, jnp.zeros((window_bytes,), css.dtype)])[None, :]
    kernel = _make_windowed_kernel(arith, br, width)
    val, ok = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r // br,),
            in_specs=[
                # element-offset (unblocked) window: start = win_start[i]
                pl.BlockSpec((1, window_bytes), lambda i, ws: (0, ws[i]),
                             indexing_mode=pl.unblocked),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
                pl.BlockSpec((br, 1), lambda i, ws: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), val_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(win_start.astype(jnp.int32), css_p, rel_off.astype(jnp.int32)[:, None],
      lengths.astype(jnp.int32)[:, None])
    return val[:, 0], ok[:, 0].astype(bool)


def parse_int_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, W) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(value (R,) int32, ok (R,) bool)``."""
    r, w = field_bytes.shape
    kernel = _make_int_kernel(min(block_rows, r), w)
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.int32, interpret)


def parse_float_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, W) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(value (R,) float32, ok (R,) bool)`` — bit-identical to
    ``typeconv.parse_float`` on every field."""
    r, w = field_bytes.shape
    kernel = _make_float_kernel(min(block_rows, r), w)
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.float32, interpret)


def parse_date_fields(
    field_bytes: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(R, 19) uint8`` gathered field bytes + ``(R,) int32`` lengths →
    ``(epoch_secs (R,) int32, ok (R,) bool)`` — bit-identical to
    ``typeconv.parse_date`` on every field."""
    r, w = field_bytes.shape
    if w != DATE_WIDTH:
        raise ValueError(f"date fields must be gathered at width {DATE_WIDTH}, got {w}")
    kernel = _make_date_kernel(min(block_rows, r))
    return _call_rowwise(kernel, field_bytes, lengths, block_rows,
                         jnp.int32, interpret)


def parse_int_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    width: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """``(N,) uint8`` CSS + ``(R,) int32`` field offsets/lengths →
    ``(value (R,) int32, ok (R,) bool)`` with the gather inside the kernel."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _int_arith(b, ln, br, width)
    return _fused_call(arith, css, offsets, lengths, width, block_rows,
                       jnp.int32, interpret)


def parse_float_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    width: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Fused float32 twin of ``parse_float_fields`` — bit-identical."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _float_arith(b, ln, br, width)
    return _fused_call(arith, css, offsets, lengths, width, block_rows,
                       jnp.float32, interpret)


def parse_date_fields_fused(
    css: jax.Array,
    offsets: jax.Array,
    lengths: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Fused date twin of ``parse_date_fields`` — bit-identical."""
    r = offsets.shape[0]
    br = min(block_rows, r)
    arith = lambda b, ln: _date_arith(b, ln, br)
    return _fused_call(arith, css, offsets, lengths, DATE_WIDTH, block_rows,
                       jnp.int32, interpret)


def parse_int_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    width: int,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_int_fields_fused``: per-block window DMA
    with window-relative offsets (see ``ops.plan_css_windows``)."""
    arith = lambda b, ln: _int_arith(b, ln, block_rows, width)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start, width,
                          block_rows, window_bytes, jnp.int32, interpret)


def parse_float_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    width: int,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_float_fields_fused`` — bit-identical."""
    arith = lambda b, ln: _float_arith(b, ln, block_rows, width)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start, width,
                          block_rows, window_bytes, jnp.float32, interpret)


def parse_date_fields_windowed(
    css: jax.Array,
    rel_offsets: jax.Array,
    lengths: jax.Array,
    win_start: jax.Array,
    *,
    block_rows: int,
    window_bytes: int,
    interpret: bool = True,
):
    """Windowed twin of ``parse_date_fields_fused`` — bit-identical."""
    arith = lambda b, ln: _date_arith(b, ln, block_rows)
    return _windowed_call(arith, css, rel_offsets, lengths, win_start,
                          DATE_WIDTH, block_rows, window_bytes, jnp.int32,
                          interpret)
