"""Op layer for the whole-pipeline megakernel: plan-shaped in, typed
columns out.

``fused_parse`` adapts a :class:`repro.core.stages.MaterializePlan`-shaped
argument set onto :func:`fused_pipeline.pipeline_call` and finishes the
*products* that need no ``(N,)`` data — ``Parsed`` normalisation (identical
to the staged composition: ``valid = ok & ~empty``, invalid numerics
zeroed) and the ``str`` no-op columns, whose ``Parsed`` is pure field-index
bookkeeping (``typeconv.parse_string_noop``).  Everything upstream of the
kernel is the §3.1 composite scan, which is O(C·S); everything downstream
is O(max_records) or scalar — the backend executor
(``core.backends._pl_execute``) composes both ends.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import typeconv as typeconv_mod
from repro.core.dfa import Dfa
from repro.kernels.fused_pipeline import fused_pipeline
from repro.kernels.numparse.cores import DATE_WIDTH


class FusedParse(NamedTuple):
    """The megakernel's per-partition products (no ``(N,)`` round-trips)."""

    css: jax.Array             # (N,) uint8 partitioned symbols
    col_start: jax.Array       # (n_cols+1,) int32
    col_count: jax.Array       # (n_cols+1,) int32
    offset: jax.Array          # (n_cols, max_records) int32
    length: jax.Array          # (n_cols, max_records) int32
    present: jax.Array         # (n_cols, max_records) bool
    fields_per_rec: jax.Array  # (max_records,) int32 — §4.3 column counts
    end_state: jax.Array       # () int32
    saw_invalid: jax.Array     # () bool — any chunk hit the invalid sink
    last_record_end: jax.Array # () int32 — §4.4 carry boundary (−1 if none)
    n_records: jax.Array       # () int32
    values: Dict[str, typeconv_mod.Parsed]


def _width_for(dtype: str, int_width: int, float_width: int) -> int:
    if dtype == "int32":
        return int_width
    if dtype == "float32":
        return float_width
    return DATE_WIDTH


def fused_parse(
    chunks: jax.Array,
    start_states: jax.Array,
    dfa: Dfa,
    *,
    tagging: str,
    n_cols: int,
    max_records: int,
    selected,
    convert: Tuple[Tuple[str, int, str], ...],
    int_width: int,
    float_width: int,
    col_seed=None,
    interpret: bool = True,
) -> FusedParse:
    """One partition through the megakernel (see module docstring).

    ``convert`` is the plan's ``(name, col_idx, dtype)`` tuple — ``str``
    entries are served from the field index outside the kernel; the rest
    convert in-kernel through the shared numparse cores.  ``col_seed`` is
    the distributed stitch's cross-shard column offset (see
    ``fused_pipeline.pipeline_call``).
    """
    kconv = tuple(
        (c, dtype, _width_for(dtype, int_width, float_width))
        for _, c, dtype in convert if dtype != "str"
    )
    css, col_start, col_count, off, ln, pres, fpr, meta, kvals = (
        fused_pipeline.pipeline_call(
            chunks, start_states, dfa, tagging=tagging, n_cols=n_cols,
            max_records=max_records, selected=selected, convert=kconv,
            col_seed=col_seed, interpret=interpret,
        )
    )

    values: Dict[str, typeconv_mod.Parsed] = {}
    ki = 0
    for name, c, dtype in convert:
        empty = ln[c] == 0
        if dtype == "str":
            # typeconv.parse_string_noop: value IS the field offset.
            values[name] = typeconv_mod.Parsed(off[c], ~empty, empty)
            continue
        val, ok = kvals[ki]
        ki += 1
        valid = ok & ~empty
        # Same normalisation as stages.materialize: garbage values are
        # meaningless (``valid`` gates them) — zero them so every path
        # agrees bit-for-bit.
        values[name] = typeconv_mod.Parsed(
            jnp.where(valid, val, jnp.zeros_like(val)), valid, empty
        )

    return FusedParse(
        css=css,
        col_start=col_start,
        col_count=col_count,
        offset=off,
        length=ln,
        present=pres,
        fields_per_rec=fpr,
        end_state=meta[0],
        saw_invalid=meta[1].astype(bool),
        last_record_end=meta[2],
        n_records=meta[3],
        values=values,
    )
