"""Whole-pipeline fusion: one Pallas megakernel per partition (replay →
tag → partition → convert) with no HBM round-trips between stages.

Wired into the ``pallas`` backend as its ``ParseBackend.execute`` override
(``core/backends.py``); selected by ``ParserConfig.fuse_pipeline=True`` and
gated behind the backend's static ``fused_max_bytes`` cap — above the cap
``stages.execute_plan`` falls back to the staged kernel composition.
"""
from repro.kernels.fused_pipeline.ops import FusedParse, fused_parse

__all__ = ["FusedParse", "fused_parse"]
