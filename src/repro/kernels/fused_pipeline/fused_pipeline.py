"""Whole-pipeline Pallas megakernel: replay → tag → partition → convert in
one kernel launch per partition (paper §3's device-residency discipline).

The staged pallas path bounces every ``(N,)``-sized intermediate through
HBM between kernels: the replay's class stream, the tag arrays, the
partition's destination map (whose perm-inversion scatter was raw XLA —
``kernels/partition/ops.py``), the field index, and the windowed numparse
gathers.  This kernel keeps all of them VMEM-resident:

  1. **Replay** — the ``dfa_scan`` fori-loop (one-hot select chains over the
     statically unrolled |S|·|G| transition/emission tables) re-simulates
     each chunk from its scan-derived start state, accumulating the class
     stream in a carry instead of an HBM output.
  2. **Ids** — record/column ids via the flat §3.2 cumulative-sum form
     (``offsets.symbol_ids``), bit-identical to the two-level chunk-summary
     form the staged path uses (the forms are cross-checked in tests).
  3. **Tagging** — ``tagging.tag_symbols`` replicated per mode; the
     selected-columns projection unrolls statically over the schema (an
     OR-chain of ``column_id == c`` compares — no gather).
  4. **Partition** — the ``scatter2`` blocked radix pass (per-block uint8
     histograms + intra-block ranks + inter-block scan) computes each
     symbol's destination, and the destination map is consumed in
     *apply-form*: ``out.at[dest].set(payload)`` writes the CSS and the
     sorted tag/flag streams directly — the perm-inversion scatter plus the
     downstream ``apply_partition`` gathers fold into one in-kernel
     scatter (a stable partition's destinations are unique, so the two
     forms are exactly equivalent: ``perm[dest[i]] = i``).
  5. **Field index** — ``fields.field_index_{tagged,terminated}`` replicated
     with in-kernel ``.at[seg].min`` / ``.at[seg].add`` segment reductions
     (the int32 identity of ``min`` is ``INT32_MAX``, matching
     ``segment_min``'s empty-segment fill bit-for-bit).
  6. **Convert** — the shared :mod:`repro.kernels.numparse.cores` arithmetic
     runs per converted column on offsets that never left the kernel, with
     ``block_rows = max_records`` (row-independent arithmetic, so the
     blocking difference vs the staged kernels cannot change results).

Outputs are the pipeline's *products* only — CSS, column extents, field
index, per-column values, per-record field counts, and four scalars — so
nothing ``(N,)``- or ``(R,)``-shaped is ever written to HBM and read back
by a later stage (pinned by ``tests/jaxpr_utils.hbm_roundtrips_outside_pallas``).

Interpret mode (this container) executes every step exactly.  On real
hardware the in-kernel scatters/gathers are Mosaic dynamic VMEM addressing
— the same caveat as the fused numparse gather — and the whole working set
(≈ ``N × ~12 B`` for the class/tag/rank intermediates plus the ``(C, K)``
byte block) must fit VMEM, so the executor gates this path behind a static
byte cap (``ParseBackend.fused_max_bytes``) and falls back to the staged
composition above it; see ``docs/ARCHITECTURE.md`` §fused-pipeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dfa import DATA, FIELD_DELIM, RECORD_DELIM, TERMINATOR_BYTE, Dfa
from repro.kernels.dfa_scan.dfa_scan import _group_select
from repro.kernels.numparse import cores

#: Partition rank-block width: intra-block ranks must fit uint8 (< 256), and
#: 128 matches the TPU lane count (same tiling as ``partition_scatter2``).
RANK_BLOCK = 128
# Plain Python int: pallas kernels may not capture traced module constants.
_I32_MAX = 2**31 - 1


def _make_pipeline_kernel(
    dfa: Dfa,
    n_chunks: int,
    chunk_bytes: int,
    *,
    tagging: str,
    n_cols: int,
    max_records: int,
    selected,
    convert,
):
    """Build the megakernel for one static shape + plan.

    ``convert`` is a tuple of ``(col_idx, dtype, width)`` for the non-str
    columns, in output order; the kernel emits ``(value, ok)`` refs per
    entry after the fixed outputs.
    """
    S, G = dfa.n_states, dfa.n_groups
    group_bytes = dfa.group_bytes
    t_flat = tuple(int(x) for x in dfa.transition.reshape(-1))
    e_flat = tuple(int(x) for x in dfa.emission.reshape(-1))
    inv = dfa.invalid_state
    C, K = n_chunks, chunk_bytes
    N = C * K
    M = max_records
    NB = -(-N // RANK_BLOCK)
    PAD = NB * RANK_BLOCK - N
    n_segs = n_cols * M

    def kernel(chunks_ref, start_ref, seed_ref, css_ref, col_start_ref,
               col_count_ref, off_ref, len_ref, pres_ref, fpr_ref, meta_ref,
               *val_refs):
        raw_u8 = chunks_ref[...].reshape(N)               # (N,) uint8
        data = chunks_ref[...].astype(jnp.int32)          # (C, K)
        state0 = start_ref[...].astype(jnp.int32).reshape(C)
        col_seed = seed_ref[0, 0]                         # () int32

        # -- 1. replay (dfa_scan one-hot select chains, classes in carry) --
        def body(k, carry):
            state, cls_buf = carry
            byte = jax.lax.dynamic_slice(data, (0, k), (C, 1))[:, 0]
            g = _group_select(byte, group_bytes, G)
            idx = state * G + g  # (C,) in [0, S*G)
            new = jnp.zeros_like(state)
            cls = jnp.zeros_like(state)
            for j in range(S * G):
                hit = idx == j
                new = jnp.where(hit, t_flat[j], new)
                cls = jnp.where(hit, e_flat[j], cls)
            cls_buf = jax.lax.dynamic_update_slice(cls_buf, cls[:, None], (0, k))
            return new, cls_buf

        end_states, cls_chunks = jax.lax.fori_loop(
            0, K, body, (state0, jnp.zeros((C, K), jnp.int32))
        )
        end_state = end_states[C - 1]
        if inv is None:
            saw_inv = jnp.int32(0)
        else:  # the invalid sink is absorbing: "ever hit" == "ended there"
            saw_inv = jnp.any(end_states == inv).astype(jnp.int32)

        # -- 2. record/column ids (offsets.symbol_ids, flat form) ----------
        cls = cls_chunks.reshape(N)
        pos = jnp.arange(N, dtype=jnp.int32)
        is_rec = cls == RECORD_DELIM
        is_fld = cls == FIELD_DELIM
        rec_i32 = is_rec.astype(jnp.int32)
        fld_i32 = is_fld.astype(jnp.int32)
        rec_incl = jnp.cumsum(rec_i32)
        record_id = rec_incl - rec_i32
        fld_incl = jnp.cumsum(fld_i32)
        fld_excl = fld_incl - fld_i32
        last_rec_incl = jax.lax.cummax(jnp.where(is_rec, pos, -1))
        last_rec_excl = jnp.concatenate(
            [jnp.full((1,), -1, jnp.int32), last_rec_incl[:-1]]
        )
        base = jnp.where(last_rec_excl >= 0, fld_incl[jnp.clip(last_rec_excl, 0)], 0)
        # Until the partition's own first record delimiter, ids are offset by
        # the cross-shard column seed (offsets.symbol_ids_from_chunks at
        # shard granularity; 0 for single-device callers).
        column_id = fld_excl - base + jnp.where(last_rec_excl < 0, col_seed, 0)
        n_records = jnp.sum(rec_i32)

        # -- 3. tagging (tagging.tag_symbols per mode) ---------------------
        is_data = cls == DATA
        is_delim = is_rec | is_fld
        if tagging == "tagged":
            keep = is_data
            symbol = raw_u8
            flag = None
        elif tagging == "inline":
            keep = is_data | is_delim
            symbol = jnp.where(is_delim, jnp.uint8(TERMINATOR_BYTE), raw_u8)
            flag = is_delim
        else:  # vector
            keep = is_data | is_delim
            symbol = raw_u8
            flag = is_delim
        in_schema = column_id < n_cols
        if selected is not None:
            # §4.3 projection, unrolled statically over the schema — the
            # OR-chain is equivalent to the staged path's clip-gather.
            sel = jnp.zeros((N,), jnp.bool_)
            for c, s in enumerate(selected):
                if s:
                    sel |= column_id == c
            in_schema &= sel
        col_tag = jnp.where(keep & in_schema, column_id, n_cols).astype(jnp.int32)

        # -- 4. stable partition (scatter2 blocked radix pass) -------------
        if PAD:
            tags = jnp.concatenate(
                [col_tag, jnp.full((PAD,), n_cols, jnp.int32)]
            )
        else:
            tags = col_tag
        tags2 = tags.reshape(NB, RANK_BLOCK)
        colsv = jnp.arange(n_cols + 1, dtype=jnp.int32)
        onehot8 = (tags2[:, :, None] == colsv[None, None, :]).astype(jnp.uint8)
        block_hist = onehot8.sum(axis=1, dtype=jnp.int32)        # (NB, C+1)
        ranks8 = jnp.cumsum(onehot8, axis=1, dtype=jnp.uint8)    # inclusive
        own_rank = jnp.take_along_axis(
            ranks8, tags2[:, :, None], axis=2
        )[:, :, 0].astype(jnp.int32) - 1                         # exclusive
        blk_excl = jnp.cumsum(block_hist, axis=0) - block_hist
        count = block_hist.sum(axis=0)
        col_start = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(count)[:-1]]
        )
        count = count.at[-1].add(-PAD)
        dest = (col_start[tags2] + jnp.take_along_axis(blk_excl, tags2, axis=1)
                + own_rank).reshape(-1)[:N]

        # Apply-form consumption of the destination map: a stable
        # partition's dest is a bijection on [0, N), so scattering payloads
        # to dest IS apply_partition(perm, payload) with perm[dest[i]] = i —
        # the XLA perm-inversion scatter and the downstream gathers fold
        # into these writes.
        css = jnp.zeros((N,), jnp.uint8).at[dest].set(symbol)
        rec_sorted = jnp.zeros((N,), jnp.int32).at[dest].set(record_id)
        col_sorted = jnp.zeros((N,), jnp.int32).at[dest].set(col_tag)

        # -- 5. field index (fields.field_index_{tagged,terminated}) -------
        in_range = (col_sorted < n_cols) & (rec_sorted < M)
        if tagging == "tagged":
            seg = jnp.where(in_range, col_sorted * M + rec_sorted, n_segs)
            offset = jnp.full((n_segs + 1,), _I32_MAX, jnp.int32
                              ).at[seg].min(pos)[:-1]
            length = jnp.zeros((n_segs + 1,), jnp.int32).at[seg].add(1)[:-1]
            present = (length > 0).reshape(n_cols, M)
            offset = jnp.where(length > 0, offset, 0).reshape(n_cols, M)
            length = length.reshape(n_cols, M)
        else:
            flag_sorted = jnp.zeros((N,), jnp.bool_).at[dest].set(flag)
            valid_t = flag_sorted & in_range
            seg = jnp.where(valid_t, col_sorted * M + rec_sorted, n_segs)
            end = jnp.full((n_segs + 1,), _I32_MAX, jnp.int32).at[seg].min(
                jnp.where(valid_t, pos, _I32_MAX)
            )[:-1].reshape(n_cols, M)
            present = end < _I32_MAX
            # Same absent-tolerant predecessor recurrence as
            # fields.field_index_terminated: start after the last *present*
            # terminator (exclusive running max), the column start when
            # none precedes.
            prev_end = jax.lax.cummax(jnp.where(present, end, -1), axis=1)
            prev_end = jnp.concatenate(
                [jnp.full((n_cols, 1), -1, jnp.int32), prev_end[:, :-1]],
                axis=1,
            )
            start_f = jnp.where(
                prev_end >= 0, prev_end + 1, col_start[:n_cols, None]
            )
            length = jnp.where(present, end - start_f, 0).astype(jnp.int32)
            offset = jnp.where(present, start_f, 0).astype(jnp.int32)

        # -- 6. typed conversion (shared numparse cores, in-kernel offsets) -
        for i, (c, dtype, width) in enumerate(convert):
            # Width-pad so offset + lane never leaves the buffer (offsets of
            # empty/padding rows clamp to [0, N]) — same contract as the
            # staged fused kernels (numparse._fused_call).
            css_pad = jnp.concatenate([css, jnp.zeros((width,), jnp.uint8)])
            off_c = jnp.clip(offset[c], 0, N)
            ln_c = length[c]
            lane = jax.lax.broadcasted_iota(jnp.int32, (M, width), 1)
            b = css_pad[off_c[:, None] + lane].astype(jnp.int32)
            if dtype == "int32":
                val, ok = cores._int_arith(b, ln_c, M, width)
            elif dtype == "float32":
                val, ok = cores._float_arith(b, ln_c, M, width)
            else:  # date
                val, ok = cores._date_arith(b, ln_c, M)
            val_refs[2 * i][...] = val[None, :]
            val_refs[2 * i + 1][...] = ok.astype(jnp.int32)[None, :]

        # -- §4.3 validation inputs + §4.4 carry scalars -------------------
        # The head record's column count includes the cross-shard seed (its
        # leading fields live on predecessor shards; seed is 0 single-device).
        rid = jnp.where(record_id < M, record_id, M)
        fpr = (jnp.zeros((M + 1,), jnp.int32).at[rid].add(fld_i32)[:-1] + 1
               ).at[0].add(col_seed)
        last_record_end = jnp.max(jnp.where(is_rec, pos, -1))

        css_ref[...] = css[None, :]
        col_start_ref[...] = col_start[None, :]
        col_count_ref[...] = count[None, :]
        off_ref[...] = offset
        len_ref[...] = length
        pres_ref[...] = present.astype(jnp.int32)
        fpr_ref[...] = fpr[None, :]
        meta_ref[...] = jnp.stack(
            [end_state, saw_inv, last_record_end, n_records]
        ).astype(jnp.int32)[None, :]

    return kernel


def pipeline_call(
    chunks: jax.Array,
    start_states: jax.Array,
    dfa: Dfa,
    *,
    tagging: str,
    n_cols: int,
    max_records: int,
    selected,
    convert,
    col_seed=None,
    interpret: bool = True,
):
    """Run the megakernel over one partition.

    Args:
      chunks: ``(C, K) uint8`` raw bytes.
      start_states: ``(C,) int32`` per-chunk start states (from the §3.1
        composite scan — the only upstream stage; it is O(C·S), never O(N)).
      convert: tuple of ``(col_idx, dtype, width)`` for non-str columns.
      col_seed: ``() int32`` cross-shard column offset entering this
        partition (field delimiters since the last record delimiter before
        it) — the distributed driver's stitch; ``None``/0 single-device.

    Returns ``(css (N,) u8, col_start (n_cols+1,) i32, col_count, offset
    (n_cols, M) i32, length, present (n_cols, M) bool, fields_per_rec (M,)
    i32, meta (4,) i32 [end_state, saw_invalid, last_record_end,
    n_records], values)`` with ``values`` a tuple of ``(value (M,), ok (M,)
    bool)`` per convert entry.
    """
    c, k = chunks.shape
    n = c * k
    m = max_records
    kernel = _make_pipeline_kernel(
        dfa, c, k, tagging=tagging, n_cols=n_cols, max_records=m,
        selected=selected, convert=convert,
    )
    fixed_shapes = [
        jax.ShapeDtypeStruct((1, n), jnp.uint8),           # css
        jax.ShapeDtypeStruct((1, n_cols + 1), jnp.int32),  # col_start
        jax.ShapeDtypeStruct((1, n_cols + 1), jnp.int32),  # col_count
        jax.ShapeDtypeStruct((n_cols, m), jnp.int32),      # field offset
        jax.ShapeDtypeStruct((n_cols, m), jnp.int32),      # field length
        jax.ShapeDtypeStruct((n_cols, m), jnp.int32),      # field present
        jax.ShapeDtypeStruct((1, m), jnp.int32),           # fields_per_rec
        jax.ShapeDtypeStruct((1, 4), jnp.int32),           # meta scalars
    ]
    conv_shapes = []
    for _, dtype, _ in convert:
        vdt = jnp.float32 if dtype == "float32" else jnp.int32
        conv_shapes += [
            jax.ShapeDtypeStruct((1, m), vdt),             # value
            jax.ShapeDtypeStruct((1, m), jnp.int32),       # ok
        ]
    seed = jnp.zeros((), jnp.int32) if col_seed is None else col_seed
    seed = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[full((c, k)), full((c, 1)), full((1, 1))],
        out_specs=[full(s.shape) for s in fixed_shapes + conv_shapes],
        out_shape=fixed_shapes + conv_shapes,
        interpret=interpret,
    )(chunks, start_states.astype(jnp.int32)[:, None], seed)
    css, col_start, col_count, off, ln, pres, fpr, meta = out[:8]
    values = tuple(
        (out[8 + 2 * i][0], out[8 + 2 * i + 1][0].astype(bool))
        for i in range(len(convert))
    )
    return (css[0], col_start[0], col_count[0], off, ln, pres.astype(bool),
            fpr[0], meta[0], values)
