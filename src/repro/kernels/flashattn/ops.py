"""Jit'd wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flashattn import flashattn


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(q, k, v, causal: bool = True, window=None, scale=None,
                    block_q: int = flashattn.DEFAULT_BLOCK_Q,
                    block_kv: int = flashattn.DEFAULT_BLOCK_KV,
                    interpret: bool = True):
    return flashattn.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
