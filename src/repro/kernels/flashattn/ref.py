"""Pure-jnp oracle for flash attention: materialised-scores softmax."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(jnp.isnan(p), 0.0, p)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0.0, 1.0, denom)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
