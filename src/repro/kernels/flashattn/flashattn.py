"""Blockwise online-softmax attention (FlashAttention-style) for TPU.

The model zoo's compute hot-spot: prefill at 32k context would materialise
(S × S) score matrices per head without it.  Grid is
``(batch, q_heads, q_blocks, kv_blocks)`` with the KV axis innermost;
running max / denominator / output accumulator live in VMEM scratch and are
finalised on the last KV block (the standard decomposition: Dao et al.,
arXiv:2205.14135, re-tiled for MXU-aligned 128-lane blocks).

GQA is handled in the index map — KV blocks are fetched from head
``q_head // group`` — so grouped KV is never materialised per q-head.
Supports causal masking and sliding windows (Hymba's local attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
_NEG_INF = -1e30


def _make_kernel(block_q, block_kv, n_kv_blocks, scale, causal, window):
    def kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        kv_idx = pl.program_id(3)
        q_idx = pl.program_id(2)

        @pl.when(kv_idx == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)

        if causal or window is not None:
            q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            mask = jnp.ones((block_q, block_kv), jnp.bool_)
            if causal:
                mask &= q_pos >= k_pos
            if window is not None:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...][:, 0]           # (BQ,)
        l_prev = l_ref[...][:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows (everything -inf) against NaNs.
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= _NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev <= _NEG_INF / 2, 0.0, alpha)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

        @pl.when(kv_idx == n_kv_blocks - 1)
        def _finalise():
            l = l_ref[...][:, 0]
            denom = jnp.where(l == 0.0, 1.0, l)
            o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)

    return kernel


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
) -> jax.Array:
    """``q (B, Hq, Sq, D)``, ``k/v (B, Hkv, Skv, D)`` → ``(B, Hq, Sq, D)``.

    ``Hq`` must be a multiple of ``Hkv`` (GQA); sequence lengths must be
    multiples of the block sizes (callers pad — masked tail rows produce
    zeros, not NaNs).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_kv_blocks = skv // bk
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    kernel = _make_kernel(bq, bk, n_kv_blocks, scale, causal, window)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
