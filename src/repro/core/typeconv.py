"""Type conversion: CSS symbol strings → typed columnar values (paper §3.3).

The paper assigns one GPU thread per field and escalates to block-/device-
level collaboration for long fields.  The TPU adaptation (DESIGN.md §3):

  * ``gather``      — fixed-width path: every field gathers up to ``W`` bytes
    and parses them with branchless vector arithmetic.  The analogue of
    thread-exclusive conversion; padding waste replaces warp divergence.
  * ``segmented``   — the collaboration analogue: digit accumulation is the
    associative semigroup ``(v_a, n_a) ⊕ (v_b, n_b) = (v_a·10^n_b + v_b,
    n_a + n_b)`` with field-boundary resets, so one segmented
    ``associative_scan`` over the whole CSS parses *all* integer fields of a
    column at once, regardless of individual field length — no padding, no
    skew sensitivity (exactly what block/device collaboration bought the
    paper).

Floats parse sign / integer / fraction / exponent sections with masked
Horner accumulation; dates use the days-from-civil algorithm (pure integer
arithmetic, fully vectorised).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_ZERO = ord("0")
_POW10_I32 = jnp.array([10**k for k in range(10)], jnp.int32)
#: Magnitude cap for int32 parses.  The cap is symmetric (|v| ≤ 2**31-1 for
#: either sign) so sign handling stays branchless; ``-2147483648`` — the one
#: value a sign-aware cap would additionally admit — is rejected as overflow.
INT32_MAX = 2**31 - 1
_I32_MAX = jnp.int32(INT32_MAX)


class Parsed(NamedTuple):
    value: jax.Array  # (R,) parsed values
    valid: jax.Array  # (R,) bool — parse succeeded on a present, non-empty field
    empty: jax.Array  # (R,) bool — zero-length field (NULL → default)


def gather_field_bytes(css: jax.Array, offset: jax.Array, length: jax.Array, width: int):
    """Gather each field's first ``width`` bytes: ``(R, W) uint8`` + mask.

    Out-of-range lanes read clamped positions and are masked to 0.
    """
    n = css.shape[0]
    idx = offset[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    mask = jnp.arange(width, dtype=jnp.int32)[None, :] < length[:, None]
    data = css[jnp.clip(idx, 0, n - 1)]
    return jnp.where(mask, data, 0), mask


def _sign_and_digits(bytes_w, mask):
    """Split optional leading sign; returns (sign ±1, digit bytes, digit mask)."""
    first = bytes_w[:, 0]
    has_sign = (first == ord("-")) | (first == ord("+"))
    sign = jnp.where(first == ord("-"), -1, 1).astype(jnp.int32)
    # Shift left by one where a sign is present.
    shifted = jnp.concatenate([bytes_w[:, 1:], jnp.zeros_like(bytes_w[:, :1])], axis=1)
    shifted_m = jnp.concatenate([mask[:, 1:], jnp.zeros_like(mask[:, :1])], axis=1)
    digits = jnp.where(has_sign[:, None], shifted, bytes_w)
    dmask = jnp.where(has_sign[:, None], shifted_m, mask)
    return sign, digits, dmask


def parse_int(css, offset, length, width: int = 10) -> Parsed:
    """Fixed-width integer parse (int32).  ``width`` counts digits + sign.

    ``valid`` requires the magnitude to fit int32 (|v| ≤ ``INT32_MAX``):
    values like ``9999999999`` that would silently Horner-wrap instead clear
    ``valid``.  The overflow test runs *before* each Horner step —
    ``acc*10+d > MAX  ⇔  acc > (MAX-d)//10`` — so it never needs a wider
    accumulator.
    """
    raw, mask = gather_field_bytes(css, offset, length, width)
    sign, digits, dmask = _sign_and_digits(raw, mask)
    d = digits.astype(jnp.int32) - _ZERO
    is_digit = (d >= 0) & (d <= 9)
    ok = jnp.all(is_digit | ~dmask, axis=1) & jnp.any(dmask, axis=1)
    ok &= length <= width  # wider fields would truncate silently

    d = jnp.where(dmask, d, 0)
    # Branchless Horner over the fixed width; masked lanes multiply by 1.
    def step(carry, col):
        acc, ovf = carry
        dk, mk = col
        ovf |= mk & (acc > (_I32_MAX - dk) // 10)
        return (acc * jnp.where(mk, 10, 1) + dk, ovf), None

    (acc, ovf), _ = jax.lax.scan(
        step,
        (jnp.zeros(raw.shape[0], jnp.int32), jnp.zeros(raw.shape[0], bool)),
        (d.T, dmask.T),
    )
    ok &= ~ovf
    empty = length == 0
    return Parsed(sign * acc, ok & ~empty, empty)


def parse_int_segmented(css: jax.Array, field_start: jax.Array, field_id: jax.Array,
                        n_fields: int) -> Parsed:
    """Skew-free integer parse over an entire CSS via segmented scan.

    Args:
      css: ``(N,) uint8`` column symbol string (one column's bytes, fields
        back to back — ``tagged`` mode layout).
      field_start: ``(N,) bool`` — True at each field's first byte.
      field_id: ``(N,) int32`` — field index per byte (``n_fields`` = drop).

    The semigroup carries ``(reset, value, ndigits, overflow)``; a reset bit
    makes the scan segmented while staying associative:
        a ⊕ b = b                      if b.reset
                (a.r, a.v·10^min(b.n,9) + b.v, a.n + b.n, a.o|b.o|ovf(a,b))
    Field values are read at each field's *last* byte.

    Overflow is detected exactly (``valid`` clears whenever a field's true
    magnitude exceeds ``INT32_MAX``, matching :func:`parse_int`): while no
    sub-window has overflowed, every carried value is exact, so the combine
    test ``a.v > (MAX - b.v) // 10^b.n`` (or ``a.v > 0`` when ``b`` spans ≥10
    digits) is exact too — and once set, the flag is sticky, which keeps the
    operator associative.  Digit counts are otherwise uncapped: any number of
    leading zeros is fine, which is what removes the old ≤9-digit cap and
    reconciles this path with the ≤10-digit gather parser.
    """
    n = css.shape[0]
    d = css.astype(jnp.int32) - _ZERO
    is_digit = (d >= 0) & (d <= 9)
    is_minus = css == ord("-")
    is_plus = css == ord("+")
    sign_pos = (is_minus | is_plus) & field_start  # sign only legal up front

    elem_v = jnp.where(is_digit, d, 0)
    elem_n = jnp.where(is_digit, 1, 0)
    elem_r = field_start
    elem_o = jnp.zeros(n, bool)

    def op(a, b):
        ar, av, an, ao = a
        br, bv, bn, bo = b
        scale = _POW10_I32[jnp.clip(bn, 0, 9)]
        ovf = jnp.where(bn >= 10, av > 0, av > (_I32_MAX - bv) // scale)
        v = jnp.where(br, bv, av * scale + bv)
        nn = jnp.where(br, bn, an + bn)
        o = jnp.where(br, bo, ao | bo | ovf)
        r = ar | br
        return (r, v, nn, o)

    _, val, ndig, ovf = jax.lax.associative_scan(
        op, (elem_r, elem_v, elem_n, elem_o), axis=0
    )

    # Per-byte validity: digits, or a legal leading sign.
    byte_ok = is_digit | sign_pos
    ok_all = jax.ops.segment_min(
        byte_ok.astype(jnp.int32), field_id, num_segments=n_fields + 1
    )[:-1].astype(bool)

    # Scatter per-field results from each field's last byte.
    pos = jnp.arange(n, dtype=jnp.int32)
    last = jax.ops.segment_max(pos, field_id, num_segments=n_fields + 1)[:-1]
    has_bytes = last >= 0
    last_c = jnp.clip(last, 0)
    value = val[last_c]
    ndigits = ndig[last_c]
    overflowed = ovf[last_c]
    sign = jnp.where(is_minus[jnp.clip(jax.ops.segment_min(pos, field_id, num_segments=n_fields + 1)[:-1], 0)], -1, 1)

    valid = has_bytes & ok_all & (ndigits > 0) & ~overflowed
    return Parsed(sign * value, valid, ~has_bytes)


def parse_float(css, offset, length, width: int = 24) -> Parsed:
    """Float32 parse: ``[+-]ddd[.ddd][eE[+-]dd]`` with masked vector passes."""
    raw, mask = gather_field_bytes(css, offset, length, width)
    sign, b, m = _sign_and_digits(raw, mask)
    w = b.shape[1]
    lane = jnp.arange(w, dtype=jnp.int32)[None, :]

    is_dot = (b == ord(".")) & m
    is_e = ((b == ord("e")) | (b == ord("E"))) & m
    dot_pos = jnp.min(jnp.where(is_dot, lane, w), axis=1)   # (R,)
    e_pos = jnp.min(jnp.where(is_e, lane, w), axis=1)

    d = b.astype(jnp.int32) - _ZERO
    is_digit = (d >= 0) & (d <= 9)

    in_mant = m & (lane < e_pos[:, None])
    mant_digit = in_mant & ~is_dot
    # Structural validity: ≤1 dot, dot (if any) before e, mantissa digits are
    # digits, at least one mantissa digit.  dot_pos == w means "no dot" —
    # legal with or without an exponent ("1e+06").
    ok = (jnp.sum(is_dot, axis=1) <= 1) & ((dot_pos <= e_pos) | (dot_pos >= w))
    ok &= jnp.all(is_digit | ~mant_digit, axis=1)
    ok &= jnp.any(mant_digit & is_digit, axis=1)

    dm = jnp.where(mant_digit & is_digit, d, 0)
    active = mant_digit & is_digit

    def mant_step(acc, col):
        dk, ak = col
        return acc * jnp.where(ak, 10.0, 1.0) + dk, None

    macc, _ = jax.lax.scan(
        mant_step, jnp.zeros(b.shape[0], jnp.float32),
        (dm.T.astype(jnp.float32), active.T),
    )
    frac_digits = jnp.sum(active & (lane > dot_pos[:, None]), axis=1)

    # Exponent section.
    after_e = m & (lane > e_pos[:, None])
    e_sign_lane = e_pos + 1
    e_first = jnp.take_along_axis(b, jnp.clip(e_sign_lane, 0, w - 1)[:, None], axis=1)[:, 0]
    has_e = e_pos < w
    e_neg = has_e & (e_first == ord("-"))
    e_signed = has_e & ((e_first == ord("-")) | (e_first == ord("+")))
    exp_digit = after_e & (lane > (e_pos + jnp.where(e_signed, 1, 0))[:, None])
    ok &= jnp.all(is_digit | ~exp_digit, axis=1)
    ok &= ~has_e | jnp.any(exp_digit, axis=1)
    de = jnp.where(exp_digit & is_digit, d, 0)

    def exp_step(acc, col):
        dk, ak = col
        return acc * jnp.where(ak, 10, 1) + dk, None

    eacc, _ = jax.lax.scan(
        exp_step, jnp.zeros(b.shape[0], jnp.int32), (de.T, exp_digit.T)
    )
    exp = jnp.where(e_neg, -eacc, eacc) - frac_digits
    value = sign.astype(jnp.float32) * macc * jnp.power(jnp.float32(10.0), exp.astype(jnp.float32))

    empty = length == 0
    ok &= length <= width
    return Parsed(value, ok & ~empty, empty)


def _days_in_month(year, mon):
    """Length of ``mon`` in ``year`` (proleptic Gregorian), branchless.

    ``30 + (m + m//8) % 2`` reproduces the 31/30 alternation (with the
    August flip) for every month except February, which gets the leap rule.
    Only meaningful for ``mon`` in 1..12 — callers gate on that separately.
    """
    leap = ((year % 4 == 0) & (year % 100 != 0)) | (year % 400 == 0)
    return jnp.where(mon == 2, 28 + leap.astype(jnp.int32),
                     30 + (mon + mon // 8) % 2)


def _days_from_civil(y, m, d):
    """Howard Hinnant's days-from-civil (proleptic Gregorian → days since epoch)."""
    y = y - (m <= 2)
    era = jnp.where(y >= 0, y, y - 399) // 400
    yoe = y - era * 400
    mp = (m + 9) % 12
    doy = (153 * mp + 2) // 5 + d - 1
    doe = yoe * 365 + yoe // 4 - yoe // 100 + doy
    return era * 146097 + doe - 719468


def parse_date(css, offset, length) -> Parsed:
    """``YYYY-MM-DD[ HH:MM:SS]`` → unix epoch seconds (int32, valid to 2038).

    Validation is semantic, not just structural: the day must exist in the
    (proleptic Gregorian) month — day 31 of a 30-day month and Feb 29 of a
    non-leap year are rejected — and the time section, when present, must
    satisfy ``hh ≤ 23``, ``mm/ss ≤ 59`` with a ``' '`` or ISO-8601 ``'T'``
    date/time separator.
    """
    raw, mask = gather_field_bytes(css, offset, length, 19)
    d = raw.astype(jnp.int32) - _ZERO

    def num(*lanes):
        acc = jnp.zeros(raw.shape[0], jnp.int32)
        for ln in lanes:
            acc = acc * 10 + d[:, ln]
        return acc

    year, mon, day = num(0, 1, 2, 3), num(5, 6), num(8, 9)
    has_time = length >= 19
    hh = jnp.where(has_time, num(11, 12), 0)
    mm = jnp.where(has_time, num(14, 15), 0)
    ss = jnp.where(has_time, num(17, 18), 0)

    digit_lanes = jnp.array([0, 1, 2, 3, 5, 6, 8, 9], jnp.int32)
    time_lanes = jnp.array([11, 12, 14, 15, 17, 18], jnp.int32)
    dd = (d >= 0) & (d <= 9)
    ok = jnp.all(dd[:, digit_lanes], axis=1)
    ok &= (raw[:, 4] == ord("-")) & (raw[:, 7] == ord("-"))
    ok &= (length == 10) | (length == 19)
    time_ok = jnp.all(dd[:, time_lanes], axis=1) & (raw[:, 13] == ord(":")) & (raw[:, 16] == ord(":"))
    time_ok &= (raw[:, 10] == ord(" ")) | (raw[:, 10] == ord("T"))  # ISO 8601 'T' too
    ok &= jnp.where(has_time, time_ok, True)
    ok &= (mon >= 1) & (mon <= 12) & (day >= 1) & (day <= _days_in_month(year, mon))
    ok &= jnp.where(has_time, (hh <= 23) & (mm <= 59) & (ss <= 59), True)

    secs = _days_from_civil(year, mon, day) * 86400 + hh * 3600 + mm * 60 + ss
    empty = length == 0
    return Parsed(secs, ok & ~empty, empty)


def parse_string_noop(css, offset, length) -> Parsed:
    """Strings stay in the CSS; "parsing" is just validity bookkeeping."""
    empty = length == 0
    return Parsed(offset, ~empty, empty)


PARSERS = {
    "int32": parse_int,
    "float32": parse_float,
    "date": parse_date,
    "str": parse_string_noop,
}

# ---------------------------------------------------------------------------
# Type inference (paper §4.3): min numeric type per column via reduction.
# ---------------------------------------------------------------------------

TYPE_CODES = ("int32", "float32", "str")


def infer_column_type(css, offset, length, present, width: int = 24):
    """Returns index into TYPE_CODES: int if every present field parses as
    int, else float if every present field parses as float, else string."""
    live = present & (length > 0)
    ints = parse_int(css, offset, length, width=min(width, 11))
    floats = parse_float(css, offset, length, width=width)
    all_int = jnp.all(ints.valid | ~live)
    all_float = jnp.all(floats.valid | ~live)
    return jnp.where(all_int, 0, jnp.where(all_float, 1, 2))
