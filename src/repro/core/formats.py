"""Format registry: named formats on the shared FSM engine (ROADMAP item 4).

The paper's core claim is that the parsing engine is *format-agnostic*: a
new delimiter-separated format is a new transition/emission table, not new
code.  This module is where that claim is cashed in — a registry mapping a
format name to its :class:`FormatSpec`:

  * a DFA factory (``make_csv_dfa`` / ``make_jsonl_dfa`` / ``make_zone_dfa``
    / ``make_log_dfa`` / …) whose tables drive every backend unchanged,
  * the default tagging mode and the tagging modes the format supports,
  * a canonical demo/test :class:`~repro.core.parser.Schema`,
  * an *oracle slot*: a pure-Python sequential parser of the same dialect,
    attached by the test suite (``tests/oracles/``) via :func:`attach_oracle`
    so conformance/fuzz/golden suites can check every backend bit-for-bit
    against it.  Core ships the slot empty — oracles are test fixtures, not
    runtime dependencies.

Every registered DFA passes ``Dfa.validate_tables`` at registration time,
so a malformed table fails here, not inside a traced kernel.

Adding a format (see docs/ARCHITECTURE.md §Format registry):

    >>> from repro.core import formats
    >>> formats.register_format(formats.FormatSpec(
    ...     name="tsv2", make_dfa=lambda: make_csv_dfa(delimiter=b"\\t"),
    ...     default_schema=Schema.of(("a", "str"), ("b", "str"))))
    >>> parser = Parser(formats.parser_config("tsv2", max_records=64))
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.dfa import (
    Dfa,
    make_csv_dfa,
    make_jsonl_dfa,
    make_log_dfa,
    make_simple_dfa,
    make_zone_dfa,
)
from repro.core.parser import ParserConfig, Schema
from repro.core.tagging import TAGGING_MODES


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """One registered format: ``(Dfa, tagging mode, oracle)`` plus metadata.

    ``make_dfa`` is a factory (not a table instance) so every caller gets
    fresh tables — :class:`Dfa` hashes by identity, and sharing one mutable
    numpy-backed instance across tenants would couple their jit caches.
    ``oracle`` is ``None`` in core; the test suite attaches the pure-Python
    sequential reference parser (``tests/oracles/``) whose output every
    backend must reproduce bit-for-bit.
    """

    name: str
    make_dfa: Callable[[], Dfa]
    default_schema: Schema
    tagging: str = "tagged"
    tagging_modes: Tuple[str, ...] = TAGGING_MODES
    doc: str = ""
    oracle: Optional[Callable] = None

    def dfa(self) -> Dfa:
        return self.make_dfa()


_REGISTRY: Dict[str, FormatSpec] = {}


def register_format(spec: FormatSpec, overwrite: bool = False) -> FormatSpec:
    """Register ``spec`` under ``spec.name``; validates the DFA tables."""
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"format {spec.name!r} already registered")
    if spec.tagging not in spec.tagging_modes:
        raise ValueError(
            f"default tagging {spec.tagging!r} not in {spec.tagging_modes}")
    unknown = set(spec.tagging_modes) - set(TAGGING_MODES)
    if unknown:
        raise ValueError(f"unknown tagging modes {sorted(unknown)}")
    spec.dfa().validate_tables()
    _REGISTRY[spec.name] = spec
    return spec


def get_format(name: str) -> FormatSpec:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown format {name!r}; registered: {available_formats()}")
    return _REGISTRY[name]


def available_formats() -> List[str]:
    return sorted(_REGISTRY)


def attach_oracle(name: str, oracle: Callable) -> FormatSpec:
    """Fill a registered format's oracle slot (test-suite hook)."""
    spec = dataclasses.replace(get_format(name), oracle=oracle)
    _REGISTRY[name] = spec
    return spec


def parser_config(name: str, schema: Optional[Schema] = None,
                  max_records: int = 1 << 10, **overrides) -> ParserConfig:
    """Build a :class:`ParserConfig` for a registered format.

    The format supplies the DFA and default tagging mode; ``schema``
    defaults to the spec's canonical schema.  Any :class:`ParserConfig`
    knob (backend, chunk_size, fuse_pipeline, …) passes through.
    """
    spec = get_format(name)
    overrides.setdefault("tagging", spec.tagging)
    if overrides["tagging"] not in spec.tagging_modes:
        raise ValueError(
            f"format {name!r} does not support tagging "
            f"{overrides['tagging']!r} (supported: {spec.tagging_modes})")
    return ParserConfig(
        dfa=spec.dfa(),
        schema=schema if schema is not None else spec.default_schema,
        max_records=max_records,
        **overrides,
    )


# ---------------------------------------------------------------------------
# built-in formats
# ---------------------------------------------------------------------------

_MIXED = Schema.of(("i", "int32"), ("s", "str"), ("f", "float32"),
                   ("d", "date"))

register_format(FormatSpec(
    name="csv", make_dfa=make_csv_dfa, default_schema=_MIXED,
    doc="RFC 4180 CSV: quoted fields, doubled-quote escapes, CRLF."))

register_format(FormatSpec(
    name="csv+comment",
    make_dfa=lambda: make_csv_dfa(comment=b"#"),
    default_schema=_MIXED,
    doc="CSV with '#' line comments (comment lines produce no records)."))

register_format(FormatSpec(
    name="tsv",
    make_dfa=lambda: make_csv_dfa(delimiter=b"\t", name="tsv"),
    default_schema=_MIXED,
    doc="Tab-separated values under the CSV quoting rules."))

register_format(FormatSpec(
    name="simple", make_dfa=make_simple_dfa,
    default_schema=Schema.of(("a", "int32"), ("b", "float32")),
    doc="Quote-free delimited baseline (paper §2's constrained format)."))

register_format(FormatSpec(
    name="clf", make_dfa=make_log_dfa,
    default_schema=Schema.of(("host", "str"), ("ts", "str"),
                             ("req", "str"), ("code", "int32")),
    doc="Common-Log-Format-style: space-delimited with [...] and \"...\" "
        "enclosing scopes."))

register_format(FormatSpec(
    name="jsonl", make_dfa=make_jsonl_dfa,
    default_schema=Schema.of(("k0", "str"), ("id", "int32"),
                             ("k1", "str"), ("name", "str"),
                             ("k2", "str"), ("score", "float32")),
    doc="JSON Lines (one object per line): depth-1 ','/':' delimit "
        "alternating key/value columns; nested values stay raw subtext."))

register_format(FormatSpec(
    name="zone", make_dfa=make_zone_dfa,
    default_schema=Schema.of(("name", "str"), ("ttl", "int32"),
                             ("class", "str"), ("type", "str"),
                             ("data", "str")),
    doc="DNS zone file: whitespace-delimited RRs, ';' comments, "
        "parenthesized multi-line records; TTL feeds int typeconv."))
