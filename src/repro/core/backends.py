"""Pluggable parse-stage backends (DESIGN.md §2; paper §3.1–§3.3).

Every driver — ``Parser``, ``DistributedParser``, ``StreamingParser`` — runs
the *same* stage functions from :mod:`repro.core.stages`; what varies is who
implements the byte-level hot loops.  A :class:`ParseBackend` bundles the
swappable stage implementations:

  * ``chunk_vectors``     — §3.1 first pass: per-chunk state-transition
    vectors (the |S|-simultaneous-DFA sweep over every byte).
  * ``replay_summaries``  — §3.1 second pass fused with the §3.2 per-chunk
    offset summaries: class codes + end states + (rec_count, col_tag,
    col_off) triples in one sweep.
  * ``partition``         — §3.3 stable partition of the tagged symbol
    stream by column tag.  Receives the *resolved* ``partition_impl``
    (``stages.plan_materialize`` maps ``"auto"`` to the backend's
    ``default_partition_impl``; ``partition_impls`` lists what the backend
    accepts).
  * ``parse_field``       — §3.3 type conversion, one entry per schema dtype
    (``int32`` / ``float32`` / ``date`` / ``str``), each mapping
    ``(css, offset, length)`` to a :class:`typeconv.Parsed`.
    ``stages.materialize`` dispatches *every* selected column through this
    table, so a backend that kernelises a dtype needs no driver changes at
    all.
  * ``prepend_carry`` / ``extract_carry`` — the §4.4 per-stream device-carry
    state machine of the streaming engine (``core/streaming.py``): splice
    the carried tail record in front of the fresh partition bytes, and cut
    the new tail after ``last_record_end``.  Both default to the shared jnp
    implementations below (pure ``where``/``roll`` masks — cheap next to
    the parse); they are backend hooks so a whole-pipeline-fusion backend
    can fold the splice into its first kernel's DMA and the cut into its
    last, without the engine changing.
  * ``execute``            — OPTIONAL whole-pipeline override: run the
    entire §3.1→§4.4 per-partition step (replay → tag → partition → field
    index → convert → validation inputs) as the backend sees fit, bypassing
    the staged composition in ``stages.execute_plan``.  Resolved into
    ``ParsePlan.execute_path`` by ``stages.plan_parse`` when the config
    asks for it (``ParserConfig.fuse_pipeline=True``) and gated at trace
    time behind the static ``fused_max_bytes`` byte cap (the megakernel
    holds the whole partition's working set in VMEM on real hardware);
    above the cap ``execute_plan`` silently runs the staged tier — same
    statically-bounded fallback design as the windowed numparse kernels.
    Backends without a fused executor leave it ``None``.

Backends:

  * ``reference`` — the pure-jnp path (``core.transition`` /
    ``core.offsets`` / ``core.partition`` / ``core.typeconv``); always
    available, the oracle.
  * ``pallas``    — the Pallas TPU kernels (``kernels.dfa_scan`` /
    ``kernels.partition`` / ``kernels.numparse``).  The fused replay kernel
    makes the separate ``chunk_summaries`` jnp pass disappear; the
    partition defaults to the single-pass radix kernel on real hardware
    (``partition_impl="auto"`` → ``"kernel"``; under ``interpret=True`` it
    resolves to the jit-fused jnp radix pass, with the kernel selectable
    explicitly); and int32/float32/date columns convert
    inside *fused gather+convert* ``numparse`` kernels that index the CSS
    in-kernel — no XLA ``take``/gather between the field index and
    conversion.  The fused kernels are *windowed* by default: each row
    block DMAs only its contiguous CSS window into VMEM (offsets within a
    column are sorted), so per-parse input is not capped by VMEM capacity;
    ``cfg.window_rows`` / ``cfg.max_window_bytes`` size the windows,
    ``window_rows=-1`` pins the whole-CSS kernels, and
    ``cfg.fuse_typeconv=False`` restores the unfused gather+kernel path
    for comparison (``str`` stays the shared no-op — strings live in the
    CSS and need no arithmetic).  ``cfg.interpret`` / ``cfg.block_chunks``
    carry the kernel knobs.

Stage functions receive the ``ParserConfig`` duck-typed (``cfg.dfa``,
``cfg.interpret``, ``cfg.block_chunks``, ``cfg.int_width``) so kernel knobs
travel with the config instead of threading through every call site, and so
this module never imports :mod:`repro.core.parser` (no cycle).

The registry is open: future PRs add a backend (e.g. a Mosaic-GPU or a
partially-fused one) with :func:`register_backend` and every driver picks it
up through ``ParserConfig.backend``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import offsets as offsets_mod
from repro.core import partition as partition_mod
from repro.core import transition as tr
from repro.core import typeconv as typeconv_mod
from repro.core.dfa import PAD_BYTE

#: Default chunk-block size for the Pallas grid (mirrors
#: ``kernels.dfa_scan.dfa_scan.DEFAULT_BLOCK_CHUNKS`` without importing the
#: kernel package at module load).
DEFAULT_BLOCK_CHUNKS = 256


# ---------------------------------------------------------------------------
# shared §4.4 stream-state hooks (device carry splice / cut) — the defaults
# every backend inherits; a fusing backend overrides them to fold the splice
# into its first kernel's DMA and the cut into its last.
# ---------------------------------------------------------------------------

def prepend_carry_jnp(carry_buf: jax.Array, carry_len: jax.Array,
                      fresh: jax.Array, fresh_len: jax.Array,
                      flush: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Splice ``carry_buf[:carry_len]`` in front of ``fresh[:fresh_len]``.

    All buffers are fixed-capacity ``(capacity,) uint8`` with PAD tails, so
    the splice is two masked ``where``s over a ``roll`` — no dynamic shapes,
    no host round-trip.  Under ``flush`` (the stream's final partition) an
    unterminated payload gets the record delimiter appended, judged on the
    last non-PAD byte (a PAD-only tail carries no record; paper §4.4 flush).

    Returns ``(buf, total, overflow)``: the assembled partition, its byte
    length (carry + fresh), and whether it no longer fits the capacity
    (including the flush delimiter's slot) — the condition the host raises
    "record longer than capacity" on, one partition behind.  Under overflow
    the buffer contents are garbage (the roll wraps); callers must raise
    before using them.
    """
    capacity = carry_buf.shape[0]
    delim = cfg.record_delim_byte
    pos = jnp.arange(capacity, dtype=jnp.int32)
    total = carry_len + fresh_len
    rolled = jnp.roll(fresh, carry_len)  # fresh payload now starts at carry_len
    buf = jnp.where(pos < carry_len, carry_buf,
                    jnp.where(pos < total, rolled, jnp.uint8(PAD_BYTE)))
    # Flush: append a record delimiter so the tail record completes.  Whether
    # one is needed is judged on the last *payload* byte (PAD bytes in the
    # source are inert — a PAD-only tail carries no record; carry_buf beyond
    # carry_len is PAD by the extract invariant below), but it is written at
    # ``total`` — after any trailing source PADs — exactly where the host
    # oracle writes it, so the two engines stay bit-identical.
    payload = jnp.max(jnp.where(buf != PAD_BYTE, pos + 1, 0))
    last_byte = buf[jnp.maximum(payload - 1, 0)]
    need_delim = flush & (payload > 0) & (last_byte != delim)
    overflow = (total > capacity) | (need_delim & (total >= capacity))
    buf = jnp.where(need_delim & (pos == total), jnp.uint8(delim), buf)
    return buf, total, overflow


def extract_carry_jnp(buf: jax.Array, total: jax.Array,
                      last_record_end: jax.Array, flush: jax.Array,
                      cfg) -> Tuple[jax.Array, jax.Array]:
    """Cut the carried tail ``buf[last_record_end+1 : total]`` to the front
    of a fresh fixed-capacity buffer.

    ``last_record_end == -1`` (no complete record) carries the whole
    payload; under ``flush`` the leftover is stale — either inert PADs or a
    record the appended delimiter could not close (malformed input;
    ``validation`` flags it) — and is dropped so the stream ends consumed.

    Returns ``(new_carry_buf, new_carry_len)`` with everything beyond
    ``new_carry_len`` PAD (the invariant ``prepend_carry`` relies on).
    """
    capacity = buf.shape[0]
    pos = jnp.arange(capacity, dtype=jnp.int32)
    cut = last_record_end + 1
    new_len = jnp.maximum(total - cut, 0)
    new_len = jnp.where(flush, 0, new_len)
    new_buf = jnp.where(pos < new_len, jnp.roll(buf, -cut), jnp.uint8(PAD_BYTE))
    return new_buf, new_len.astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ParseBackend:
    """Bundle of swappable stage implementations (see module docstring).

    Signatures (all traced under the driver's jit):
      chunk_vectors(chunks (C,K) u8, cfg) -> (C,S) i32
      replay_summaries(chunks (C,K) u8, start (C,) i32, cfg)
          -> (classes (C,K) u8, end_states (C,) i32, saw_invalid (C,) bool,
              offsets.ChunkSummary)
      partition(col_tag (N,) i32, n_cols, impl: str, cfg)
          -> partition.Partitioned      for impl in ``partition_impls``
      parse_field[dtype](css (N,) u8, offset (R,) i32, length (R,) i32, cfg)
          -> typeconv.Parsed     for dtype in int32 | float32 | date | str

    ``partition_impls`` / ``default_partition_impl`` are static metadata the
    planning layer uses to resolve ``ParserConfig.partition_impl="auto"``
    and to fail fast on impls the backend does not implement;
    ``typeconv_path`` names the conversion strategy the config resolves to
    (``reference`` / ``unfused`` / ``fused-windowed`` / ``fused-wholecss``)
    so plans and benchmark reports can label it without re-deriving the
    backend's dispatch logic.
    """

    name: str
    chunk_vectors: Callable
    replay_summaries: Callable
    partition: Callable
    parse_field: Dict[str, Callable]
    partition_impls: Tuple[str, ...]
    default_partition_impl: Callable  # (cfg) -> impl name ("auto" resolution)
    typeconv_path: Callable = lambda cfg: "reference"  # (cfg) -> path label
    # §4.4 per-stream device-carry hooks (streaming engine); see module
    # docstring.  Signatures:
    #   prepend_carry(carry_buf (B,) u8, carry_len () i32, fresh (B,) u8,
    #                 fresh_len () i32, flush () bool, cfg)
    #       -> (buf (B,) u8, total () i32, overflow () bool)
    #   extract_carry(buf (B,) u8, total () i32, last_record_end () i32,
    #                 flush () bool, cfg) -> (carry_buf (B,) u8, carry_len () i32)
    prepend_carry: Callable = prepend_carry_jnp
    extract_carry: Callable = extract_carry_jnp
    # Whole-pipeline fused executor (see module docstring).  Signature:
    #   execute(raw_chunks (C,K) u8, plan: stages.ParsePlan, cfg,
    #           initial_state () i32,
    #           stitch: Optional[stages.ParseStitch] = None)
    #       -> stages.ParseResult
    # ``stitch`` carries the distributed driver's cross-shard hooks (prefix
    # composition, offset/record-base seeding, global validation reductions)
    # so the fused path runs per-shard under shard_map — see
    # ``stages.ParseStitch``.  None = backend has no fused path; plans
    # resolve to "staged".
    execute: Optional[Callable] = None
    # Static byte cap for the fused path: partitions larger than this run
    # the staged tier instead (checked at trace time in execute_plan — the
    # megakernel's whole working set must fit VMEM on real hardware).
    fused_max_bytes: int = 4 << 20
    # Backend-specific contribution to ``stages.plan_key`` (the serving
    # registry's executable fingerprint): a hashable tuple of every config
    # knob this backend's traced code *reads* beyond what the ParsePlan
    # already captures.  Two configs whose plan keys are equal must trace
    # to bit-identical executables — list knobs conservatively.
    config_key: Callable = lambda cfg: ()


BACKENDS: Dict[str, ParseBackend] = {}


def register_backend(backend: ParseBackend) -> ParseBackend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> ParseBackend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown parser backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(BACKENDS))


def pad_to_block(arr: jax.Array, block: int, fill) -> Tuple[jax.Array, int]:
    """Pad ``arr``'s leading axis up to a multiple of ``block``.

    Returns ``(padded, original_length)``; padding rows are ``fill`` and are
    inert by construction (PAD bytes / dummy states / zero-length fields), so
    callers slice results back to ``original_length``.
    """
    n = arr.shape[0]
    pad = (-n) % block
    if pad == 0:
        return arr, n
    padding = jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, padding], axis=0), n


def _saw_invalid(end_states: jax.Array, dfa) -> jax.Array:
    """The invalid sink is absorbing, so "ever hit" == "ended there"."""
    if dfa.invalid_state is None:
        return jnp.zeros(end_states.shape, bool)
    return end_states == dfa.invalid_state


# ---------------------------------------------------------------------------
# reference backend — pure jnp (core.transition / core.offsets / core.typeconv)
# ---------------------------------------------------------------------------

def _ref_chunk_vectors(chunks: jax.Array, cfg) -> jax.Array:
    groups = tr.byte_groups(chunks, cfg.dfa)
    return tr.chunk_transition_vectors(groups, cfg.dfa)


def _ref_replay_summaries(chunks: jax.Array, start: jax.Array, cfg):
    groups = tr.byte_groups(chunks, cfg.dfa)
    classes, end_states, saw_invalid = tr.replay(groups, start, cfg.dfa)
    summaries = offsets_mod.chunk_summaries(classes)
    return classes, end_states, saw_invalid, summaries


def _jnp_partition(col_tag, n_cols, impl, cfg) -> partition_mod.Partitioned:
    return partition_mod.PARTITION_IMPLS[impl](col_tag, n_cols)


def _ref_parse_int(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_int(css, offset, length, width=cfg.int_width)


def _ref_parse_float(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_float(css, offset, length, width=cfg.float_width)


def _ref_parse_date(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_date(css, offset, length)


def _shared_parse_str(css, offset, length, cfg) -> typeconv_mod.Parsed:
    # Strings stay in the CSS; both backends share the bookkeeping no-op.
    return typeconv_mod.parse_string_noop(css, offset, length)


REFERENCE = register_backend(ParseBackend(
    name="reference",
    chunk_vectors=_ref_chunk_vectors,
    replay_summaries=_ref_replay_summaries,
    partition=_jnp_partition,
    parse_field={
        "int32": _ref_parse_int,
        "float32": _ref_parse_float,
        "date": _ref_parse_date,
        "str": _shared_parse_str,
    },
    partition_impls=tuple(sorted(partition_mod.PARTITION_IMPLS)),
    default_partition_impl=lambda cfg: "scatter",
))


# ---------------------------------------------------------------------------
# pallas backend — kernels.dfa_scan + kernels.partition + kernels.numparse
# ---------------------------------------------------------------------------

def _block_chunks(cfg, c: int) -> int:
    return min(getattr(cfg, "block_chunks", DEFAULT_BLOCK_CHUNKS) or
               DEFAULT_BLOCK_CHUNKS, c)


def _pl_chunk_vectors(chunks: jax.Array, cfg) -> jax.Array:
    from repro.kernels.dfa_scan import dfa_scan

    bc = _block_chunks(cfg, chunks.shape[0])
    padded, n = pad_to_block(chunks, bc, PAD_BYTE)
    vecs = dfa_scan.chunk_vectors(
        padded, cfg.dfa, block_chunks=bc, interpret=cfg.interpret
    )
    return vecs[:n]


def _pl_replay_summaries(chunks: jax.Array, start: jax.Array, cfg):
    from repro.kernels.dfa_scan import dfa_scan

    bc = _block_chunks(cfg, chunks.shape[0])
    padded, n = pad_to_block(chunks, bc, PAD_BYTE)
    start_p, _ = pad_to_block(
        start.astype(jnp.int32), bc, cfg.dfa.start_state
    )
    classes, end_states, summ = dfa_scan.replay_fused(
        padded, start_p, cfg.dfa, block_chunks=bc, interpret=cfg.interpret
    )
    classes, end_states, summ = classes[:n], end_states[:n], summ[:n]
    summaries = offsets_mod.ChunkSummary(
        rec_count=summ[:, 0], col_tag=summ[:, 1], col_off=summ[:, 2]
    )
    return classes, end_states, _saw_invalid(end_states, cfg.dfa), summaries


def _pl_partition(col_tag, n_cols, impl, cfg) -> partition_mod.Partitioned:
    if impl != "kernel":  # explicit jnp impls stay available for comparison
        return partition_mod.PARTITION_IMPLS[impl](col_tag, n_cols)
    from repro.kernels.partition import ops as partition_ops

    kw = {}
    bt = getattr(cfg, "partition_block_tags", 0)
    if bt:
        kw["block_tags"] = bt
    return partition_ops.partition_tags(
        col_tag, n_cols, interpret=cfg.interpret, **kw
    )


def _fuse(cfg) -> bool:
    return getattr(cfg, "fuse_typeconv", True)


def _window_kw(cfg) -> Dict[str, int]:
    """Windowed-DMA knobs for the fused numparse path (see ParserConfig)."""
    return dict(window_rows=getattr(cfg, "window_rows", 0),
                window_bytes=getattr(cfg, "max_window_bytes", 0))


def _pl_parse_int(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_int_column(
            css, offset, length, width=cfg.int_width, interpret=cfg.interpret)
    return numparse_ops.parse_int_column_fused(
        css, offset, length, width=cfg.int_width, interpret=cfg.interpret,
        **_window_kw(cfg))


def _pl_parse_float(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_float_column(
            css, offset, length, width=cfg.float_width, interpret=cfg.interpret)
    return numparse_ops.parse_float_column_fused(
        css, offset, length, width=cfg.float_width, interpret=cfg.interpret,
        **_window_kw(cfg))


def _pl_parse_date(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_date_column(
            css, offset, length, interpret=cfg.interpret)
    return numparse_ops.parse_date_column_fused(
        css, offset, length, interpret=cfg.interpret, **_window_kw(cfg))


def _pl_execute(raw_chunks, plan, cfg, initial_state, stitch=None):
    """Whole-pipeline fused executor: §3.1 scan + ONE megakernel per
    partition (``kernels/fused_pipeline``), then O(max_records)/scalar
    assembly — no ``(N,)``/``(R,)`` intermediate ever leaves a kernel.

    Bit-identical to the staged composition in ``stages.execute_plan`` by
    construction: the megakernel replicates each staged stage op-for-op
    (same replay select chains, same id scans, same scatter2 radix pass,
    same segment reductions, same shared numparse cores) and this wrapper
    replicates the §4.3 validation arithmetic on the kernel's
    ``fields_per_rec``/scalar outputs exactly as ``validation.validate``
    computes it from the flat class stream.

    Under a ``stitch`` (distributed execution; ``stages.ParseStitch``) the
    composite scan is seeded with the cross-device prefix, the megakernel's
    in-kernel tagging is seeded with the shard's column offset, and
    validation goes through the stitch's global reductions.  The column
    seed comes from the §3.2 summaries, which the megakernel only produces
    *internally* — so the stitched fused path runs the staged summary
    kernel (``replay_summaries``) first for the stitch and the megakernel
    re-replays in VMEM.  That duplicate replay is the price of keeping the
    megakernel single-launch; the shard driver's own summary pass CSEs
    against it, so it is one extra replay total, still O(N/D) per device
    with O(D·|S|) collectives.
    """
    from repro.core import stages as stages_mod
    from repro.core import validation as validation_mod
    from repro.kernels.fused_pipeline import ops as fused_ops

    mat = plan.materialize
    # §3.1 upstream: chunk transition vectors (pallas kernel) + the O(C·S)
    # composite scan — the only stages outside the megakernel.
    vecs = _pl_chunk_vectors(raw_chunks, cfg)
    scanned = tr.exclusive_scan_vectors(vecs, use_matmul=cfg.use_matmul_scan)
    if stitch is not None:
        prefix = stitch.prefix_fn(vecs)
        scanned = tr.compose(jnp.broadcast_to(prefix, scanned.shape), scanned)
    start = tr.start_states(scanned, cfg.dfa, initial_state=initial_state)

    col_seed = None
    if stitch is not None:
        _, _, _, summaries = _pl_replay_summaries(raw_chunks, start, cfg)
        _, _, col_seed, n_total = stitch.offsets_fn(summaries)

    out = fused_ops.fused_parse(
        raw_chunks, start, cfg.dfa,
        tagging=mat.tagging, n_cols=mat.n_cols, max_records=mat.max_records,
        selected=mat.selected, convert=mat.convert,
        int_width=cfg.int_width, float_width=cfg.float_width,
        col_seed=col_seed, interpret=cfg.interpret,
    )

    if stitch is not None:
        # §4.3 goes through the stitch's global reductions; the kernel's
        # fields_per_rec is already seed-corrected at the head record.
        val = stitch.validation_fn(
            out.fields_per_rec, out.n_records, out.end_state,
            out.saw_invalid, n_total,
        )
        return stages_mod.ParseResult(
            css=out.css,
            col_start=out.col_start,
            col_count=out.col_count,
            field_offset=out.offset,
            field_length=out.length,
            field_present=out.present,
            values=out.values,
            validation=val,
            end_state=out.end_state.astype(jnp.int32),
            last_record_end=out.last_record_end.astype(jnp.int32),
        )

    # §4.3 validation from the kernel's per-record field counts + scalars —
    # the same arithmetic validation.validate runs on the flat class stream.
    m = mat.max_records
    accept = jnp.asarray(cfg.dfa.accept)
    end_ok = accept[out.end_state.astype(jnp.int32)]
    no_inv = ~out.saw_invalid
    rec_live = jnp.arange(m) < out.n_records
    big = jnp.int32(2**31 - 1)
    minc = jnp.min(jnp.where(rec_live, out.fields_per_rec, big))
    maxc = jnp.max(jnp.where(rec_live, out.fields_per_rec, 0))
    if plan.expected_columns is None:
        record_ok = rec_live
    else:
        record_ok = rec_live & (out.fields_per_rec == plan.expected_columns)
    ok = end_ok & no_inv
    if plan.expected_columns is not None:
        ok &= jnp.all(record_ok | ~rec_live)
    val = validation_mod.Validation(
        ok, end_ok, no_inv, out.n_records, minc, maxc, record_ok
    )

    return stages_mod.ParseResult(
        css=out.css,
        col_start=out.col_start,
        col_count=out.col_count,
        field_offset=out.offset,
        field_length=out.length,
        field_present=out.present,
        values=out.values,
        validation=val,
        end_state=out.end_state.astype(jnp.int32),
        last_record_end=out.last_record_end.astype(jnp.int32),
    )


def _pl_config_key(cfg) -> Tuple:
    """Pallas kernel knobs that shape traced code beyond the ParsePlan."""
    return (
        "interpret", bool(cfg.interpret),
        "block_chunks", getattr(cfg, "block_chunks", None),
        "fuse_typeconv", _fuse(cfg),
        "window_rows", getattr(cfg, "window_rows", 0),
        "max_window_bytes", getattr(cfg, "max_window_bytes", 0),
        # None (unset) and False trace identically (staged)
        "fuse_pipeline", bool(getattr(cfg, "fuse_pipeline", False) or False),
        "partition_block_tags", getattr(cfg, "partition_block_tags", 0),
        "fused_max_bytes", getattr(cfg, "fused_max_bytes", 0),
    )


def _pl_typeconv_path(cfg) -> str:
    if not _fuse(cfg):
        return "unfused"
    from repro.kernels.numparse import ops as numparse_ops

    if getattr(cfg, "window_rows", 0) == numparse_ops.WHOLE_CSS:
        return "fused-wholecss"
    return "fused-windowed"


PALLAS = register_backend(ParseBackend(
    name="pallas",
    chunk_vectors=_pl_chunk_vectors,
    replay_summaries=_pl_replay_summaries,
    partition=_pl_partition,
    parse_field={
        "int32": _pl_parse_int,
        "float32": _pl_parse_float,
        "date": _pl_parse_date,
        "str": _shared_parse_str,
    },
    partition_impls=tuple(sorted(partition_mod.PARTITION_IMPLS)) + ("kernel",),
    # "auto" resolution: the radix kernel when compiling for real hardware;
    # under interpret=True (CPU containers/CI) the kernel runs op-by-op in
    # the Pallas interpreter, where XLA's jit-fused radix pass (scatter2) is
    # strictly faster — the kernel stays selectable (partition_impl="kernel")
    # and is pinned bit-identical by the parity/fuzz/golden suites.
    default_partition_impl=lambda cfg: "scatter2" if cfg.interpret else "kernel",
    typeconv_path=_pl_typeconv_path,
    config_key=_pl_config_key,
    # whole-pipeline fusion (ParserConfig.fuse_pipeline=True): one
    # megakernel per partition, gated behind fused_max_bytes (the dataclass
    # default) with the staged composition above as the fallback tier
    execute=_pl_execute,
))
