"""Pluggable parse-stage backends (DESIGN.md §2; paper §3.1–§3.3).

Every driver — ``Parser``, ``DistributedParser``, ``StreamingParser`` — runs
the *same* stage functions from :mod:`repro.core.stages`; what varies is who
implements the byte-level hot loops.  A :class:`ParseBackend` bundles the
swappable stage implementations:

  * ``chunk_vectors``     — §3.1 first pass: per-chunk state-transition
    vectors (the |S|-simultaneous-DFA sweep over every byte).
  * ``replay_summaries``  — §3.1 second pass fused with the §3.2 per-chunk
    offset summaries: class codes + end states + (rec_count, col_tag,
    col_off) triples in one sweep.
  * ``partition``         — §3.3 stable partition of the tagged symbol
    stream by column tag.  Receives the *resolved* ``partition_impl``
    (``stages.plan_materialize`` maps ``"auto"`` to the backend's
    ``default_partition_impl``; ``partition_impls`` lists what the backend
    accepts).
  * ``parse_field``       — §3.3 type conversion, one entry per schema dtype
    (``int32`` / ``float32`` / ``date`` / ``str``), each mapping
    ``(css, offset, length)`` to a :class:`typeconv.Parsed`.
    ``stages.materialize`` dispatches *every* selected column through this
    table, so a backend that kernelises a dtype needs no driver changes at
    all.

Backends:

  * ``reference`` — the pure-jnp path (``core.transition`` /
    ``core.offsets`` / ``core.partition`` / ``core.typeconv``); always
    available, the oracle.
  * ``pallas``    — the Pallas TPU kernels (``kernels.dfa_scan`` /
    ``kernels.partition`` / ``kernels.numparse``).  The fused replay kernel
    makes the separate ``chunk_summaries`` jnp pass disappear; the
    partition defaults to the single-pass radix kernel on real hardware
    (``partition_impl="auto"`` → ``"kernel"``; under ``interpret=True`` it
    resolves to the jit-fused jnp radix pass, with the kernel selectable
    explicitly); and int32/float32/date columns convert
    inside *fused gather+convert* ``numparse`` kernels that index the CSS
    in-kernel — no XLA ``take``/gather between the field index and
    conversion.  The fused kernels are *windowed* by default: each row
    block DMAs only its contiguous CSS window into VMEM (offsets within a
    column are sorted), so per-parse input is not capped by VMEM capacity;
    ``cfg.window_rows`` / ``cfg.max_window_bytes`` size the windows,
    ``window_rows=-1`` pins the whole-CSS kernels, and
    ``cfg.fuse_typeconv=False`` restores the unfused gather+kernel path
    for comparison (``str`` stays the shared no-op — strings live in the
    CSS and need no arithmetic).  ``cfg.interpret`` / ``cfg.block_chunks``
    carry the kernel knobs.

Stage functions receive the ``ParserConfig`` duck-typed (``cfg.dfa``,
``cfg.interpret``, ``cfg.block_chunks``, ``cfg.int_width``) so kernel knobs
travel with the config instead of threading through every call site, and so
this module never imports :mod:`repro.core.parser` (no cycle).

The registry is open: future PRs add a backend (e.g. a Mosaic-GPU or a
partially-fused one) with :func:`register_backend` and every driver picks it
up through ``ParserConfig.backend``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import offsets as offsets_mod
from repro.core import partition as partition_mod
from repro.core import transition as tr
from repro.core import typeconv as typeconv_mod
from repro.core.dfa import PAD_BYTE

#: Default chunk-block size for the Pallas grid (mirrors
#: ``kernels.dfa_scan.dfa_scan.DEFAULT_BLOCK_CHUNKS`` without importing the
#: kernel package at module load).
DEFAULT_BLOCK_CHUNKS = 256


@dataclasses.dataclass(frozen=True)
class ParseBackend:
    """Bundle of swappable stage implementations (see module docstring).

    Signatures (all traced under the driver's jit):
      chunk_vectors(chunks (C,K) u8, cfg) -> (C,S) i32
      replay_summaries(chunks (C,K) u8, start (C,) i32, cfg)
          -> (classes (C,K) u8, end_states (C,) i32, saw_invalid (C,) bool,
              offsets.ChunkSummary)
      partition(col_tag (N,) i32, n_cols, impl: str, cfg)
          -> partition.Partitioned      for impl in ``partition_impls``
      parse_field[dtype](css (N,) u8, offset (R,) i32, length (R,) i32, cfg)
          -> typeconv.Parsed     for dtype in int32 | float32 | date | str

    ``partition_impls`` / ``default_partition_impl`` are static metadata the
    planning layer uses to resolve ``ParserConfig.partition_impl="auto"``
    and to fail fast on impls the backend does not implement;
    ``typeconv_path`` names the conversion strategy the config resolves to
    (``reference`` / ``unfused`` / ``fused-windowed`` / ``fused-wholecss``)
    so plans and benchmark reports can label it without re-deriving the
    backend's dispatch logic.
    """

    name: str
    chunk_vectors: Callable
    replay_summaries: Callable
    partition: Callable
    parse_field: Dict[str, Callable]
    partition_impls: Tuple[str, ...]
    default_partition_impl: Callable  # (cfg) -> impl name ("auto" resolution)
    typeconv_path: Callable = lambda cfg: "reference"  # (cfg) -> path label


BACKENDS: Dict[str, ParseBackend] = {}


def register_backend(backend: ParseBackend) -> ParseBackend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> ParseBackend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown parser backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(BACKENDS))


def pad_to_block(arr: jax.Array, block: int, fill) -> Tuple[jax.Array, int]:
    """Pad ``arr``'s leading axis up to a multiple of ``block``.

    Returns ``(padded, original_length)``; padding rows are ``fill`` and are
    inert by construction (PAD bytes / dummy states / zero-length fields), so
    callers slice results back to ``original_length``.
    """
    n = arr.shape[0]
    pad = (-n) % block
    if pad == 0:
        return arr, n
    padding = jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, padding], axis=0), n


def _saw_invalid(end_states: jax.Array, dfa) -> jax.Array:
    """The invalid sink is absorbing, so "ever hit" == "ended there"."""
    if dfa.invalid_state is None:
        return jnp.zeros(end_states.shape, bool)
    return end_states == dfa.invalid_state


# ---------------------------------------------------------------------------
# reference backend — pure jnp (core.transition / core.offsets / core.typeconv)
# ---------------------------------------------------------------------------

def _ref_chunk_vectors(chunks: jax.Array, cfg) -> jax.Array:
    groups = tr.byte_groups(chunks, cfg.dfa)
    return tr.chunk_transition_vectors(groups, cfg.dfa)


def _ref_replay_summaries(chunks: jax.Array, start: jax.Array, cfg):
    groups = tr.byte_groups(chunks, cfg.dfa)
    classes, end_states, saw_invalid = tr.replay(groups, start, cfg.dfa)
    summaries = offsets_mod.chunk_summaries(classes)
    return classes, end_states, saw_invalid, summaries


def _jnp_partition(col_tag, n_cols, impl, cfg) -> partition_mod.Partitioned:
    return partition_mod.PARTITION_IMPLS[impl](col_tag, n_cols)


def _ref_parse_int(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_int(css, offset, length, width=cfg.int_width)


def _ref_parse_float(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_float(css, offset, length, width=cfg.float_width)


def _ref_parse_date(css, offset, length, cfg) -> typeconv_mod.Parsed:
    return typeconv_mod.parse_date(css, offset, length)


def _shared_parse_str(css, offset, length, cfg) -> typeconv_mod.Parsed:
    # Strings stay in the CSS; both backends share the bookkeeping no-op.
    return typeconv_mod.parse_string_noop(css, offset, length)


REFERENCE = register_backend(ParseBackend(
    name="reference",
    chunk_vectors=_ref_chunk_vectors,
    replay_summaries=_ref_replay_summaries,
    partition=_jnp_partition,
    parse_field={
        "int32": _ref_parse_int,
        "float32": _ref_parse_float,
        "date": _ref_parse_date,
        "str": _shared_parse_str,
    },
    partition_impls=tuple(sorted(partition_mod.PARTITION_IMPLS)),
    default_partition_impl=lambda cfg: "scatter",
))


# ---------------------------------------------------------------------------
# pallas backend — kernels.dfa_scan + kernels.partition + kernels.numparse
# ---------------------------------------------------------------------------

def _block_chunks(cfg, c: int) -> int:
    return min(getattr(cfg, "block_chunks", DEFAULT_BLOCK_CHUNKS) or
               DEFAULT_BLOCK_CHUNKS, c)


def _pl_chunk_vectors(chunks: jax.Array, cfg) -> jax.Array:
    from repro.kernels.dfa_scan import dfa_scan

    bc = _block_chunks(cfg, chunks.shape[0])
    padded, n = pad_to_block(chunks, bc, PAD_BYTE)
    vecs = dfa_scan.chunk_vectors(
        padded, cfg.dfa, block_chunks=bc, interpret=cfg.interpret
    )
    return vecs[:n]


def _pl_replay_summaries(chunks: jax.Array, start: jax.Array, cfg):
    from repro.kernels.dfa_scan import dfa_scan

    bc = _block_chunks(cfg, chunks.shape[0])
    padded, n = pad_to_block(chunks, bc, PAD_BYTE)
    start_p, _ = pad_to_block(
        start.astype(jnp.int32), bc, cfg.dfa.start_state
    )
    classes, end_states, summ = dfa_scan.replay_fused(
        padded, start_p, cfg.dfa, block_chunks=bc, interpret=cfg.interpret
    )
    classes, end_states, summ = classes[:n], end_states[:n], summ[:n]
    summaries = offsets_mod.ChunkSummary(
        rec_count=summ[:, 0], col_tag=summ[:, 1], col_off=summ[:, 2]
    )
    return classes, end_states, _saw_invalid(end_states, cfg.dfa), summaries


def _pl_partition(col_tag, n_cols, impl, cfg) -> partition_mod.Partitioned:
    if impl != "kernel":  # explicit jnp impls stay available for comparison
        return partition_mod.PARTITION_IMPLS[impl](col_tag, n_cols)
    from repro.kernels.partition import ops as partition_ops

    return partition_ops.partition_tags(
        col_tag, n_cols, interpret=cfg.interpret
    )


def _fuse(cfg) -> bool:
    return getattr(cfg, "fuse_typeconv", True)


def _window_kw(cfg) -> Dict[str, int]:
    """Windowed-DMA knobs for the fused numparse path (see ParserConfig)."""
    return dict(window_rows=getattr(cfg, "window_rows", 0),
                window_bytes=getattr(cfg, "max_window_bytes", 0))


def _pl_parse_int(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_int_column(
            css, offset, length, width=cfg.int_width, interpret=cfg.interpret)
    return numparse_ops.parse_int_column_fused(
        css, offset, length, width=cfg.int_width, interpret=cfg.interpret,
        **_window_kw(cfg))


def _pl_parse_float(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_float_column(
            css, offset, length, width=cfg.float_width, interpret=cfg.interpret)
    return numparse_ops.parse_float_column_fused(
        css, offset, length, width=cfg.float_width, interpret=cfg.interpret,
        **_window_kw(cfg))


def _pl_parse_date(css, offset, length, cfg) -> typeconv_mod.Parsed:
    from repro.kernels.numparse import ops as numparse_ops

    if not _fuse(cfg):
        return numparse_ops.parse_date_column(
            css, offset, length, interpret=cfg.interpret)
    return numparse_ops.parse_date_column_fused(
        css, offset, length, interpret=cfg.interpret, **_window_kw(cfg))


def _pl_typeconv_path(cfg) -> str:
    if not _fuse(cfg):
        return "unfused"
    from repro.kernels.numparse import ops as numparse_ops

    if getattr(cfg, "window_rows", 0) == numparse_ops.WHOLE_CSS:
        return "fused-wholecss"
    return "fused-windowed"


PALLAS = register_backend(ParseBackend(
    name="pallas",
    chunk_vectors=_pl_chunk_vectors,
    replay_summaries=_pl_replay_summaries,
    partition=_pl_partition,
    parse_field={
        "int32": _pl_parse_int,
        "float32": _pl_parse_float,
        "date": _pl_parse_date,
        "str": _shared_parse_str,
    },
    partition_impls=tuple(sorted(partition_mod.PARTITION_IMPLS)) + ("kernel",),
    # "auto" resolution: the radix kernel when compiling for real hardware;
    # under interpret=True (CPU containers/CI) the kernel runs op-by-op in
    # the Pallas interpreter, where XLA's jit-fused radix pass (scatter2) is
    # strictly faster — the kernel stays selectable (partition_impl="kernel")
    # and is pinned bit-identical by the parity/fuzz/golden suites.
    default_partition_impl=lambda cfg: "scatter2" if cfg.interpret else "kernel",
    typeconv_path=_pl_typeconv_path,
))
