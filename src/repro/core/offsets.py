"""Record and column identification (paper §3.2).

Per-symbol record ids are an exclusive prefix sum over the record-delimiter
bitmap.  Column ids need the paper's (abs/rel) semigroup: a chunk that saw a
record delimiter publishes an *absolute* column offset (count of field
delimiters after its last record delimiter), anything else publishes a
*relative* count that accumulates onto its predecessor:

    (a_t, a_o) ⊕ (b_t, b_o) = (b_t, b_o)            if b_t == ABS
                              (a_t, a_o + b_o)       otherwise

Two granularities are implemented:

  * symbol-level, via cumulative sums + a running "last record delimiter"
    cummax — the flattened equivalent used inside a single device, and
  * chunk-level, the paper-faithful summaries consumed by the distributed
    parser's cross-device scan.

Both are cross-checked in tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dfa import FIELD_DELIM, RECORD_DELIM

REL = 0
ABS = 1


class SymbolIds(NamedTuple):
    record_id: jax.Array  # (N,) int32 — record each symbol belongs to
    column_id: jax.Array  # (N,) int32 — column each symbol belongs to
    n_records: jax.Array  # () int32


def symbol_ids(classes: jax.Array) -> SymbolIds:
    """Record/column id per symbol from the flattened class stream ``(N,)``.

    Delimiters belong to the field/record they terminate, matching the
    paper's tagging (Fig. 4): a record delimiter's column id is its record's
    last column index.
    """
    classes = classes.reshape(-1)
    n = classes.shape[0]
    is_rec = classes == RECORD_DELIM
    is_fld = classes == FIELD_DELIM

    rec_incl = jnp.cumsum(is_rec.astype(jnp.int32))
    record_id = rec_incl - is_rec.astype(jnp.int32)  # exclusive

    # Column = (# field delims strictly before i) − (# field delims at or
    # before the last record delimiter strictly before i).
    idx = jnp.arange(n, dtype=jnp.int32)
    fld_incl = jnp.cumsum(is_fld.astype(jnp.int32))
    fld_excl = fld_incl - is_fld.astype(jnp.int32)
    last_rec_incl = jax.lax.cummax(jnp.where(is_rec, idx, -1))
    last_rec_excl = jnp.concatenate([jnp.full((1,), -1, jnp.int32), last_rec_incl[:-1]])
    base = jnp.where(last_rec_excl >= 0, fld_incl[jnp.clip(last_rec_excl, 0)], 0)
    column_id = fld_excl - base
    return SymbolIds(record_id, column_id, rec_incl[-1] if n else jnp.int32(0))


class ChunkSummary(NamedTuple):
    """Per-chunk offset summary (paper Fig. 4, the "abs"/"rel" rows)."""

    rec_count: jax.Array  # (C,) int32 — record delimiters in chunk
    col_tag: jax.Array    # (C,) int32 — ABS iff chunk contains a record delim
    col_off: jax.Array    # (C,) int32 — column offset (absolute or relative)


def chunk_summaries(classes: jax.Array) -> ChunkSummary:
    """Summarise per-chunk class codes ``(C, K)`` into scan elements."""
    is_rec = classes == RECORD_DELIM
    is_fld = classes == FIELD_DELIM
    rec_count = is_rec.sum(axis=1).astype(jnp.int32)
    has_rec = rec_count > 0

    # Zero field-delimiter bits at or before the last record delimiter
    # (paper: "zeroing all bits of the column delimiter bitmap index that
    # precede the last set bit in the record delimiter bitmap index").
    k = classes.shape[1]
    pos = jnp.arange(k, dtype=jnp.int32)
    last_rec = jnp.max(jnp.where(is_rec, pos, -1), axis=1)  # (C,)
    after = pos[None, :] > last_rec[:, None]
    fld_after = (is_fld & after).sum(axis=1).astype(jnp.int32)
    fld_all = is_fld.sum(axis=1).astype(jnp.int32)

    col_tag = jnp.where(has_rec, ABS, REL).astype(jnp.int32)
    col_off = jnp.where(has_rec, fld_after, fld_all)
    return ChunkSummary(rec_count, col_tag, col_off)


def combine_col(a, b):
    """The paper's associative column-offset operator (elementwise batched)."""
    a_t, a_o = a
    b_t, b_o = b
    t = jnp.where(b_t == ABS, b_t, a_t)
    o = jnp.where(b_t == ABS, b_o, a_o + b_o)
    return (t, o)


class ChunkOffsets(NamedTuple):
    rec_offset: jax.Array  # (C,) int32 — records before chunk start
    col_tag: jax.Array     # (C,) int32 — ABS once any predecessor saw a record delim
    col_offset: jax.Array  # (C,) int32 — column index at chunk start


def scan_chunk_offsets(summ: ChunkSummary) -> ChunkOffsets:
    """Exclusive scans giving each chunk its record and column offsets."""
    c = summ.rec_count.shape[0]
    rec_off = jnp.cumsum(summ.rec_count) - summ.rec_count

    t_inc, o_inc = jax.lax.associative_scan(
        combine_col, (summ.col_tag, summ.col_off), axis=0
    )
    # Exclusive shift seeded with (REL, 0): the input's first chunk starts at
    # column 0 of record 0.
    zero = jnp.zeros((1,), jnp.int32)
    col_tag = jnp.concatenate([zero + REL, t_inc[:-1]])
    col_off = jnp.concatenate([zero, o_inc[:-1]])
    return ChunkOffsets(rec_off.astype(jnp.int32), col_tag, col_off)


def fold_summary(summ: ChunkSummary):
    """Reduce a shard's chunk summaries to one summary triple.

    Cross-device building block: the distributed parser all-gathers one
    (rec_count, col_tag, col_off) triple per device — O(devices) bytes total,
    independent of input size.
    """
    rec = summ.rec_count.sum().astype(jnp.int32)

    def body(carry, x):
        return combine_col(carry, x), None

    (t, o), _ = jax.lax.scan(
        body,
        (jnp.int32(REL), jnp.int32(0)),
        (summ.col_tag, summ.col_off),
    )
    return rec, t, o


def symbol_ids_from_chunks(
    classes: jax.Array, offs: ChunkOffsets
) -> SymbolIds:
    """Per-symbol ids using chunk offsets (two-level form of ``symbol_ids``).

    ``classes``: ``(C, K)``.  Within each chunk, record/column ids are local
    scans seeded by the chunk's offsets; the column seed only applies until
    the chunk's own first record delimiter (after which ids are chunk-local
    absolutes).
    """
    c, k = classes.shape
    is_rec = classes == RECORD_DELIM
    is_fld = classes == FIELD_DELIM

    rec_local_incl = jnp.cumsum(is_rec.astype(jnp.int32), axis=1)
    rec_local_excl = rec_local_incl - is_rec.astype(jnp.int32)
    record_id = offs.rec_offset[:, None] + rec_local_excl

    pos = jnp.arange(k, dtype=jnp.int32)
    fld_incl = jnp.cumsum(is_fld.astype(jnp.int32), axis=1)
    fld_excl = fld_incl - is_fld.astype(jnp.int32)
    # Last record delimiter strictly before each position, within the chunk.
    last_rec_incl = jax.lax.cummax(jnp.where(is_rec, pos[None, :], -1), axis=1)
    last_rec_excl = jnp.concatenate(
        [jnp.full((c, 1), -1, jnp.int32), last_rec_incl[:, :-1]], axis=1
    )
    base = jnp.where(
        last_rec_excl >= 0,
        jnp.take_along_axis(fld_incl, jnp.clip(last_rec_excl, 0), axis=1),
        0,
    )
    local_col = fld_excl - base
    # Until the first in-chunk record delimiter, add the chunk's column seed.
    before_first_rec = last_rec_excl < 0
    column_id = jnp.where(before_first_rec, offs.col_offset[:, None] + local_col, local_col)

    n_records = offs.rec_offset[-1] + rec_local_incl[-1, -1] if c else jnp.int32(0)
    return SymbolIds(record_id.reshape(-1), column_id.reshape(-1), n_records)
