"""State-transition vectors and their associative composite (paper §3.1).

A chunk's *state-transition vector* ``v`` satisfies ``v[s] = final state of a
DFA that entered the chunk in state s``.  The composite

    (a ∘ b)[s] = b[a[s]]

is associative, so an exclusive ``associative_scan`` over per-chunk vectors
yields every chunk's true start state with O(log n_chunks) depth and zero
sequential work — the paper's core contribution.

Two interchangeable composite implementations are provided:

  * ``compose`` — gather form ``take_along_axis(b, a)``; O(S) work per pair,
    runs on the TPU VPU.
  * ``compose_matmul`` — one-hot boolean-matrix product; O(S²) MACs per pair
    but lands on the MXU.  ``M[i, j] = 1 iff v[i] == j`` and function
    composition "apply a, then b" is exactly ``A @ B``.

Which one wins is workload/hardware dependent; ``benchmarks/bench_scan.py``
and EXPERIMENTS.md §Perf carry the measurements.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfa import Dfa


def byte_groups(raw: jax.Array, dfa: Dfa) -> jax.Array:
    """Map raw bytes ``(…,) uint8`` to symbol groups via the 256-entry LUT.

    jnp reference path; the Pallas kernel replaces this with broadcast
    compares against ``dfa.group_bytes`` (TPU analogue of the paper's SWAR
    matching, see kernels/dfa_scan).
    """
    lut = jnp.asarray(dfa.group_of)
    return lut[raw.astype(jnp.int32)]


def identity_vector(n_states: int, dtype=jnp.int32) -> jax.Array:
    return jnp.arange(n_states, dtype=dtype)


def compose(a: jax.Array, b: jax.Array) -> jax.Array:
    """Composite of state-transition vectors: ``(a ∘ b)[s] = b[a[s]]``.

    Shapes ``(..., S)``; leading dims broadcast elementwise (as required by
    ``lax.associative_scan``).
    """
    return jnp.take_along_axis(b, a.astype(jnp.int32), axis=-1)


def vectors_to_matrices(vecs: jax.Array, n_states: int, dtype=jnp.float32) -> jax.Array:
    """One-hot encode ``(..., S)`` vectors into ``(..., S, S)`` matrices."""
    return jax.nn.one_hot(vecs, n_states, dtype=dtype)


def matrices_to_vectors(mats: jax.Array) -> jax.Array:
    """Invert ``vectors_to_matrices`` (rows are one-hot)."""
    n = mats.shape[-1]
    return (mats @ jnp.arange(n, dtype=mats.dtype)).astype(jnp.int32)


def compose_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """MXU form of the composite: one-hot ``A @ B``."""
    return jnp.matmul(a, b)


def chunk_transition_vectors(groups: jax.Array, dfa: Dfa) -> jax.Array:
    """Per-chunk state-transition vectors.

    Args:
      groups: ``(n_chunks, chunk_bytes) int32`` symbol groups.
    Returns:
      ``(n_chunks, n_states) int32`` vectors — the |S| simultaneous DFA
      instances of paper §3.1, vectorised across chunks instead of across
      GPU threads.
    """
    n_chunks = groups.shape[0]
    s = dfa.n_states
    t_flat = jnp.asarray(dfa.transition.reshape(-1).astype(np.int32))
    n_groups = dfa.n_groups

    def step(vec, g_col):
        # vec: (n_chunks, S); g_col: (n_chunks,)
        new = t_flat[vec * n_groups + g_col[:, None]]
        return new, None

    init = jnp.broadcast_to(identity_vector(s), (n_chunks, s))
    vec, _ = jax.lax.scan(step, init, groups.T)
    return vec


def exclusive_scan_vectors(vecs: jax.Array, use_matmul: bool = False) -> jax.Array:
    """Exclusive composite scan over chunk vectors ``(n_chunks, S)``.

    Row ``i`` of the result maps "state the sequential DFA was in at the start
    of the input" → "state at the start of chunk i" (paper Fig. 3).
    """
    n_states = vecs.shape[-1]
    if use_matmul:
        mats = vectors_to_matrices(vecs, n_states)
        inc = jax.lax.associative_scan(compose_matmul, mats, axis=0)
        inc = matrices_to_vectors(inc)
    else:
        inc = jax.lax.associative_scan(compose, vecs, axis=0)
    ident = jnp.broadcast_to(identity_vector(n_states), (1, n_states))
    return jnp.concatenate([ident, inc[:-1]], axis=0)


def fold_vectors(vecs: jax.Array) -> jax.Array:
    """Composite-reduce ``(n_chunks, S) → (S,)`` (log-depth tree).

    Used by the distributed parser to summarise a device shard before the
    cross-device scan.
    """
    n = vecs.shape[0]
    # Pad to a power of two with identity vectors, then tree-reduce.
    n_pad = 1 << max(1, (n - 1).bit_length())
    ident = jnp.broadcast_to(identity_vector(vecs.shape[-1]), (n_pad - n, vecs.shape[-1]))
    v = jnp.concatenate([vecs, ident], axis=0)
    while v.shape[0] > 1:
        v = compose(v[0::2], v[1::2])
    return v[0]


def start_states(scanned: jax.Array, dfa: Dfa, initial_state: jax.Array | None = None) -> jax.Array:
    """Read each chunk's true start state out of the scanned vectors.

    ``initial_state`` overrides the DFA's start state — used by the streaming
    parser, which threads the previous partition's end state through
    (paper §4.4 carry-over).
    """
    if initial_state is None:
        initial_state = jnp.int32(dfa.start_state)
    return jnp.take_along_axis(
        scanned, jnp.broadcast_to(initial_state, (scanned.shape[0], 1)).astype(jnp.int32), axis=1
    )[:, 0]


def replay(
    groups: jax.Array,
    start: jax.Array,
    dfa: Dfa,
):
    """Second pass (paper §3.1 end): re-simulate one DFA instance per chunk
    from its now-known start state, emitting the symbol-class code stream.

    Args:
      groups: ``(n_chunks, chunk_bytes) int32``.
      start:  ``(n_chunks,) int32`` true start states.
    Returns:
      classes: ``(n_chunks, chunk_bytes) uint8`` symbol classes.
      states:  ``(n_chunks,) int32`` end state per chunk.
      saw_invalid: ``(n_chunks,) bool`` — whether the invalid sink was hit.
    """
    t_flat = jnp.asarray(dfa.transition.reshape(-1).astype(np.int32))
    e_flat = jnp.asarray(dfa.emission.reshape(-1).astype(np.int32))
    n_groups = dfa.n_groups
    inv = dfa.invalid_state

    def step(state, g_col):
        idx = state * n_groups + g_col
        cls = e_flat[idx]
        new = t_flat[idx]
        return new, cls

    final, classes = jax.lax.scan(step, start.astype(jnp.int32), groups.T)
    classes = classes.T.astype(jnp.uint8)
    if inv is None:
        saw_invalid = jnp.zeros(final.shape, bool)
    else:
        # The sink is absorbing, so "ever hit" == "ended there".
        saw_invalid = final == inv
    return classes, final, saw_invalid


@functools.partial(jax.jit, static_argnames=("dfa", "use_matmul"))
def transition_pipeline(raw_chunks: jax.Array, dfa: Dfa, use_matmul: bool = False):
    """Fused convenience entry: bytes → (classes, end_states, saw_invalid).

    ``raw_chunks``: ``(n_chunks, chunk_bytes) uint8``.
    """
    groups = byte_groups(raw_chunks, dfa)
    vecs = chunk_transition_vectors(groups, dfa)
    scanned = exclusive_scan_vectors(vecs, use_matmul=use_matmul)
    start = start_states(scanned, dfa)
    return replay(groups, start, dfa)


def sequential_reference(raw: np.ndarray, dfa: Dfa):
    """Pure-numpy sequential oracle: exactly what a one-thread parser does.

    Used by tests to validate the parallel pipeline symbol-for-symbol.
    """
    state = dfa.start_state
    classes = np.zeros(raw.shape[0], np.uint8)
    states = np.zeros(raw.shape[0], np.int32)
    for i, b in enumerate(raw):
        g = dfa.group_of[b]
        states[i] = state
        classes[i] = dfa.emission[state, g]
        state = dfa.transition[state, g]
    return classes, states, state
