"""Shared parse-pipeline stages (paper §3.1–§3.3) — the single composition
point every driver runs through.

``Parser`` (single device), ``DistributedParser`` (shard_map over a mesh)
and ``StreamSession``/``StreamingParser`` (partition-pipelined, device-
resident carry) all compose exactly these functions; the byte-level hot
loops inside them come from the
:class:`repro.core.backends.ParseBackend` selected by
``ParserConfig.backend``:

    determine_contexts  — §3.1 context determination + replay, fused with
                          the §3.2 per-chunk offset summaries
    identify_symbols    — §3.2 record/column ids from the chunk summaries
    materialize         — the §3.2/§3.3 back half as ONE backend-owned
                          stage: tagging → stable partition → field index
                          → per-dtype type conversion.  What to build is
                          described by a static :class:`MaterializePlan`
                          (``plan_materialize``); *how* each step runs is
                          the backend's call (``backend.partition``,
                          ``backend.parse_field``) — so fusing partition
                          and conversion into kernels is a backend change,
                          never a driver change.
    locate_carry        — §4.4 carry-over boundary for streaming

The whole per-partition pipeline is itself planned and executed the same
way: :func:`plan_parse` resolves a config into a static :class:`ParsePlan`
(the :class:`MaterializePlan` plus the §4.3 validation contract), and
:func:`execute_plan` runs context-determination → symbol-ids → materialize
→ validation → carry location as one traced function returning a
:class:`ParseResult`.  ``Parser.parse_chunks`` is one ``jax.jit`` of
``execute_plan``; the streaming engine (``core/streaming.py``) wraps the
same executor in a donated carry-prepend/carry-extract step and ``vmap``s
it over a stream axis — every driver executes the *same* plan, so a plan
change (new stage, new fusion) propagates to all of them at once.

Materialization is a backend responsibility, not driver glue: drivers pass
the plan through and receive a :class:`ColumnBatch` plus converted values.
On ``backend="pallas"`` the partition runs the two-pass radix kernel
(``kernels.partition``) and every typed column converts in a fused
gather+convert kernel (``kernels.numparse``) that indexes the CSS in-kernel
— no XLA ``take``/gather between the field index and conversion.  The
fused kernels DMA one contiguous CSS *window* per row block (sorted
offsets make windows contiguous; ``cfg.window_rows`` /
``cfg.max_window_bytes``), so VMEM never holds the whole CSS and per-parse
input size is unbounded by VMEM capacity; see ``docs/ARCHITECTURE.md``.

Driver-specific glue stays in the drivers: the cross-device scans of
``DistributedParser`` plug in via a :class:`ParseStitch` — three hooks
(transition-composite prefix, stitched chunk offsets + shard seeds, and a
cross-shard validation reduction) that let every shard of a mesh run this
*same* ``execute_plan`` composition end to end (conversion included) while
this module stays mesh-agnostic.  ``plan_parse(convert=False)`` remains
available for index-only shard export (each host converts its own batch —
the pre-mesh-native contract, still used by the dry-run roofline cells).
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fields as fields_mod
from repro.core import offsets as offsets_mod
from repro.core import partition as partition_mod
from repro.core import tagging as tagging_mod
from repro.core import typeconv as typeconv_mod
from repro.core import validation as validation_mod
from repro.core.backends import ParseBackend
from repro.core.dfa import RECORD_DELIM


class ParseContext(NamedTuple):
    """§3.1/§3.2 output: every chunk knows its context and its summaries."""

    classes: jax.Array                    # (C, K) uint8 symbol classes
    end_states: jax.Array                 # (C,) int32 — per-chunk end state
    saw_invalid: jax.Array                # (C,) bool — invalid sink hit
    summaries: offsets_mod.ChunkSummary   # per-chunk §3.2 scan elements


class ColumnBatch(NamedTuple):
    """§3.3 output: partitioned CSS plus its field index."""

    css: jax.Array        # (N,) uint8 partitioned symbols
    col_start: jax.Array  # (n_cols+1,) int32
    col_count: jax.Array  # (n_cols+1,) int32
    findex: fields_mod.FieldIndex


class MaterializePlan(NamedTuple):
    """Static description of the §3.3 back half (what ``materialize`` builds).

    Everything here is hashable config, baked into the jitted closure:
    the tagging output layout, the partition choice (already resolved —
    never ``"auto"``), and which columns convert to which dtype.  Building
    the plan up front keeps driver call sites one line and makes the
    fused/unfused choice a plan+backend property instead of driver glue.
    """

    tagging: str                                # tagged | inline | vector
    partition_impl: str                         # argsort|scatter|scatter2|kernel
    n_cols: int
    max_records: int
    selected: Optional[Tuple[bool, ...]]        # None = every column selected
    convert: Tuple[Tuple[str, int, str], ...]   # (name, schema index, dtype)
    typeconv_path: str = "reference"            # reference | unfused |
                                                # fused-windowed | fused-wholecss


def plan_materialize(cfg, backend: ParseBackend, *, convert: bool = True
                     ) -> MaterializePlan:
    """Resolve ``cfg`` into a :class:`MaterializePlan` for ``backend``.

    ``partition_impl="auto"`` becomes the backend's config-aware default
    (on ``pallas``: the radix kernel when compiling for real hardware, the
    jit-fused jnp radix pass under ``interpret=True``); explicit impls are
    validated against ``backend.partition_impls`` so typos and
    backend-foreign impls fail at config time, not under jit.  The
    windowed-DMA knobs (``cfg.window_rows`` / ``cfg.max_window_bytes``,
    pallas fused path) are range-checked here for the same reason, and the
    resolved conversion strategy is recorded as ``plan.typeconv_path``
    (``reference`` / ``unfused`` / ``fused-windowed`` / ``fused-wholecss``)
    so benchmarks and debug output can name the path a config actually
    runs.  With ``convert=False`` the plan builds the CSS + field index
    only (the distributed driver's per-shard contract).
    """
    impl = cfg.partition_impl
    if impl == "auto":
        impl = backend.default_partition_impl(cfg)
    if impl not in backend.partition_impls:
        raise ValueError(
            f"partition_impl {impl!r} not supported by backend "
            f"{backend.name!r}; available: {backend.partition_impls}"
        )
    window_rows = getattr(cfg, "window_rows", 0)
    if window_rows < -1:
        raise ValueError(
            f"window_rows must be ≥ -1 (-1 = whole-CSS fused kernels, "
            f"0 = kernel default), got {window_rows}"
        )
    max_window_bytes = getattr(cfg, "max_window_bytes", 0)
    if max_window_bytes < 0:
        raise ValueError(
            f"max_window_bytes must be ≥ 0 (0 = auto-size), got {max_window_bytes}"
        )
    partition_block_tags = getattr(cfg, "partition_block_tags", 0)
    if partition_block_tags < 0:
        raise ValueError(
            f"partition_block_tags must be ≥ 0 (0 = kernel default), "
            f"got {partition_block_tags}"
        )
    if getattr(cfg, "fused_max_bytes", 0) < 0:
        raise ValueError(
            f"fused_max_bytes must be ≥ 0 (0 = backend default), "
            f"got {cfg.fused_max_bytes}"
        )
    selected = None
    if not all(c.selected for c in cfg.schema.columns):
        selected = tuple(bool(c.selected) for c in cfg.schema.columns)
    conv: Tuple[Tuple[str, int, str], ...] = ()
    if convert:
        conv = tuple(
            (col.name, c, col.dtype)
            for c, col in enumerate(cfg.schema.columns) if col.selected
        )
    return MaterializePlan(
        tagging=cfg.tagging,
        partition_impl=impl,
        n_cols=cfg.schema.n_cols,
        max_records=cfg.max_records,
        selected=selected,
        convert=conv,
        typeconv_path=backend.typeconv_path(cfg),
    )


class ParseResult(NamedTuple):
    """Everything one parsed partition produces (all device arrays).

    Returned by :func:`execute_plan`; re-exported as
    ``repro.core.parser.ParseResult`` (the public name).
    """

    css: jax.Array                       # (N,) uint8 partitioned symbols
    col_start: jax.Array                 # (n_cols+1,) int32
    col_count: jax.Array                 # (n_cols+1,) int32
    field_offset: jax.Array              # (n_cols, max_records) int32
    field_length: jax.Array              # (n_cols, max_records) int32
    field_present: jax.Array             # (n_cols, max_records) bool — field
                                         # materialised in input (disambiguates
                                         # empty-but-terminated from absent;
                                         # the distributed host assembly keys
                                         # boundary-piece recovery on it)
    values: Dict[str, typeconv_mod.Parsed]
    validation: validation_mod.Validation
    end_state: jax.Array                 # () int32 — carried into next partition
    last_record_end: jax.Array           # () int32 — byte pos of last record
                                         # delimiter (−1 if none); the
                                         # streaming carry-over boundary


class ParsePlan(NamedTuple):
    """Static description of the WHOLE per-partition parse step.

    ``plan_parse`` resolves a config once — the materialize sub-plan plus
    the §4.3 validation contract plus the staged-vs-fused execution choice —
    and ``execute_plan`` runs it.  Like :class:`MaterializePlan`, everything
    here is hashable config baked into the jitted closure; drivers build the
    plan at construction time so typos fail fast and every partition of a
    stream reuses one executable.

    ``execute_path`` records the *resolved* execution tier (``"staged"`` =
    the stage composition below; ``"fused"`` = the backend's whole-pipeline
    ``execute`` override, still subject to the trace-time
    ``backend.fused_max_bytes`` cap — :func:`resolved_execute_path` names
    the tier a concrete input size actually takes) and ``path_reason`` says
    why, replacing silent resolution with an inspectable decision.
    """

    materialize: MaterializePlan
    expected_columns: Optional[int]   # None = skip the §4.3 column-count check
    execute_path: str = "staged"      # staged | fused
    path_reason: str = "fuse_pipeline not requested"


class ParseStitch(NamedTuple):
    """Cross-shard stitching hooks for running :func:`execute_plan` under
    ``shard_map`` (the distributed driver's glue, paper Fig. 4 at mesh
    granularity).

    Each hook exchanges only O(devices · |S|) summary data — never anything
    input-sized — which is the whole scale-out argument: per-shard work is
    N/D bytes, the stitching collectives are constant.

    ``prefix_fn(vecs (C,S)) -> (S,)``
        Exclusive cross-device composite of the §3.1 transition summaries,
        applied before the local exclusive scan (one all-gather of one
        ``(S,)`` vector per device).
    ``offsets_fn(summaries) -> (ChunkOffsets, rec_base (), col_seed (), n_total ())``
        Globally stitched §3.2 chunk offsets plus the shard seeds: the
        first global record id in the shard, the column offset entering the
        shard (field delimiters since the last record delimiter before it),
        and the global record count (one all-gather of one summary triple
        per device).
    ``validation_fn(fields_per_rec (M,), n_local (), end_state (), saw_invalid (), n_total ()) -> Validation``
        Cross-shard §4.3 reduction: ``fields_per_rec`` is the shard's
        *seed-corrected* per-record column counts on shard-local ids (the
        boundary record's count already includes ``col_seed``), and the hook
        reduces the global flags (accepting end state on the last shard,
        min/max columns, conformance) across the mesh axis — O(devices)
        scalars.  ``record_ok`` in the returned Validation stays per-shard.

    With a stitch in place the executor materializes with *shard-local*
    record ids (``record_id - rec_base``) so the field index stays small;
    ``rec_base`` restores global ids.
    """

    prefix_fn: Callable
    offsets_fn: Callable
    validation_fn: Callable


def plan_parse(cfg, backend: ParseBackend, *, convert: bool = True) -> ParsePlan:
    """Resolve ``cfg`` into the full per-partition :class:`ParsePlan`.

    ``convert=False`` plans an index-only materialization (the distributed
    driver's per-shard contract: shards export the CSS + field index and
    each host converts its own batch).

    ``cfg.fuse_pipeline=True`` requests the backend's whole-pipeline fused
    executor (``backend.execute``); the request resolves here — softly, with
    the decision and its reason recorded on the plan — because the fallback
    tiers are part of the design (mirroring the windowed numparse kernels):
    backends without a fused executor, and index-only plans (the megakernel
    produces typed columns, which ``convert=False`` drivers must not pay
    for), stay staged.
    """
    # Fail fast on malformed DFA tables (a hand-rolled or third-party
    # format whose groups/PAD/record-delimiter contract is broken would
    # otherwise surface as wrong parses deep inside a traced kernel).
    # Registered formats (core/formats.py) were validated at registration;
    # this covers configs built around ad-hoc Dfa instances too.
    cfg.dfa.validate_tables()
    path, reason = "staged", "fuse_pipeline not requested"
    if getattr(cfg, "fuse_pipeline", False):
        if backend.execute is None:
            reason = f"backend {backend.name!r} has no fused executor"
        elif not convert:
            reason = "index-only plan (convert=False) stays staged"
        else:
            path, reason = "fused", "fuse_pipeline=True"
    return ParsePlan(
        materialize=plan_materialize(cfg, backend, convert=convert),
        expected_columns=cfg.schema.n_cols if cfg.validate_columns else None,
        execute_path=path,
        path_reason=reason,
    )


def fused_cap(cfg, backend: ParseBackend) -> int:
    """The fused path's effective byte cap: the config override
    (``cfg.fused_max_bytes``, a tunable — the real ceiling is a VMEM
    property only measurable on hardware) or the backend's static default."""
    return int(getattr(cfg, "fused_max_bytes", 0) or 0) or backend.fused_max_bytes


def resolved_execute_path(plan: ParsePlan, backend: ParseBackend,
                          n_bytes: int, cfg=None) -> str:
    """The execution tier ``execute_plan`` actually takes for an input of
    ``n_bytes`` — the plan's choice plus the static byte cap (benchmarks
    and debug output report this instead of guessing).  ``cfg`` enables the
    per-config cap override; without it the backend default applies."""
    if plan.execute_path != "fused":
        return "staged"
    cap = fused_cap(cfg, backend) if cfg is not None else backend.fused_max_bytes
    return "fused" if n_bytes <= cap else "staged"


def dfa_key(dfa) -> Tuple:
    """Content fingerprint of a :class:`~repro.core.dfa.Dfa`.

    ``Dfa`` hashes by identity (its tables are numpy arrays), which is right
    for jit caching within a process but wrong for a serving registry: two
    tenants constructing ``make_csv_dfa()`` independently get *equal* DFAs
    in different objects.  This keys on the table bytes instead.
    """
    return (
        dfa.transition.tobytes(), dfa.emission.tobytes(),
        dfa.group_of.tobytes(), tuple(dfa.group_bytes),
        int(dfa.start_state), dfa.accept.tobytes(), dfa.invalid_state,
    )


def plan_key(cfg, backend: Optional[ParseBackend] = None, *,
             convert: bool = True) -> Tuple:
    """Stable, hashable fingerprint of the executable ``cfg`` compiles to.

    Two configs with equal plan keys trace to bit-identical jitted parse
    steps — same DFA *content* (not object identity), same schema, same
    static capacities, same resolved :class:`ParsePlan`, same backend knobs
    (``backend.config_key``) — so a serving registry can share ONE compiled
    ``Parser``/``StreamSession`` among the tenants that produce them.
    Unequal keys may still compile identically (the key is conservative);
    that only costs a duplicate executable, never a wrong share.
    """
    if backend is None:
        from repro.core import backends as backends_mod
        backend = backends_mod.get_backend(cfg.backend)
    plan = plan_parse(cfg, backend, convert=convert)
    return (
        backend.name,
        backend.config_key(cfg),
        dfa_key(cfg.dfa),
        tuple((c.name, c.dtype, bool(c.selected)) for c in cfg.schema.columns),
        cfg.chunk_size,
        cfg.use_matmul_scan,
        cfg.int_width,
        cfg.float_width,
        plan,
    )


def execute_plan(
    raw_chunks: jax.Array,
    plan: ParsePlan,
    cfg,
    backend: ParseBackend,
    initial_state: Optional[jax.Array] = None,
    stitch: Optional[ParseStitch] = None,
) -> ParseResult:
    """Run one partition through the full §3.1→§4.4 pipeline per ``plan``.

    The single traced composition point every driver executes:
    ``Parser.parse_chunks`` jits exactly this; the streaming engine wraps it
    in its donated carry step (prepend → ``execute_plan`` → extract) and
    ``vmap``s that over a stream axis; the distributed driver runs it on
    every shard under ``shard_map`` with a :class:`ParseStitch` supplying
    the cross-device prefixes/seeds/reductions.  ``initial_state`` overrides
    the DFA start state (the mid-record partition-boundary hook).
    """
    if initial_state is None:
        initial_state = jnp.int32(cfg.dfa.start_state)

    # Whole-pipeline fusion: when the plan resolved to the backend's fused
    # executor AND the partition fits the backend's static VMEM byte cap,
    # hand the entire replay→tag→partition→convert composition to the
    # megakernel.  Both conditions are trace-time Python (shape + plan), so
    # the staged composition below is the statically bounded fallback tier
    # — same design as the windowed numparse cap, one level up.
    if plan.execute_path == "fused" and raw_chunks.size <= fused_cap(cfg, backend):
        return backend.execute(raw_chunks, plan, cfg, initial_state,
                               stitch=stitch)

    # §3.1/§3.2 — parsing context + fused per-chunk offset summaries (the
    # stitch plugs the cross-device composite prefix into the scan).
    ctx = determine_contexts(
        raw_chunks, cfg, backend, initial_state=initial_state,
        prefix_fn=None if stitch is None else stitch.prefix_fn,
    )
    end_state = ctx.end_states[-1]

    # §3.2 — record/column identification from the summaries.  Under a
    # stitch the chunk offsets are globally seeded and materialization runs
    # on shard-local record ids (rec_base restores global ids).
    if stitch is None:
        ids = identify_symbols(ctx)
        rec_for_index = ids.record_id
    else:
        offs, rec_base, col_seed, n_total = stitch.offsets_fn(ctx.summaries)
        ids = identify_symbols(ctx, chunk_offsets=offs)
        rec_for_index = ids.record_id - rec_base

    # §3.2/§3.3 — backend-owned materialization: tagging, stable partition,
    # field index, type conversion (one shared stage, one static plan).
    cols, values = materialize(
        raw_chunks, ctx.classes, rec_for_index, ids.column_id,
        plan.materialize, cfg, backend,
    )

    # §4.3 — validation (stitched: local per-record column counts, with the
    # boundary record's count completed by the cross-device column seed,
    # reduced globally by the stitch hook).
    flat_classes = ctx.classes.reshape(-1)
    if stitch is None:
        val = validation_mod.validate(
            flat_classes, rec_for_index, end_state, ctx.saw_invalid, cfg.dfa,
            plan.materialize.max_records,
            expected_columns=plan.expected_columns,
        )
    else:
        fpr = validation_mod.fields_per_record(
            flat_classes, rec_for_index, plan.materialize.max_records
        ).at[0].add(col_seed)
        n_local = jnp.sum(flat_classes == RECORD_DELIM).astype(jnp.int32)
        val = stitch.validation_fn(
            fpr, n_local, end_state, jnp.any(ctx.saw_invalid), n_total
        )

    return ParseResult(
        css=cols.css,
        col_start=cols.col_start,
        col_count=cols.col_count,
        field_offset=cols.findex.offset,
        field_length=cols.findex.length,
        field_present=cols.findex.present,
        values=values,
        validation=val,
        end_state=end_state.astype(jnp.int32),
        last_record_end=locate_carry(flat_classes),
    )


def determine_contexts(
    chunks: jax.Array,
    cfg,
    backend: ParseBackend,
    initial_state: Optional[jax.Array] = None,
    prefix_fn=None,
) -> ParseContext:
    """§3.1: transition vectors → composite scan → replay (+§3.2 summaries).

    ``prefix_fn(vecs) -> (S,)`` supplies a cross-device exclusive composite
    (the distributed parser's all-gather stitch) applied before the local
    exclusive scan; ``initial_state`` overrides the DFA start state (the
    streaming carry-over hook).
    """
    from repro.core import transition as tr

    vecs = backend.chunk_vectors(chunks, cfg)
    scanned = tr.exclusive_scan_vectors(vecs, use_matmul=cfg.use_matmul_scan)
    if prefix_fn is not None:
        prefix = prefix_fn(vecs)
        scanned = tr.compose(jnp.broadcast_to(prefix, scanned.shape), scanned)
    start = tr.start_states(scanned, cfg.dfa, initial_state=initial_state)
    classes, end_states, saw_invalid, summaries = backend.replay_summaries(
        chunks, start, cfg
    )
    return ParseContext(classes, end_states, saw_invalid, summaries)


def identify_symbols(
    ctx: ParseContext,
    chunk_offsets: Optional[offsets_mod.ChunkOffsets] = None,
) -> offsets_mod.SymbolIds:
    """§3.2: per-symbol record/column ids from the chunk summaries.

    ``chunk_offsets`` overrides the local exclusive scan with externally
    stitched offsets (the distributed parser's cross-device prefixes).
    """
    if chunk_offsets is None:
        chunk_offsets = offsets_mod.scan_chunk_offsets(ctx.summaries)
    return offsets_mod.symbol_ids_from_chunks(ctx.classes, chunk_offsets)


def materialize(
    raw_chunks: jax.Array,
    classes: jax.Array,
    record_id: jax.Array,
    column_id: jax.Array,
    plan: MaterializePlan,
    cfg,
    backend: ParseBackend,
) -> Tuple[ColumnBatch, Dict[str, typeconv_mod.Parsed]]:
    """§3.2/§4.1 tagging → §3.3 stable partition → field index → typeconv.

    ``record_id`` is whatever the caller wants in the field index: global
    ids for the single-device parser, shard-local ids for the distributed
    one.  The partition and every per-dtype conversion dispatch through the
    backend (``backend.partition`` / ``backend.parse_field``); invalid
    numeric values are normalised to 0 so backends agree bit-for-bit (their
    Horner loops treat non-digit garbage differently, and garbage values
    are meaningless anyway — ``valid`` gates them).  ``str`` is exempt: its
    ``value`` is the field offset, which the export path may use regardless
    of validity.
    """
    n_cols = plan.n_cols
    flat_classes = classes.reshape(-1)

    selected = np.asarray(plan.selected) if plan.selected is not None else None
    tagged = tagging_mod.tag_symbols(
        raw_chunks, flat_classes, record_id, column_id, n_cols,
        plan.tagging, selected_mask=selected,
    )

    part = backend.partition(tagged.col_tag, n_cols, plan.partition_impl, cfg)
    if plan.tagging == "tagged":
        # delim_flag is structurally all-False in tagged mode: skip one
        # N-sized gather+write (EXPERIMENTS.md §Perf parser iteration)
        css, rec_sorted, col_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag
        )
        flag_sorted = None
    else:
        css, rec_sorted, col_sorted, flag_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag,
            tagged.delim_flag,
        )
    findex = fields_mod.field_index(
        plan.tagging, col_sorted, rec_sorted, part.col_start, n_cols,
        plan.max_records, term_flag=flag_sorted,
    )
    cols = ColumnBatch(css, part.col_start, part.col_count, findex)

    values: Dict[str, typeconv_mod.Parsed] = {}
    for name, c, dtype in plan.convert:
        p = backend.parse_field[dtype](
            css, findex.offset[c], findex.length[c], cfg
        )
        if dtype != "str":
            p = p._replace(value=jnp.where(p.valid, p.value, jnp.zeros_like(p.value)))
        values[name] = p
    return cols, values


def locate_carry(flat_classes: jax.Array) -> jax.Array:
    """§4.4: byte position of the last record delimiter (−1 if none) — the
    streaming carry-over boundary."""
    pos = jnp.arange(flat_classes.shape[0], dtype=jnp.int32)
    return jnp.max(jnp.where(flat_classes == RECORD_DELIM, pos, -1)).astype(jnp.int32)
