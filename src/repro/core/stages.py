"""Shared parse-pipeline stages (paper §3.1–§3.3) — the single composition
point every driver runs through.

``Parser`` (single device), ``DistributedParser`` (shard_map over a mesh)
and ``StreamingParser`` (partition-pipelined, via ``Parser``) all compose
exactly these functions; the byte-level hot loops inside them come from the
:class:`repro.core.backends.ParseBackend` selected by
``ParserConfig.backend``:

    determine_contexts  — §3.1 context determination + replay, fused with
                          the §3.2 per-chunk offset summaries
    identify_symbols    — §3.2 record/column ids from the chunk summaries
    build_columns       — §3.2/§4.1 tagging → §3.3 stable partition →
                          field index
    convert_types       — §3.3 type conversion (every dtype routed through
                          the backend's per-dtype ``parse_field`` table)
    locate_carry        — §4.4 carry-over boundary for streaming

Driver-specific glue stays in the drivers: the cross-device prefix scans of
``DistributedParser`` plug in via ``prefix_fn`` / ``chunk_offsets`` without
this module knowing about meshes.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fields as fields_mod
from repro.core import offsets as offsets_mod
from repro.core import partition as partition_mod
from repro.core import tagging as tagging_mod
from repro.core import typeconv as typeconv_mod
from repro.core.backends import ParseBackend
from repro.core.dfa import RECORD_DELIM


class ParseContext(NamedTuple):
    """§3.1/§3.2 output: every chunk knows its context and its summaries."""

    classes: jax.Array                    # (C, K) uint8 symbol classes
    end_states: jax.Array                 # (C,) int32 — per-chunk end state
    saw_invalid: jax.Array                # (C,) bool — invalid sink hit
    summaries: offsets_mod.ChunkSummary   # per-chunk §3.2 scan elements


class ColumnBatch(NamedTuple):
    """§3.3 output: partitioned CSS plus its field index."""

    css: jax.Array        # (N,) uint8 partitioned symbols
    col_start: jax.Array  # (n_cols+1,) int32
    col_count: jax.Array  # (n_cols+1,) int32
    findex: fields_mod.FieldIndex


def determine_contexts(
    chunks: jax.Array,
    cfg,
    backend: ParseBackend,
    initial_state: Optional[jax.Array] = None,
    prefix_fn=None,
) -> ParseContext:
    """§3.1: transition vectors → composite scan → replay (+§3.2 summaries).

    ``prefix_fn(vecs) -> (S,)`` supplies a cross-device exclusive composite
    (the distributed parser's all-gather stitch) applied before the local
    exclusive scan; ``initial_state`` overrides the DFA start state (the
    streaming carry-over hook).
    """
    from repro.core import transition as tr

    vecs = backend.chunk_vectors(chunks, cfg)
    scanned = tr.exclusive_scan_vectors(vecs, use_matmul=cfg.use_matmul_scan)
    if prefix_fn is not None:
        prefix = prefix_fn(vecs)
        scanned = tr.compose(jnp.broadcast_to(prefix, scanned.shape), scanned)
    start = tr.start_states(scanned, cfg.dfa, initial_state=initial_state)
    classes, end_states, saw_invalid, summaries = backend.replay_summaries(
        chunks, start, cfg
    )
    return ParseContext(classes, end_states, saw_invalid, summaries)


def identify_symbols(
    ctx: ParseContext,
    chunk_offsets: Optional[offsets_mod.ChunkOffsets] = None,
) -> offsets_mod.SymbolIds:
    """§3.2: per-symbol record/column ids from the chunk summaries.

    ``chunk_offsets`` overrides the local exclusive scan with externally
    stitched offsets (the distributed parser's cross-device prefixes).
    """
    if chunk_offsets is None:
        chunk_offsets = offsets_mod.scan_chunk_offsets(ctx.summaries)
    return offsets_mod.symbol_ids_from_chunks(ctx.classes, chunk_offsets)


def build_columns(
    raw_chunks: jax.Array,
    classes: jax.Array,
    record_id: jax.Array,
    column_id: jax.Array,
    cfg,
) -> ColumnBatch:
    """§3.2/§4.1 tagging → §3.3 stable partition → field index.

    ``record_id`` is whatever the caller wants in the field index: global
    ids for the single-device parser, shard-local ids for the distributed
    one.
    """
    n_cols = cfg.schema.n_cols
    flat_classes = classes.reshape(-1)

    selected = None
    if not all(c.selected for c in cfg.schema.columns):
        selected = np.asarray([c.selected for c in cfg.schema.columns])
    tagged = tagging_mod.tag_symbols(
        raw_chunks, flat_classes, record_id, column_id, n_cols,
        cfg.tagging, selected_mask=selected,
    )

    part = partition_mod.PARTITION_IMPLS[cfg.partition_impl](tagged.col_tag, n_cols)
    if cfg.tagging == "tagged":
        # delim_flag is structurally all-False in tagged mode: skip one
        # N-sized gather+write (EXPERIMENTS.md §Perf parser iteration)
        css, rec_sorted, col_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag
        )
        findex = fields_mod.field_index_tagged(
            col_sorted, rec_sorted, n_cols, cfg.max_records
        )
    else:
        css, rec_sorted, col_sorted, flag_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag,
            tagged.delim_flag,
        )
        findex = fields_mod.field_index_terminated(
            flag_sorted, col_sorted, rec_sorted, part.col_start, n_cols,
            cfg.max_records,
        )
    return ColumnBatch(css, part.col_start, part.col_count, findex)


def convert_types(
    css: jax.Array,
    findex: fields_mod.FieldIndex,
    cfg,
    backend: ParseBackend,
) -> Dict[str, typeconv_mod.Parsed]:
    """§3.3 type conversion per selected column.

    *Every* column dispatches through ``backend.parse_field[dtype]`` — on
    ``backend="pallas"`` int32/float32/date columns all run inside
    ``kernels.numparse`` Pallas kernels; there is no per-dtype jnp fallback
    on the hot path.  Invalid numeric values are normalised to 0 so backends
    agree bit-for-bit (their Horner loops treat non-digit garbage
    differently, and garbage values are meaningless anyway — ``valid`` gates
    them).  ``str`` is exempt: its ``value`` is the field offset, which the
    export path may use regardless of validity.
    """
    values: Dict[str, typeconv_mod.Parsed] = {}
    for c, col in enumerate(cfg.schema.columns):
        if not col.selected:
            continue
        off = findex.offset[c]
        ln = findex.length[c]
        p = backend.parse_field[col.dtype](css, off, ln, cfg)
        if col.dtype != "str":
            p = p._replace(value=jnp.where(p.valid, p.value, jnp.zeros_like(p.value)))
        values[col.name] = p
    return values


def locate_carry(flat_classes: jax.Array) -> jax.Array:
    """§4.4: byte position of the last record delimiter (−1 if none) — the
    streaming carry-over boundary."""
    pos = jnp.arange(flat_classes.shape[0], dtype=jnp.int32)
    return jnp.max(jnp.where(flat_classes == RECORD_DELIM, pos, -1)).astype(jnp.int32)
