"""CSS field index: per-field offsets and lengths (paper §3.3, Fig. 5).

The paper run-length-encodes record tags inside each column's CSS and
prefix-sums the run lengths.  On TPU the same index falls out of two
segment reductions keyed by (column, record):

    offset[c, r] = min position of a (c, r) symbol
    length[c, r] = count of (c, r) symbols

which additionally handles *empty* fields (no symbols at all → length 0,
offset patched harmlessly) and *missing* fields in ragged records, neither
of which produce an RLE run.  For the inline/vector tagging modes the index
instead derives from terminator/flag positions, matching paper §4.1.

:func:`field_index` dispatches on the tagging mode — the single entry point
``stages.materialize`` uses, so the mode split lives here with the index
logic rather than in the stage layer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_BIG = jnp.int32(2**31 - 1)


class FieldIndex(NamedTuple):
    offset: jax.Array   # (n_cols, max_records) int32 — absolute into the CSS buffer
    length: jax.Array   # (n_cols, max_records) int32
    present: jax.Array  # (n_cols, max_records) bool — field materialised in input


def field_index(
    mode: str,
    col_sorted: jax.Array,
    rec_sorted: jax.Array,
    col_start: jax.Array,
    n_cols: int,
    max_records: int,
    term_flag=None,
) -> "FieldIndex":
    """Build the field index for a tagging mode (paper §3.3 / §4.1).

    ``tagged`` derives the index from the sorted (column, record) tags and
    ignores ``col_start``/``term_flag``; ``inline``/``vector`` derive it
    from the partitioned terminator flags (``term_flag`` required).
    """
    if mode == "tagged":
        return field_index_tagged(col_sorted, rec_sorted, n_cols, max_records)
    if term_flag is None:
        raise ValueError(f"tagging mode {mode!r} needs the terminator flags")
    return field_index_terminated(
        term_flag, col_sorted, rec_sorted, col_start, n_cols, max_records
    )


def field_index_tagged(
    col_sorted: jax.Array,
    rec_sorted: jax.Array,
    n_cols: int,
    max_records: int,
) -> FieldIndex:
    """Index from sorted (column, record) tags — ``tagged`` mode.

    Args:
      col_sorted / rec_sorted: ``(N,) int32`` tags after partitioning (value
        symbols grouped by column, original order preserved within).
    """
    n = col_sorted.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    in_range = (col_sorted < n_cols) & (rec_sorted < max_records)
    n_segs = n_cols * max_records
    seg = jnp.where(in_range, col_sorted * max_records + rec_sorted, n_segs)

    offset = jax.ops.segment_min(pos, seg, num_segments=n_segs + 1)[:-1]
    length = jax.ops.segment_sum(
        jnp.ones_like(pos), seg, num_segments=n_segs + 1
    )[:-1]
    present = length > 0
    offset = jnp.where(present, offset, 0)
    return FieldIndex(
        offset.reshape(n_cols, max_records).astype(jnp.int32),
        length.reshape(n_cols, max_records).astype(jnp.int32),
        present.reshape(n_cols, max_records),
    )


def field_index_terminated(
    term_flag_sorted: jax.Array,
    col_sorted: jax.Array,
    rec_sorted: jax.Array,
    col_start: jax.Array,
    n_cols: int,
    max_records: int,
) -> FieldIndex:
    """Index from terminator positions — ``inline``/``vector`` modes.

    Each terminator carries the (column, record) of the field it closes, so
    a segment-min keyed on those tags lands every field's *end*; the start is
    the previous field's end + 1 (one terminator byte separates fields), and
    the column's CSS start for the first record.

    Args:
      term_flag_sorted: ``(N,) bool`` terminator marker after partitioning.
      col_start: ``(≥n_cols,) int32`` CSS start per column (from the
        partition histogram).
    """
    n = term_flag_sorted.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    in_range = (col_sorted < n_cols) & (rec_sorted < max_records)
    valid = term_flag_sorted & in_range
    n_segs = n_cols * max_records
    seg = jnp.where(valid, col_sorted * max_records + rec_sorted, n_segs)

    end = jax.ops.segment_min(
        jnp.where(valid, pos, _BIG), seg, num_segments=n_segs + 1
    )[:-1].reshape(n_cols, max_records)
    present = end < _BIG

    # Start = one past the previous *present* field's terminator (absent
    # fields — a record whose field terminated on an earlier shard, or a
    # ragged record's missing column — contribute no bytes), or the
    # column's CSS start when no field precedes.  Ends are monotone within
    # a column (stable partition), so an exclusive running max finds the
    # predecessor; with every field present this reduces to the plain
    # ``end[r-1] + 1`` recurrence bit-for-bit.
    prev_end = jax.lax.cummax(jnp.where(present, end, -1), axis=1)
    prev_end = jnp.concatenate(
        [jnp.full((n_cols, 1), -1, end.dtype), prev_end[:, :-1]], axis=1
    )
    start = jnp.where(prev_end >= 0, prev_end + 1, col_start[:n_cols, None])
    length = jnp.where(present, end - start, 0)
    offset = jnp.where(present, start, 0)
    return FieldIndex(
        offset.astype(jnp.int32), length.astype(jnp.int32), present
    )
