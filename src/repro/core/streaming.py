"""End-to-end streaming parse (paper §4.4).

The paper overlaps three pipeline stages per partition — transfer in, parse,
return — on the PCIe bus's full-duplex channels with a device-side double
buffer and a *carry-over*: the trailing incomplete record of partition *i*
is prepended to partition *i+1*.

JAX mapping (DESIGN.md §3): XLA's async dispatch is the stream engine.
``device_put`` of partition *i+1* and the host-side read-back of partition
*i−1*'s results both overlap the device parse of partition *i*; the only
synchronisation is fetching the scalar ``last_record_end`` (the carry
boundary), mirroring the carry-copy dependency edge in the paper's Fig. 7.
Because every partition reuses one compiled executable (static capacity),
there is no recompilation in the steady state.

The carry boundary comes from parse *metadata*, not from a host ``rfind``:
a newline inside a quoted field must not be mistaken for a record boundary,
which is exactly the context problem the paper solves.

This driver composes :class:`Parser` partition-by-partition, so it inherits
the backend-owned materialization path (``stages.materialize``) untouched:
with ``backend="pallas"`` every partition runs the radix partition kernel
and the fused gather+convert typeconv kernels with zero changes here.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfa import PAD_BYTE
from repro.core.parser import ParseResult, Parser


@dataclasses.dataclass
class StreamStats:
    partitions: int = 0
    bytes_in: int = 0
    records: int = 0
    max_carry: int = 0


class StreamingParser:
    """Partition-pipelined parser with carry-over record stitching.

    Args:
      parser: a configured single-device :class:`Parser`; its
        ``max_records`` bounds records *per partition*.
      partition_bytes: raw bytes consumed from the source per partition.
      max_carry_bytes: capacity reserved for the carry-over (longest record
        the stream may contain, paper's carry-over allocation).
    """

    def __init__(self, parser: Parser, partition_bytes: int,
                 max_carry_bytes: Optional[int] = None):
        self.parser = parser
        self.partition_bytes = int(partition_bytes)
        self.max_carry_bytes = int(max_carry_bytes or partition_bytes)
        k = parser.cfg.chunk_size
        cap = self.partition_bytes + self.max_carry_bytes + 1
        self.capacity = ((cap + k - 1) // k) * k
        self.stats = StreamStats()

    def _buf_to_chunks(self, buf: bytes, final: bool) -> np.ndarray:
        k = self.parser.cfg.chunk_size
        raw = np.frombuffer(buf, np.uint8)
        out = np.full(self.capacity, PAD_BYTE, np.uint8)
        out[: raw.size] = raw
        if final:
            # Flush the unterminated tail record — but judge "unterminated"
            # on the last *payload* byte: a PAD-only tail (trailing 0x00
            # padding in the source) carries no record, and appending a
            # delimiter after it would mint a spurious empty record.
            payload = raw.size
            while payload and raw[payload - 1] == PAD_BYTE:
                payload -= 1
            if payload and raw[payload - 1] != self.parser.cfg.record_delim_byte:
                if raw.size >= self.capacity:
                    # The carry consumed the slot reserved for the flush
                    # delimiter (a single record filled the whole buffer).
                    raise ValueError(
                        f"record longer than capacity ({raw.size + 1} > "
                        f"{self.capacity}); increase max_carry_bytes"
                    )
                out[raw.size] = self.parser.cfg.record_delim_byte
        return out.reshape(-1, k)

    def parse_stream(
        self, source: Iterable[bytes]
    ) -> Iterator[Tuple[ParseResult, int]]:
        """Yields ``(result, n_complete_records)`` per partition.

        Only records ``[0, n_complete)`` of each result are complete; the
        trailing bytes re-appear at the front of the next partition.
        """
        carry = b""
        it = iter(source)
        buf = b""
        exhausted = False
        while True:
            # fill the partition
            while not exhausted and len(buf) < self.partition_bytes:
                try:
                    buf += next(it)
                except StopIteration:
                    exhausted = True
            take = buf[: self.partition_bytes]
            buf = buf[self.partition_bytes:]
            if not take and not carry:
                break
            final = exhausted and not buf
            full = carry + take
            if len(full) > self.capacity:
                raise ValueError(
                    f"record longer than capacity ({len(full)} > {self.capacity}); "
                    "increase max_carry_bytes"
                )
            chunks = self._buf_to_chunks(full, final)
            # async dispatch: the device parses while the host assembles the
            # next partition; only the carry boundary scalar synchronises.
            result = self.parser.parse_chunks(jnp.asarray(chunks))
            last = int(result.last_record_end)
            n_complete = int(result.validation.n_records)
            if last < 0:
                carry = full  # no complete record in this partition
            else:
                carry = full[last + 1:]
            if final and carry:
                # The stream is exhausted, so leftover carry is stale, not a
                # pending record: either inert PAD/control bytes (a PAD-only
                # tail — nothing left to parse), or an unterminated record
                # that the appended delimiter could not close (malformed
                # input, e.g. an unclosed quote; ``validation`` flags it).
                # Drop it explicitly so stats and any caller inspecting the
                # carry see the stream as fully consumed.
                carry = b""
            self.stats.partitions += 1
            self.stats.bytes_in += len(take)
            self.stats.records += n_complete
            self.stats.max_carry = max(self.stats.max_carry, len(carry))
            yield result, n_complete
            if final:
                break

    def parse_all(self, source: Iterable[bytes]):
        """Convenience: fully consume the stream, returning concatenated
        per-column host arrays (Arrow layout, like ``Parser.to_arrow``)."""
        schema = self.parser.cfg.schema
        acc = {c.name: [] for c in schema.columns}
        for result, n in self.parse_stream(source):
            arrow = self.parser.to_arrow(result)
            for c in schema.columns:
                acc[c.name].append(_trim(arrow[c.name], n))
        return {name: _concat(parts) for name, parts in acc.items()}


def _trim(arrow_col: dict, n: int) -> dict:
    if "values" in arrow_col:
        return dict(values=arrow_col["values"][:n],
                    validity=arrow_col["validity"], n=n)
    offsets = arrow_col["offsets"][: n + 1]
    return dict(offsets=offsets, data=arrow_col["data"][: offsets[-1] if n else 0],
                validity=arrow_col["validity"], n=n)


def _unpack_bits(packed: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(packed, bitorder="little")[:n].astype(bool)


def _concat(parts) -> dict:
    if not parts:
        return {}
    if "values" in parts[0]:
        values = np.concatenate([p["values"][: p["n"]] for p in parts])
        validity = np.concatenate([_unpack_bits(p["validity"], p["n"]) for p in parts])
        return dict(values=values, validity=validity)
    datas, offs, vals = [], [np.zeros(1, np.int64)], []
    base = 0
    for p in parts:
        n = p["n"]
        o = p["offsets"].astype(np.int64)
        offs.append(o[1 : n + 1] + base)
        datas.append(p["data"][: o[n]])
        vals.append(_unpack_bits(p["validity"], n))
        base += int(o[n])
    return dict(
        offsets=np.concatenate(offs),
        data=np.concatenate(datas) if datas else np.zeros(0, np.uint8),
        validity=np.concatenate(vals),
    )


def iter_file(path: str, read_bytes: int = 1 << 20) -> Iterator[bytes]:
    """Simple file source for ``parse_stream``."""
    with open(path, "rb") as f:
        while True:
            b = f.read(read_bytes)
            if not b:
                return
            yield b
