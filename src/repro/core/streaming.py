"""End-to-end streaming parse engine (paper §4.4).

The paper overlaps three pipeline stages per partition — transfer in, parse,
return — on the PCIe bus's full-duplex channels with a device-side double
buffer and a *carry-over*: the trailing incomplete record of partition *i*
is prepended to partition *i+1*.

JAX mapping (DESIGN.md §3): XLA's async dispatch is the stream engine, and
:class:`StreamSession` keeps the whole carry path on the device so nothing
serialises it.  The per-partition step is ONE donated jitted function —
``backend.prepend_carry`` (splice the device-resident carry in front of the
fresh bytes) → ``stages.execute_plan`` (the same :class:`stages.ParsePlan`
executor every driver runs) → ``backend.extract_carry`` (cut the new tail
after ``last_record_end``) — whose carry outputs feed the next dispatch
*directly*, as device arrays.  No ``int(result.last_record_end)``, no host
``bytes`` slicing: the host thread only cuts source bytes into fixed-size
takes and reads results **one partition behind** the dispatch (the paper's
Fig. 7 timeline: transfer-in of partition *i+1* and the read-back of
partition *i−1* both overlap the parse of partition *i*).  Because every
partition reuses one compiled executable (static capacity), there is no
recompilation in the steady state.

The carry boundary comes from parse *metadata*, not from a host ``rfind``:
a newline inside a quoted field must not be mistaken for a record boundary,
which is exactly the context problem the paper solves.

**Multi-stream batching**: ``StreamSession(n_streams=S)`` ``vmap``s the
step over a leading stream axis — per-stream carry buffers, per-stream
flush flags — so S independent sources (concurrent tenants) parse in one
dispatch per round, bit-identical to S sequential single-stream sessions
(pinned by ``tests/test_streaming.py``).

**Lane sharding**: with ``mesh=`` the stream axis is additionally sharded
over a mesh axis (``shard_map`` around the vmapped step): each device owns
``S/D`` lanes, their carry buffers stay device-resident round over round
(no carry leaf ever crosses devices, no collectives in the step), and one
dispatch still drives the whole fleet — bit-identical to the single-device
batched engine (pinned by ``tests/test_distributed.py``).

:class:`StreamingParser` is the legacy iterator API, now a thin wrapper
over a single-stream session (``engine="device"``); ``engine="host"``
keeps the original host-carry loop — one blocking sync per partition —
as the bit-identity oracle the device engine is tested against.

Both engines compose :class:`Parser`'s plan, so they inherit the
backend-owned materialization path untouched: with ``backend="pallas"``
every partition runs the radix partition kernel and the fused
gather+convert typeconv kernels with zero changes here.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec
from jax.experimental.shard_map import shard_map

from repro.core import stages as stages_mod
from repro.core.dfa import PAD_BYTE
from repro.core.parser import ParseResult, Parser

#: The engine's ONLY device→host read goes through this indirection — an
#: *explicit* transfer, so a session keeps running under
#: ``jax.transfer_guard_device_to_host("disallow")`` (which traps implicit
#: ``int(...)``/``.item()``/``np.asarray`` syncs).  Tests monkeypatch it to
#: count fetches and assert they trail dispatches by one partition.
_device_get = jax.device_get


class StreamOverflow(ValueError):
    """Typed per-stream overflow record.

    A record longer than the session capacity cannot be parsed: the carry
    splice wraps and the lane's buffer contents are garbage.  In a batched
    session this is a *per-lane* fault, not a session fault —
    :meth:`StreamSession.parse_streams` yields ``(stream, StreamOverflow,
    0)`` on the failed lane's channel and keeps parsing every other lane
    (fault isolation for multi-tenant serving).  The single-stream
    :class:`StreamingParser` re-raises it, so legacy callers still see a
    ``ValueError`` with the historical message.
    """

    def __init__(self, stream: int, n_bytes: int, capacity: int,
                 n_streams: int = 1):
        self.stream = int(stream)
        self.n_bytes = int(n_bytes)
        self.capacity = int(capacity)
        super().__init__(
            f"record longer than capacity ({n_bytes} > {capacity}); "
            "increase max_carry_bytes"
            + (f" [stream {stream}]" if n_streams > 1 else "")
        )


@dataclasses.dataclass
class StreamStats:
    """Per-stream accounting.  Exact definitions:

    ``partitions``
        Parsed partitions yielded to the caller (suppressed no-op rounds of
        a batched session are not counted).
    ``bytes_in``
        Raw *source* bytes consumed, each counted exactly once — the
        denominator for honest end-to-end throughput.  Carry bytes that
        re-enter the next partition are **not** re-counted here.
    ``bytes_reparsed``
        Carry-over bytes parsed a second (or third, …) time because their
        record straddled a partition boundary.  Device work per stream is
        proportional to ``bytes_in + bytes_reparsed``; a high ratio means
        the partition size is too small for the record length.
    ``records``
        Complete records across all yielded partitions.
    ``max_carry``
        Largest carry that *survived* a partition (after the
        final-partition stale-carry drop), i.e. the minimum
        ``max_carry_bytes`` this stream would have needed.
    ``flush_delims``
        Synthetic flush delimiters appended on-device (one per flush round
        whose stream did not end on a record delimiter).  These bytes are
        *parsed* but are not source bytes, so they are counted here and
        **not** in ``bytes_in``: total device-parsed bytes for a stream are
        exactly ``bytes_in + bytes_reparsed + flush_delims``, while GB/s
        denominators should keep using ``bytes_in`` (each source byte once).
    ``failed``
        The stream hit a :class:`StreamOverflow` and its lane was retired
        for the rest of the call; ``bytes_in``/``bytes_reparsed`` include
        the overflowing round (the work was dispatched), but
        ``partitions``/``records`` do not (nothing usable came back).
    """

    partitions: int = 0
    bytes_in: int = 0
    bytes_reparsed: int = 0
    records: int = 0
    max_carry: int = 0
    flush_delims: int = 0
    failed: bool = False


class _StepAux(NamedTuple):
    """Tiny per-partition scalars the host reads one round behind.

    Deliberately does NOT alias the donated carry outputs: the next round's
    dispatch donates ``(carry_buf, carry_len)``, which would invalidate any
    aux leaf sharing their buffers before the one-behind fetch reads it
    (``last_record_end`` lets the host re-derive the carry length from
    values it already knows instead).
    """

    n_records: jax.Array        # () / (S,) int32 — complete records
    last_record_end: jax.Array  # () / (S,) int32 — §4.4 carry boundary
    overflow: jax.Array         # () / (S,) bool  — partition no longer fits


class _Feed:
    """Host-side cursor cutting one ``Iterable[bytes]`` into partition takes.

    Every stream ends with exactly one ``flush=True`` take (possibly empty:
    the source exhausted at a partition boundary); after that ``next_take``
    returns ``None`` and the stream's lane goes inert.
    """

    def __init__(self, source: Iterable[bytes], partition_bytes: int):
        self._it = iter(source)
        self._buf = b""
        self._pb = partition_bytes
        self.exhausted = False
        self.flushed = False
        #: Last non-PAD byte produced so far — the host mirror of the
        #: device's flush-delimiter judgement (append one iff the stream's
        #: last payload byte is not already a record delimiter).
        self.last_payload: Optional[int] = None

    def next_take(self) -> Optional[Tuple[bytes, bool]]:
        if self.flushed:
            return None
        while not self.exhausted and len(self._buf) < self._pb:
            try:
                self._buf += next(self._it)
            except StopIteration:
                self.exhausted = True
        take, self._buf = self._buf[: self._pb], self._buf[self._pb:]
        flush = self.exhausted and not self._buf
        if flush:
            self.flushed = True
        payload = take.rstrip(bytes([PAD_BYTE]))
        if payload:
            self.last_payload = payload[-1]
        return take, flush

    def kill(self) -> None:
        """Retire the lane (fault isolation): subsequent ``next_take``
        calls return ``None`` and the lane goes inert."""
        self.flushed = True


class StreamSession:
    """Device-resident streaming engine with dispatch-ahead and multi-stream
    batching (see module docstring).

    Args:
      parser: a configured :class:`Parser`; its ``max_records`` bounds
        records *per partition per stream*, and its :class:`ParsePlan` is
        the one the session step executes.
      partition_bytes: raw bytes consumed from each source per partition.
      max_carry_bytes: capacity reserved for the carry-over (longest record
        any stream may contain — the paper's carry-over allocation).
      n_streams: number of independent sources batched per dispatch
        (leading ``vmap`` axis of the step; per-stream carry state).
      mesh: optional device mesh — lanes are sharded over ``mesh_axis``
        (``n_streams`` must divide by its size), each device owning a
        disjoint lane set whose carry buffers stay resident on that
        device across rounds (the carry never crosses devices; the step
        compiles with zero collectives).  One dispatch per round drives
        every device; results are bit-identical to the same session
        without a mesh.
      mesh_axis: the mesh axis name lanes shard over.

    ``stats`` is one :class:`StreamStats` per stream, accumulated across
    ``parse_streams`` calls (carry state resets per call); ``call_stats``
    is the same accounting reset at the start of every ``parse_streams``
    call — what a serving layer reports per tenant per batch.

    A session drives ONE ``parse_streams`` generator at a time: its carry
    buffers are donated between rounds and a dispatched round may still be
    in flight when the generator is abandoned, so re-entry is guarded by a
    state machine (``idle`` → ``active`` → ``idle`` | ``dirty``).  A
    generator that exits abnormally (caller ``break``/``close`` or an
    exception) leaves the session ``dirty``; call :meth:`reset` to settle
    the in-flight round and return to ``idle``.
    """

    def __init__(self, parser: Parser, partition_bytes: int,
                 max_carry_bytes: Optional[int] = None, n_streams: int = 1,
                 mesh: Optional[Mesh] = None, mesh_axis: str = "streams"):
        self.parser = parser
        self.partition_bytes = int(partition_bytes)
        self.max_carry_bytes = int(max_carry_bytes or partition_bytes)
        k = parser.cfg.chunk_size
        cap = self.partition_bytes + self.max_carry_bytes + 1
        self.capacity = ((cap + k - 1) // k) * k
        if self.partition_bytes < 1:
            raise ValueError(
                f"partition_bytes must be >= 1, got {partition_bytes}")
        self.n_streams = int(n_streams)
        if self.n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        # Lane sharding (mesh mode): the stream axis is sharded over a mesh
        # axis — each device owns a disjoint lane set and its lanes' carry
        # buffers live on that device for the whole session (the step's
        # in/out specs keep every leaf P(axis), so no carry leaf ever
        # crosses devices and the step body compiles with ZERO collectives —
        # pinned by tests/test_distributed.py).  Bit-identical to the
        # single-device batched engine: the step body is the same vmapped
        # function, merely partitioned along the lane axis.
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None:
            if mesh_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no axis {mesh_axis!r}: {mesh.axis_names}")
            d = mesh.shape[mesh_axis]
            if self.n_streams % d:
                raise ValueError(
                    f"n_streams={self.n_streams} not divisible by mesh axis "
                    f"{mesh_axis!r} size {d}")
        #: Mesh mode always uses batched (leading-stream-axis) shapes, even
        #: for S == 1, so the shard_map specs are uniform.
        self._batched = self.n_streams > 1 or mesh is not None
        self._lane_sharding = (
            None if mesh is None
            else NamedSharding(mesh, PartitionSpec(mesh_axis)))
        # Double-buffered staging: round r+1 is assembled in one buffer
        # while the other may still back round r's in-flight transfer.
        # Stale bytes beyond a take need no re-padding — prepend_carry masks
        # the fresh buffer at fresh_len, so only [0, len(take)) is ever read.
        # Staging is PARTITION-sized, not capacity-sized: only fresh source
        # bytes cross the bus each round; the jitted step zero-extends to
        # capacity on-device (the carry tail never transfers).
        S = self.n_streams
        self._staging = [np.full((S, self.partition_bytes), PAD_BYTE, np.uint8)
                         for _ in range(2)]
        self._staging_idx = 0
        self.stats: Tuple[StreamStats, ...] = tuple(StreamStats() for _ in range(S))
        self.call_stats: Tuple[StreamStats, ...] = tuple(
            StreamStats() for _ in range(S))
        self._state = "idle"        # idle | active | dirty
        self._failed = [False] * S  # per-lane fault flags, reset per call
        self._inflight = None       # last dispatched round's device outputs
        self._step = self._build_step()

    # -- the donated per-partition device step -------------------------------
    def _build_step(self):
        parser = self.parser
        cfg, backend, plan = parser.cfg, parser.backend, parser.plan
        k = cfg.chunk_size

        capacity = self.capacity

        def step_one(carry_buf, carry_len, fresh, fresh_len, flush):
            # The host transfers only the partition-sized fresh bytes;
            # extend to the carry capacity on-device (PAD tail, fused into
            # the splice by XLA — nothing extra crosses the bus).
            pad = capacity - fresh.shape[-1]
            if pad:
                fresh = jnp.concatenate(
                    [fresh, jnp.full((pad,), PAD_BYTE, jnp.uint8)])
            buf, total, overflow = backend.prepend_carry(
                carry_buf, carry_len, fresh, fresh_len, flush, cfg
            )
            # execute_plan dispatches staged vs fused (the whole-pipeline
            # megakernel) per the resolved plan — the carry hooks above/
            # below are path-agnostic, so fuse_pipeline streams for free.
            result = stages_mod.execute_plan(buf.reshape(-1, k), plan, cfg, backend)
            new_buf, new_len = backend.extract_carry(
                buf, total, result.last_record_end, flush, cfg
            )
            aux = _StepAux(
                n_records=result.validation.n_records.astype(jnp.int32),
                last_record_end=result.last_record_end,
                overflow=overflow,
            )
            return result, new_buf, new_len, aux

        fn = step_one if not self._batched else jax.vmap(step_one)
        if self.mesh is not None:
            # Lane sharding: every in/out leaf is partitioned on its leading
            # stream axis; each device runs the SAME vmapped step over its
            # own S/D lanes.  check_rep=False: nothing is replicated.
            spec = PartitionSpec(self.mesh_axis)
            fn = shard_map(fn, mesh=self.mesh,
                           in_specs=(spec, spec, spec, spec, spec),
                           out_specs=spec, check_rep=False)
        # Donate the carry buffers: partition i+1's step overwrites partition
        # i's carry in place (no device-side copy growth).  CPU/interpret
        # hosts can't alias donations — skip there to keep runs warning-free.
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        return jax.jit(fn, donate_argnums=donate)

    def _init_carry(self):
        S = self.n_streams
        shape = (S, self.capacity) if self._batched else (self.capacity,)
        lshape = (S,) if self._batched else ()
        buf = jnp.full(shape, PAD_BYTE, jnp.uint8)
        ln = jnp.zeros(lshape, jnp.int32)
        if self._lane_sharding is not None:
            # Carry locality: buffers start on their owning device and the
            # step's out_specs keep them there — the carry never crosses.
            buf = jax.device_put(buf, self._lane_sharding)
            ln = jax.device_put(ln, self._lane_sharding)
        return buf, ln

    # -- host-side staging ---------------------------------------------------
    def _stage_round(self, feeds: List[_Feed]):
        """Assemble the next round's fresh buffers; ``None`` when every
        stream has dispatched its flush partition."""
        S = self.n_streams
        staging = self._staging[self._staging_idx]
        self._staging_idx ^= 1
        fresh_len = np.zeros(S, np.int32)
        flush = np.zeros(S, bool)
        active = [False] * S
        delims = [False] * S
        for s, feed in enumerate(feeds):
            nt = feed.next_take()
            if nt is None:
                # Inert lane: empty take under flush keeps the (already
                # empty) carry pinned at zero; drained rounds skip it.
                flush[s] = True
                continue
            take, fl = nt
            raw = np.frombuffer(take, np.uint8)
            staging[s, : raw.size] = raw
            fresh_len[s] = raw.size
            flush[s] = fl
            active[s] = True
            if fl:
                # Host mirror of the device's flush-delimiter judgement
                # (for stats only — the device decides independently): a
                # delimiter is appended iff the stream's last payload byte
                # is not already a record delimiter.  The carry is always a
                # contiguous suffix of consumed bytes, so the buffer's last
                # payload byte equals the stream-wide one.
                delims[s] = (
                    feed.last_payload is not None
                    and feed.last_payload != self.parser.cfg.record_delim_byte
                )
        if not any(active):
            return None
        host = staging if self._batched else staging[0]
        fresh = (jax.device_put(host, self._lane_sharding)
                 if self._lane_sharding is not None else jax.device_put(host))
        return fresh, fresh_len, flush, active, delims

    # -- the dispatch-ahead loop ---------------------------------------------
    def parse_streams(
        self, sources: Sequence[Iterable[bytes]]
    ) -> Iterator[Tuple[int, ParseResult, int]]:
        """Drive ``n_streams`` sources to completion, one batched dispatch
        per round, yielding ``(stream, result, n_complete)`` per partition
        in round order.

        Results are read one round behind the dispatch: round *r* is
        yielded only after round *r+1* is in flight, and the only host
        reads are one explicit ``jax.device_get`` of three scalars per
        round (``_StepAux``) — the carry path itself never touches the
        host.  Only records ``[0, n_complete)`` of each result are
        complete; the trailing bytes re-appear in the stream's next
        partition.

        **Fault isolation**: a lane whose record exceeds the capacity
        yields ``(stream, StreamOverflow, 0)`` once, is retired for the
        rest of the call (its remaining source is not consumed, its stats
        are finalized with ``failed=True``), and every other lane parses
        to completion exactly as if the failed lane had never been there.
        No exception crosses lane boundaries.
        """
        if self._state != "idle":
            raise RuntimeError(
                f"StreamSession is {self._state!r}: a previous parse_streams "
                "generator is still open or exited abnormally; exhaust/close "
                "it and call reset() before reuse"
            )
        S = self.n_streams
        sources = list(sources)
        if len(sources) != S:
            raise ValueError(f"expected {S} sources, got {len(sources)}")
        self._state = "active"
        self.call_stats = tuple(StreamStats() for _ in range(S))
        self._failed = [False] * S
        done = False
        try:
            feeds = [_Feed(src, self.partition_bytes) for src in sources]
            carry_buf, carry_len = self._init_carry()
            carry_known = [0] * S  # host mirror of carry_len, one round behind
            pending = None
            while True:
                staged = self._stage_round(feeds)
                if staged is None:
                    break
                fresh, fresh_len, flush, active, delims = staged
                # Drop the in-flight record before dispatch: the step donates
                # the previous round's carry outputs, so they must not be
                # retained (reset() would try to block on dead buffers).
                self._inflight = None
                result, carry_buf, carry_len, aux = self._step(
                    carry_buf, carry_len, fresh,
                    jnp.asarray(fresh_len if self._batched else fresh_len[0]),
                    jnp.asarray(flush if self._batched else flush[0]),
                )
                self._inflight = (result, carry_buf, carry_len, aux)
                if pending is not None:
                    yield from self._drain(pending, carry_known, feeds)
                pending = (result, aux, fresh_len, flush, active, delims)
            if pending is not None:
                yield from self._drain(pending, carry_known, feeds)
            done = True
        finally:
            if done:
                self._state = "idle"
                self._inflight = None
            else:
                # Abandoned mid-stream (caller break/close or an exception):
                # a dispatched round may still be in flight against donated
                # carry — refuse silent reuse until reset().
                self._state = "dirty"

    def reset(self) -> None:
        """Settle an abnormally-exited session back to ``idle``.

        Blocks on the last dispatched round (so no computation is still
        writing into the donated carry buffers), drops it, and clears the
        state guard.  Cumulative ``stats`` are preserved; the next
        ``parse_streams`` call re-initialises carry state as always.  A
        session with a still-open generator must have it closed first.
        """
        if self._state == "active":
            raise RuntimeError(
                "cannot reset a StreamSession with an open parse_streams "
                "generator; close it first"
            )
        if self._inflight is not None:
            try:
                jax.block_until_ready(self._inflight)
            except Exception:
                pass  # donated-away buffers: already settled by definition
            self._inflight = None
        self._state = "idle"

    def _drain(self, pending, carry_known: List[int], feeds: List[_Feed]):
        """Fetch one round's scalars (the one-behind read) and yield its
        per-stream results; overflowing lanes yield a typed
        :class:`StreamOverflow` and are retired without disturbing the
        rest of the batch."""
        result, aux, fresh_len, flush, active, delims = pending
        aux_np = _device_get(aux)
        n_records = np.atleast_1d(aux_np.n_records)
        last_end = np.atleast_1d(aux_np.last_record_end)
        overflow = np.atleast_1d(aux_np.overflow)
        for s in range(self.n_streams):
            if not active[s] or self._failed[s]:
                # Inert lane, or a failed lane's already-dispatched round
                # (dispatch runs one ahead of the drain that detects the
                # overflow): its buffer contents are garbage — suppress.
                continue
            take_len, carry_in = int(fresh_len[s]), carry_known[s]
            if take_len == 0 and carry_in == 0:
                # The optimistic end-of-stream flush round found nothing to
                # parse (the source ended exactly at a partition boundary,
                # or was empty): a no-op, not a partition.
                carry_known[s] = 0
                continue
            if bool(overflow[s]):
                # Per-lane fault: the splice wrapped, this lane's buffer is
                # garbage.  Retire the lane (its feed stops producing; the
                # next parse_streams call re-inits carry device-side) and
                # report on this stream's channel only.
                err = StreamOverflow(
                    s, carry_in + take_len + (1 if flush[s] else 0),
                    self.capacity, self.n_streams)
                self._failed[s] = True
                feeds[s].kill()
                carry_known[s] = 0
                for st in (self.stats[s], self.call_stats[s]):
                    st.bytes_in += take_len
                    st.bytes_reparsed += carry_in
                    st.failed = True
                yield s, err, 0
                continue
            # Mirror of extract_carry: the carry length re-derived from
            # host-known values + the fetched boundary (the donated device
            # carry_len itself is never read back).
            carry_out = 0 if flush[s] else max(
                carry_in + take_len - (int(last_end[s]) + 1), 0)
            for st in (self.stats[s], self.call_stats[s]):
                st.partitions += 1
                st.bytes_in += take_len
                st.bytes_reparsed += carry_in
                st.records += int(n_records[s])
                st.max_carry = max(st.max_carry, carry_out)
                if flush[s] and delims[s]:
                    st.flush_delims += 1
            carry_known[s] = carry_out
            yield s, self._slice_result(result, s), int(n_records[s])

    def _slice_result(self, result: ParseResult, s: int) -> ParseResult:
        if not self._batched:
            return result
        return jax.tree_util.tree_map(lambda x: x[s], result)


class StreamingParser:
    """Partition-pipelined parser with carry-over record stitching — the
    legacy single-stream iterator API.

    ``engine="device"`` (default) wraps a single-stream
    :class:`StreamSession`: device-resident carry, no per-partition host
    sync, results one partition behind dispatch.  ``engine="host"`` keeps
    the original host-carry loop — Python ``bytes`` stitching and one
    blocking ``int(result.last_record_end)`` per partition — as the oracle
    the device engine is pinned bit-identical to.

    Args:
      parser: a configured single-device :class:`Parser`; its
        ``max_records`` bounds records *per partition*.
      partition_bytes: raw bytes consumed from the source per partition.
      max_carry_bytes: capacity reserved for the carry-over (longest record
        the stream may contain, paper's carry-over allocation).
      engine: ``device`` | ``host``.
    """

    def __init__(self, parser: Parser, partition_bytes: int,
                 max_carry_bytes: Optional[int] = None, engine: str = "device"):
        self.parser = parser
        self.partition_bytes = int(partition_bytes)
        self.max_carry_bytes = int(max_carry_bytes or partition_bytes)
        if self.partition_bytes < 1:
            raise ValueError(
                f"partition_bytes must be >= 1, got {partition_bytes}")
        if engine not in ("device", "host"):
            raise ValueError(f"engine must be 'device' or 'host', got {engine!r}")
        self.engine = engine
        if engine == "device":
            self._session = StreamSession(
                parser, self.partition_bytes, max_carry_bytes=self.max_carry_bytes
            )
            self.capacity = self._session.capacity
            self.stats = self._session.stats[0]
        else:
            k = parser.cfg.chunk_size
            cap = self.partition_bytes + self.max_carry_bytes + 1
            self.capacity = ((cap + k - 1) // k) * k
            self.stats = StreamStats()
            # One preallocated staging buffer reused across partitions (the
            # host engine syncs per partition, so the device is done with it
            # before the next rewrite); only the dirtied tail is re-padded.
            self._staging = np.full(self.capacity, PAD_BYTE, np.uint8)
            self._staged = 0

    def parse_stream(
        self, source: Iterable[bytes]
    ) -> Iterator[Tuple[ParseResult, int]]:
        """Yields ``(result, n_complete_records)`` per partition.

        Only records ``[0, n_complete)`` of each result are complete; the
        trailing bytes re-appear at the front of the next partition.
        """
        if self.engine == "device":
            gen = self._session.parse_streams([source])
            try:
                for _s, result, n in gen:
                    if isinstance(result, StreamOverflow):
                        # Single-stream legacy contract: overflow raises
                        # (it is a ValueError subclass with the historical
                        # message).  Batched callers use StreamSession and
                        # get the per-lane typed-result contract instead.
                        raise result
                    yield result, n
            finally:
                gen.close()
                if self._session._state == "dirty":
                    self._session.reset()
        else:
            yield from self._parse_stream_host(source)

    def reset(self) -> None:
        """Settle the underlying session after an abnormal exit
        (device engine only; the host engine is stateless per call)."""
        if self.engine == "device":
            self._session.reset()

    # -- legacy host-carry engine (the bit-identity oracle) ------------------
    def _buf_to_chunks(self, buf: bytes, final: bool) -> np.ndarray:
        k = self.parser.cfg.chunk_size
        raw = np.frombuffer(buf, np.uint8)
        out = self._staging
        out[raw.size : max(self._staged, raw.size + 1)] = PAD_BYTE
        out[: raw.size] = raw
        self._staged = raw.size
        if final:
            # Flush the unterminated tail record — but judge "unterminated"
            # on the last *payload* byte: a PAD-only tail (trailing 0x00
            # padding in the source) carries no record, and appending a
            # delimiter after it would mint a spurious empty record.
            payload = raw.size
            while payload and raw[payload - 1] == PAD_BYTE:
                payload -= 1
            if payload and raw[payload - 1] != self.parser.cfg.record_delim_byte:
                if raw.size >= self.capacity:
                    # The carry consumed the slot reserved for the flush
                    # delimiter (a single record filled the whole buffer).
                    self.stats.failed = True
                    raise StreamOverflow(0, raw.size + 1, self.capacity)
                out[raw.size] = self.parser.cfg.record_delim_byte
                self._staged = raw.size + 1
                self.stats.flush_delims += 1
        return out.reshape(-1, k)

    def _parse_stream_host(self, source: Iterable[bytes]):
        carry = b""
        it = iter(source)
        buf = b""
        exhausted = False
        while True:
            # fill the partition
            while not exhausted and len(buf) < self.partition_bytes:
                try:
                    buf += next(it)
                except StopIteration:
                    exhausted = True
            take = buf[: self.partition_bytes]
            buf = buf[self.partition_bytes:]
            if not take and not carry:
                break
            final = exhausted and not buf
            full = carry + take
            if len(full) > self.capacity:
                self.stats.failed = True
                raise StreamOverflow(0, len(full), self.capacity)
            chunks = self._buf_to_chunks(full, final)
            # The host-carry sync: fetching the carry boundary blocks on the
            # partition's parse — the serialisation StreamSession removes.
            result = self.parser.parse_chunks(jnp.asarray(chunks))
            last = int(result.last_record_end)
            n_complete = int(result.validation.n_records)
            if last < 0:
                carry = full  # no complete record in this partition
            else:
                carry = full[last + 1:]
            if final and carry:
                # The stream is exhausted, so leftover carry is stale, not a
                # pending record: either inert PAD/control bytes (a PAD-only
                # tail — nothing left to parse), or an unterminated record
                # that the appended delimiter could not close (malformed
                # input, e.g. an unclosed quote; ``validation`` flags it).
                # Drop it explicitly so stats and any caller inspecting the
                # carry see the stream as fully consumed.
                carry = b""
            self.stats.partitions += 1
            self.stats.bytes_in += len(take)
            self.stats.bytes_reparsed += len(full) - len(take)
            self.stats.records += n_complete
            self.stats.max_carry = max(self.stats.max_carry, len(carry))
            yield result, n_complete
            if final:
                break

    def parse_all(self, source: Iterable[bytes]):
        """Convenience: fully consume the stream, returning concatenated
        per-column host arrays (Arrow layout, like ``Parser.to_arrow``)."""
        schema = self.parser.cfg.schema
        acc = {c.name: [] for c in schema.columns}
        for result, n in self.parse_stream(source):
            arrow = self.parser.to_arrow(result)
            for c in schema.columns:
                acc[c.name].append(_trim(arrow[c.name], n))
        return {name: _concat(parts) for name, parts in acc.items()}


def _trim(arrow_col: dict, n: int) -> dict:
    if "values" in arrow_col:
        return dict(values=arrow_col["values"][:n],
                    validity=arrow_col["validity"], n=n)
    offsets = arrow_col["offsets"][: n + 1]
    return dict(offsets=offsets, data=arrow_col["data"][: offsets[-1] if n else 0],
                validity=arrow_col["validity"], n=n)


def _unpack_bits(packed: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(packed, bitorder="little")[:n].astype(bool)


def _concat(parts) -> dict:
    if not parts:
        return {}
    if "values" in parts[0]:
        values = np.concatenate([p["values"][: p["n"]] for p in parts])
        validity = np.concatenate([_unpack_bits(p["validity"], p["n"]) for p in parts])
        return dict(values=values, validity=validity)
    datas, offs, vals = [], [np.zeros(1, np.int64)], []
    base = 0
    for p in parts:
        n = p["n"]
        o = p["offsets"].astype(np.int64)
        offs.append(o[1 : n + 1] + base)
        datas.append(p["data"][: o[n]])
        vals.append(_unpack_bits(p["validity"], n))
        base += int(o[n])
    return dict(
        offsets=np.concatenate(offs),
        data=np.concatenate(datas) if datas else np.zeros(0, np.uint8),
        validity=np.concatenate(vals),
    )


def iter_file(path: str, read_bytes: int = 1 << 20) -> Iterator[bytes]:
    """Simple file source for ``parse_stream``."""
    with open(path, "rb") as f:
        while True:
            b = f.read(read_bytes)
            if not b:
                return
            yield b
