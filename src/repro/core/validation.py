"""Format validation and column-count inference (paper §4.3).

ParPaRaw's DFA simulation makes validation nearly free: invalid transitions
are a sink state checked during replay, and the end state must be accepting.
Column-count inference/validation is a segment reduction over per-record
field counts; the paper's chunk-level relative-min/max machinery reappears
in ``chunk_colcount_summary`` for the distributed parser.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dfa import FIELD_DELIM, RECORD_DELIM, Dfa


class Validation(NamedTuple):
    ok: jax.Array            # () bool — DFA-accepted and no invalid transitions
    end_state_ok: jax.Array  # () bool
    no_invalid: jax.Array    # () bool
    n_records: jax.Array     # () int32
    min_columns: jax.Array   # () int32 — over complete records
    max_columns: jax.Array   # () int32
    record_ok: jax.Array     # (max_records,) bool — per-record conformance


def fields_per_record(
    classes: jax.Array, record_id: jax.Array, max_records: int
) -> jax.Array:
    """Per-record column counts ``(max_records,) int32`` — one more than the
    field delimiters attributed to each record.  Records at or beyond
    ``max_records`` are clipped into a dropped overflow segment.

    Shared between :func:`validate` (single device) and the distributed
    driver, whose shards compute *local* counts on shard-local record ids
    and stitch the boundary record's count with the cross-device column
    seed before reducing (``core/distributed.py``).
    """
    classes = classes.reshape(-1)
    is_fld = classes == FIELD_DELIM
    rid = jnp.where(record_id < max_records, record_id, max_records)
    return jax.ops.segment_sum(
        is_fld.astype(jnp.int32), rid, num_segments=max_records + 1
    )[:-1] + 1


def validate(
    classes: jax.Array,
    record_id: jax.Array,
    end_state: jax.Array,
    saw_invalid: jax.Array,
    dfa: Dfa,
    max_records: int,
    expected_columns: int | None = None,
) -> Validation:
    """Global + per-record validation from parse metadata.

    Args:
      classes / record_id: flattened ``(N,)`` streams.
      end_state: final DFA state of the last chunk.
      saw_invalid: ``(n_chunks,) bool`` from replay.
    """
    classes = classes.reshape(-1)
    accept = jnp.asarray(dfa.accept)
    end_ok = accept[end_state.astype(jnp.int32)]
    no_inv = ~jnp.any(saw_invalid)

    is_rec = classes == RECORD_DELIM
    n_records = jnp.sum(is_rec).astype(jnp.int32)

    fields_per_rec = fields_per_record(classes, record_id, max_records)
    rec_live = jnp.arange(max_records) < n_records
    big = jnp.int32(2**31 - 1)
    minc = jnp.min(jnp.where(rec_live, fields_per_rec, big))
    maxc = jnp.max(jnp.where(rec_live, fields_per_rec, 0))

    if expected_columns is None:
        record_ok = rec_live
    else:
        record_ok = rec_live & (fields_per_rec == expected_columns)

    ok = end_ok & no_inv
    if expected_columns is not None:
        ok &= jnp.all(record_ok | ~rec_live)
    return Validation(ok, end_ok, no_inv, n_records, minc, maxc, record_ok)


class ColCountSummary(NamedTuple):
    """Chunk-level column-count bookkeeping (paper §4.3 "relative min/max").

    ``rel`` — field delimiters before the chunk's first record delimiter
    (meaningful only relative to the predecessor's column offset).
    ``minc``/``maxc`` — min/max complete-record column counts observed after
    the first record delimiter; ``has_rec`` gates their validity.
    """

    rel: jax.Array
    minc: jax.Array
    maxc: jax.Array
    has_rec: jax.Array


def chunk_colcount_summary(classes: jax.Array) -> ColCountSummary:
    """Per-chunk summaries over ``(C, K)`` class codes."""
    is_rec = classes == RECORD_DELIM
    is_fld = classes == FIELD_DELIM
    c, k = classes.shape
    pos = jnp.arange(k, dtype=jnp.int32)

    has_rec = jnp.any(is_rec, axis=1)
    first_rec = jnp.min(jnp.where(is_rec, pos[None], k), axis=1)
    rel = jnp.sum(is_fld & (pos[None] < first_rec[:, None]), axis=1).astype(jnp.int32)

    # Complete records inside the chunk: count fields between consecutive
    # in-chunk record delimiters.
    rec_idx = jnp.cumsum(is_rec.astype(jnp.int32), axis=1) - is_rec
    fld_per = jax.vmap(
        lambda f, r: jax.ops.segment_sum(f.astype(jnp.int32), r, num_segments=k + 1)
    )(is_fld, jnp.where(is_rec, rec_idx, k))
    # Record r is complete within the chunk iff r >= 1 (its start was the
    # previous in-chunk record delimiter) and r <= last record index.
    n_rec = jnp.sum(is_rec, axis=1)
    ridx = jnp.arange(k + 1, dtype=jnp.int32)
    live = (ridx[None, :] >= 1) & (ridx[None, :] < n_rec[:, None])
    big = jnp.int32(2**31 - 1)
    counts = fld_per + 1
    minc = jnp.min(jnp.where(live, counts, big), axis=1)
    maxc = jnp.max(jnp.where(live, counts, 0), axis=1)
    return ColCountSummary(rel, minc.astype(jnp.int32), maxc.astype(jnp.int32), has_rec)
