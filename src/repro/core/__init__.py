"""ParPaRaw core: massively parallel parsing of delimiter-separated data.

Public API re-exports; see DESIGN.md §2 for the module map.
"""
from repro.core.dfa import (
    CONTROL,
    DATA,
    FIELD_DELIM,
    PAD_BYTE,
    RECORD_DELIM,
    TERMINATOR_BYTE,
    Dfa,
    make_csv_dfa,
    make_jsonl_dfa,
    make_log_dfa,
    make_simple_dfa,
    make_zone_dfa,
)
from repro.core.backends import ParseBackend, available_backends, get_backend, register_backend
from repro.core.parser import Column, ParseResult, Parser, ParserConfig, Schema
from repro.core.formats import (
    FormatSpec,
    attach_oracle,
    available_formats,
    get_format,
    parser_config,
    register_format,
)

__all__ = [
    "ParseBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "CONTROL",
    "DATA",
    "FIELD_DELIM",
    "PAD_BYTE",
    "RECORD_DELIM",
    "TERMINATOR_BYTE",
    "Dfa",
    "make_csv_dfa",
    "make_jsonl_dfa",
    "make_log_dfa",
    "make_simple_dfa",
    "make_zone_dfa",
    "FormatSpec",
    "attach_oracle",
    "available_formats",
    "get_format",
    "parser_config",
    "register_format",
    "Column",
    "ParseResult",
    "Parser",
    "ParserConfig",
    "Schema",
]
