"""Symbol tagging and the paper's alternative tagging modes (§3.2, §4.1).

Every symbol receives a column tag and a record tag.  Symbols that do not
contribute to any column's value stream (quotes, comments, CR, padding —
class CONTROL) get the sentinel column ``n_cols`` so the partition step
groups them past all real columns, where they are simply ignored
("irrelevant symbols", paper §4.3).

Modes:
  * ``tagged``  — value symbols only; 4-byte record tags travel with them.
  * ``inline``  — field/record delimiters are kept, re-written to the
    0x1F terminator, and tagged with the column they terminate.  The CSS
    index then falls out of terminator positions; record tags are not needed
    downstream (paper Fig. 6 left).
  * ``vector``  — like ``inline`` but the original delimiter bytes survive
    and a parallel boolean vector marks them (paper Fig. 6 right); for
    inputs whose values may legitimately contain 0x1F.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dfa import (
    CONTROL,
    DATA,
    FIELD_DELIM,
    RECORD_DELIM,
    TERMINATOR_BYTE,
)

TAGGING_MODES = ("tagged", "inline", "vector")


class TaggedSymbols(NamedTuple):
    symbol: jax.Array      # (N,) uint8 — possibly rewritten symbol stream
    col_tag: jax.Array     # (N,) int32 — column, or n_cols sentinel to drop
    rec_tag: jax.Array     # (N,) int32 — record id
    delim_flag: jax.Array  # (N,) bool  — field-terminator marker (vector mode)


def tag_symbols(
    raw: jax.Array,
    classes: jax.Array,
    record_id: jax.Array,
    column_id: jax.Array,
    n_cols: int,
    mode: str = "tagged",
    selected_mask=None,
    skip_records=None,
) -> TaggedSymbols:
    """Assign (column, record) tags per symbol under the given mode.

    Columns ≥ ``n_cols`` (ragged records wider than the schema) are also
    dropped to the sentinel partition; validation reports them separately.

    Paper §4.3 projections: ``selected_mask`` ((n_cols,) bool) drops
    deselected columns' symbols as irrelevant; ``skip_records`` ((R,) bool,
    True = drop) does the same per record — both fold into the same sentinel
    tag, so projection is free at partition time.
    """
    if mode not in TAGGING_MODES:
        raise ValueError(f"unknown tagging mode {mode!r}")
    raw = raw.reshape(-1)
    classes = classes.reshape(-1)
    is_data = classes == DATA
    is_delim = (classes == FIELD_DELIM) | (classes == RECORD_DELIM)

    if mode == "tagged":
        keep = is_data
        symbol = raw
        delim_flag = jnp.zeros_like(keep)
    elif mode == "inline":
        keep = is_data | is_delim
        symbol = jnp.where(is_delim, jnp.uint8(TERMINATOR_BYTE), raw)
        delim_flag = is_delim
    else:  # vector
        keep = is_data | is_delim
        symbol = raw
        delim_flag = is_delim

    in_schema = column_id < n_cols
    if selected_mask is not None:
        sel = jnp.asarray(selected_mask)
        in_schema &= sel[jnp.clip(column_id, 0, n_cols - 1)]
    if skip_records is not None:
        r = jnp.asarray(skip_records)
        in_schema &= ~r[jnp.clip(record_id, 0, r.shape[0] - 1)]
    col_tag = jnp.where(keep & in_schema, column_id, n_cols).astype(jnp.int32)
    return TaggedSymbols(symbol, col_tag, record_id.astype(jnp.int32), delim_flag)
