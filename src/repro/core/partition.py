"""Stable partition of symbols by column tag → concatenated symbol strings
(paper §3.3).

The paper uses a stable radix sort over column tags (CUB).  Column counts in
delimiter-separated data are tiny (≤ a few dozen), so a single
histogram + prefix-sum + scatter pass — exactly one radix pass — suffices.
Two TPU-friendly implementations:

  * ``partition_argsort``  — XLA's stable sort network over the tag key.
    O(N log² N) comparator depth but a single fused op; the robust default.
  * ``partition_scatter``  — the paper's radix pass made explicit: one-hot
    histogram, exclusive prefix sum for column starts, rank-within-column via
    a (N × n_cols+1) cumsum, then a scatter.  O(N·C) work, all dense vector
    ops; wins for small C (§Perf measures the crossover).

Both return the permutation so callers can carry any payload (symbols,
record tags, delimiter flags) through the same reordering.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Partitioned(NamedTuple):
    perm: jax.Array        # (N,) int32 — destination order (gather indices)
    col_start: jax.Array   # (n_cols+1,) int32 — CSS offset per column
    col_count: jax.Array   # (n_cols+1,) int32 — symbols per column
    # (the sentinel "drop" partition is the trailing entry of both)


def column_histogram(col_tag: jax.Array, n_cols: int) -> jax.Array:
    """Counts per column including the sentinel drop column: ``(n_cols+1,)``."""
    return jnp.bincount(col_tag, length=n_cols + 1).astype(jnp.int32)


def partition_argsort(col_tag: jax.Array, n_cols: int) -> Partitioned:
    perm = jnp.argsort(col_tag, stable=True).astype(jnp.int32)
    count = column_histogram(col_tag, n_cols)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(count)[:-1]])
    return Partitioned(perm, start, count)


def partition_scatter(col_tag: jax.Array, n_cols: int) -> Partitioned:
    """Single stable radix pass: histogram → exclusive scan → rank → scatter.

    ``perm`` is returned in gather form (like argsort) so the two paths are
    drop-in interchangeable; the scatter computes destination positions and
    inverts them.
    """
    n = col_tag.shape[0]
    cols = jnp.arange(n_cols + 1, dtype=jnp.int32)
    onehot = (col_tag[:, None] == cols[None, :]).astype(jnp.int32)  # (N, C+1)
    count = onehot.sum(axis=0).astype(jnp.int32)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(count)[:-1]])
    # Rank of each symbol within its own column (stable: input order).
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    own_rank = jnp.take_along_axis(ranks, col_tag[:, None], axis=1)[:, 0]
    dest = start[col_tag] + own_rank  # (N,) — a permutation of [0, N)
    # Invert: perm[dest[i]] = i, giving gather indices.
    perm = jnp.zeros((n,), jnp.int32).at[dest].set(jnp.arange(n, dtype=jnp.int32))
    return Partitioned(perm, start, count)


def partition_scatter2(col_tag: jax.Array, n_cols: int,
                       block: int = 128) -> Partitioned:
    """Two-level counting scatter — the classic GPU radix-pass structure
    (per-block histogram → inter-block scan → intra-block ranks) re-tiled
    for HBM traffic instead of shared memory.

    The flat pass's dominant cost is the (N × C) int32 one-hot cumsum
    (~12·N·C bytes of traffic).  Blocking bounds intra-block ranks by
    ``block`` ≤ 255 so they fit uint8 (~2·N·C bytes), and the inter-block
    scan shrinks to (N/block × C) int32 — a ~6× traffic cut on the
    partition step (EXPERIMENTS.md §Perf, parser iteration 1).
    """
    n = col_tag.shape[0]
    assert block < 256, "intra-block ranks must fit uint8"
    nb = -(-n // block)
    pad = nb * block - n
    tags = jnp.concatenate(
        [col_tag, jnp.full((pad,), n_cols, col_tag.dtype)]) if pad else col_tag
    tags2 = tags.reshape(nb, block)
    cols = jnp.arange(n_cols + 1, dtype=jnp.int32)
    onehot8 = (tags2[:, :, None] == cols[None, None, :]).astype(jnp.uint8)

    # per-block histograms + intra-block exclusive ranks (uint8 traffic)
    block_hist = onehot8.sum(axis=1, dtype=jnp.int32)          # (NB, C+1)
    ranks8 = jnp.cumsum(onehot8, axis=1, dtype=jnp.uint8)      # inclusive
    own_rank = jnp.take_along_axis(
        ranks8, tags2[:, :, None].astype(jnp.int32), axis=2
    )[:, :, 0].astype(jnp.int32) - 1                           # exclusive

    # inter-block exclusive scan per column (tiny: N/block × C)
    blk_excl = jnp.cumsum(block_hist, axis=0) - block_hist     # (NB, C+1)
    count = block_hist.sum(axis=0)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(count)[:-1]])
    # padding rows land in the sentinel column and past position n; they are
    # sliced off dest below but must not inflate the reported count
    count = count.at[-1].add(-pad)

    base = start[tags2] + jnp.take_along_axis(
        blk_excl, tags2.astype(jnp.int32), axis=1)
    dest = (base + own_rank).reshape(-1)[:n]
    perm = jnp.zeros((n,), jnp.int32).at[dest].set(jnp.arange(n, dtype=jnp.int32))
    return Partitioned(perm, start, count)


def apply_partition(perm: jax.Array, *arrays: jax.Array):
    """Gather any number of parallel payload arrays through ``perm``."""
    out = tuple(a.reshape(-1)[perm] for a in arrays)
    return out if len(out) != 1 else out[0]


PARTITION_IMPLS = {
    "argsort": partition_argsort,
    "scatter": partition_scatter,
    "scatter2": partition_scatter2,
}
