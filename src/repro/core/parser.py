"""End-to-end single-device ParPaRaw parse pipeline (paper §3).

Pipeline (all on-device, one jit):

    bytes ─▶ symbol groups ─▶ chunk transition vectors ─▶ composite scan
          ─▶ replay (class codes) ─▶ record/column ids ─▶ tagging
          ─▶ stable partition (CSS) ─▶ field index ─▶ type conversion
          ─▶ validation

Static configuration (DFA, schema, chunk size, capacities) is baked into the
jitted closure; the only traced input is the padded byte buffer, so repeated
parses of same-shaped partitions reuse one executable — the property the
streaming layer (core/streaming.py) relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fields as fields_mod
from repro.core import offsets as offsets_mod
from repro.core import partition as partition_mod
from repro.core import tagging as tagging_mod
from repro.core import transition as transition_mod
from repro.core import typeconv as typeconv_mod
from repro.core import validation as validation_mod
from repro.core.dfa import PAD_BYTE, Dfa


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    dtype: str = "str"  # int32 | float32 | date | str
    selected: bool = True  # paper §4.3: deselected columns' symbols are
                           # marked irrelevant at tagging and never partake
                           # in partitioning/typeconv

    def __post_init__(self):
        assert self.dtype in typeconv_mod.PARSERS, self.dtype


@dataclasses.dataclass(frozen=True)
class Schema:
    columns: Tuple[Column, ...]

    @classmethod
    def of(cls, *cols: Tuple[str, str]) -> "Schema":
        return cls(tuple(Column(n, d) for n, d in cols))

    @property
    def n_cols(self) -> int:
        return len(self.columns)


@dataclasses.dataclass(frozen=True)
class ParserConfig:
    dfa: Dfa
    schema: Schema
    max_records: int
    chunk_size: int = 64
    tagging: str = "tagged"          # tagged | inline | vector
    partition_impl: str = "scatter"  # scatter | argsort
    use_matmul_scan: bool = False
    int_width: int = 11
    float_width: int = 24
    validate_columns: bool = False

    @property
    def record_delim_byte(self) -> int:
        return self.dfa.group_bytes[0]


class ParseResult(NamedTuple):
    css: jax.Array                       # (N,) uint8 partitioned symbols
    col_start: jax.Array                 # (n_cols+1,) int32
    col_count: jax.Array                 # (n_cols+1,) int32
    field_offset: jax.Array              # (n_cols, max_records) int32
    field_length: jax.Array              # (n_cols, max_records) int32
    values: Dict[str, typeconv_mod.Parsed]
    validation: validation_mod.Validation
    end_state: jax.Array                 # () int32 — carried into next partition
    last_record_end: jax.Array           # () int32 — byte pos of last record
                                         # delimiter (−1 if none); the
                                         # streaming carry-over boundary


def _parse_impl(raw_chunks: jax.Array, cfg: ParserConfig,
                initial_state: jax.Array) -> ParseResult:
    dfa = cfg.dfa
    n_cols = cfg.schema.n_cols

    # §3.1 — parsing context via composite scan, then replay.
    groups = transition_mod.byte_groups(raw_chunks, dfa)
    vecs = transition_mod.chunk_transition_vectors(groups, dfa)
    scanned = transition_mod.exclusive_scan_vectors(vecs, use_matmul=cfg.use_matmul_scan)
    start = transition_mod.start_states(scanned, dfa, initial_state=initial_state)
    classes, chunk_end, saw_invalid = transition_mod.replay(groups, start, dfa)
    end_state = chunk_end[-1]

    # §3.2 — record/column identification.
    flat_classes = classes.reshape(-1)
    ids = offsets_mod.symbol_ids(flat_classes)

    # §3.2/§4.1 — tagging (+ §4.3 column projection).
    selected = None
    if not all(c.selected for c in cfg.schema.columns):
        selected = np.asarray([c.selected for c in cfg.schema.columns])
    tagged = tagging_mod.tag_symbols(
        raw_chunks, flat_classes, ids.record_id, ids.column_id, n_cols,
        cfg.tagging, selected_mask=selected,
    )

    # §3.3 — stable partition into per-column CSS.
    part = partition_mod.PARTITION_IMPLS[cfg.partition_impl](tagged.col_tag, n_cols)
    if cfg.tagging == "tagged":
        # delim_flag is structurally all-False in tagged mode: skip one
        # N-sized gather+write (EXPERIMENTS.md §Perf parser iteration)
        css, rec_sorted, col_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag
        )
        flag_sorted = jnp.zeros_like(css, dtype=bool)
    else:
        css, rec_sorted, col_sorted, flag_sorted = partition_mod.apply_partition(
            part.perm, tagged.symbol, tagged.rec_tag, tagged.col_tag, tagged.delim_flag
        )

    # §3.3 — field index.
    if cfg.tagging == "tagged":
        findex = fields_mod.field_index_tagged(col_sorted, rec_sorted, n_cols, cfg.max_records)
    else:
        findex = fields_mod.field_index_terminated(
            flag_sorted, col_sorted, rec_sorted, part.col_start, n_cols, cfg.max_records
        )

    # §3.3 — type conversion.
    values = {}
    for c, col in enumerate(cfg.schema.columns):
        if not col.selected:
            continue
        off = findex.offset[c]
        ln = findex.length[c]
        if col.dtype == "int32":
            values[col.name] = typeconv_mod.parse_int(css, off, ln, width=cfg.int_width)
        elif col.dtype == "float32":
            values[col.name] = typeconv_mod.parse_float(css, off, ln, width=cfg.float_width)
        elif col.dtype == "date":
            values[col.name] = typeconv_mod.parse_date(css, off, ln)
        else:
            values[col.name] = typeconv_mod.parse_string_noop(css, off, ln)

    # §4.3 — validation.
    val = validation_mod.validate(
        flat_classes, ids.record_id, end_state, saw_invalid, dfa, cfg.max_records,
        expected_columns=n_cols if cfg.validate_columns else None,
    )

    # Streaming support (paper §4.4): byte position of the last record
    # delimiter — everything after it is the next partition's carry-over.
    pos = jnp.arange(flat_classes.shape[0], dtype=jnp.int32)
    from repro.core.dfa import RECORD_DELIM as _RD
    last_rec = jnp.max(jnp.where(flat_classes == _RD, pos, -1))

    return ParseResult(
        css=css,
        col_start=part.col_start,
        col_count=part.col_count,
        field_offset=findex.offset,
        field_length=findex.length,
        values=values,
        validation=val,
        end_state=end_state.astype(jnp.int32),
        last_record_end=last_rec.astype(jnp.int32),
    )


class Parser:
    """User-facing parser: host-side input prep + one jitted device pipeline."""

    def __init__(self, cfg: ParserConfig):
        self.cfg = cfg
        self._jit = jax.jit(lambda chunks, st: _parse_impl(chunks, cfg, st))

    # -- host-side -----------------------------------------------------------
    def prepare(self, data: bytes, pad_to: Optional[int] = None) -> np.ndarray:
        """bytes → ``(n_chunks, chunk_size) uint8`` with trailing record
        delimiter + PAD padding.  ``pad_to`` fixes the total byte capacity so
        different partitions share one compiled shape."""
        k = self.cfg.chunk_size
        raw = np.frombuffer(data, np.uint8)
        need_delim = raw.size == 0 or raw[-1] != self.cfg.record_delim_byte
        n = raw.size + (1 if need_delim else 0)
        total = pad_to if pad_to is not None else ((n + k - 1) // k) * k
        if total < n:
            raise ValueError(f"pad_to={pad_to} smaller than input ({n} bytes)")
        buf = np.full(total, PAD_BYTE, np.uint8)
        buf[: raw.size] = raw
        if need_delim:
            buf[raw.size] = self.cfg.record_delim_byte
        return buf.reshape(-1, k)

    # -- device-side ---------------------------------------------------------
    def parse_chunks(self, chunks, initial_state: Optional[jax.Array] = None) -> ParseResult:
        if initial_state is None:
            initial_state = jnp.int32(self.cfg.dfa.start_state)
        return self._jit(jnp.asarray(chunks), jnp.asarray(initial_state, jnp.int32))

    def parse(self, data: bytes) -> ParseResult:
        return self.parse_chunks(self.prepare(data))

    def infer_types(self, result: ParseResult):
        """Paper §4.3 type inference: min numeric type per column via a
        parallel reduction over the already-columnar CSS."""
        out = {}
        for c, col in enumerate(self.cfg.schema.columns):
            if not col.selected:
                continue
            n = self.cfg.max_records
            present = jnp.arange(n) < result.validation.n_records
            code = typeconv_mod.infer_column_type(
                result.css, result.field_offset[c], result.field_length[c],
                present, width=self.cfg.float_width,
            )
            out[col.name] = typeconv_mod.TYPE_CODES[int(code)]
        return out

    # -- export --------------------------------------------------------------
    def to_arrow(self, result: ParseResult) -> Dict[str, dict]:
        """Arrow-layout host export: per column a dict with ``validity``
        (packed bits), plus either ``values`` (numeric) or
        ``offsets``+``data`` (strings).  No pyarrow dependency; layouts match
        the Arrow columnar spec so buffers can be zero-copy wrapped."""
        n = int(result.validation.n_records)
        n = min(n, self.cfg.max_records)
        out = {}
        css = np.asarray(result.css)
        for c, col in enumerate(self.cfg.schema.columns):
            if not col.selected:
                continue
            parsed = result.values[col.name]
            if col.dtype == "str":
                ln = np.asarray(result.field_length[c][:n], np.int32)
                start = int(result.col_start[c])
                count = int(result.col_count[c])
                offsets = np.zeros(n + 1, np.int32)
                np.cumsum(ln, out=offsets[1:])
                data = css[start : start + count]
                if self.cfg.tagging != "tagged":
                    # Terminators/delimiters live inside the CSS in these
                    # modes; re-gather the value bytes densely for export.
                    off_abs = np.asarray(result.field_offset[c][:n])
                    pieces = [css[o : o + l] for o, l in zip(off_abs, ln)]
                    data = np.concatenate(pieces) if pieces else np.zeros(0, np.uint8)
                validity = ~np.asarray(parsed.empty[:n])
                out[col.name] = dict(offsets=offsets, data=data, validity=np.packbits(validity, bitorder="little"))
            else:
                validity = np.asarray(parsed.valid[:n])
                out[col.name] = dict(
                    values=np.asarray(parsed.value[:n]),
                    validity=np.packbits(validity, bitorder="little"),
                )
        return out
