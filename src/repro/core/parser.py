"""End-to-end single-device ParPaRaw parse pipeline (paper §3).

Pipeline (all on-device, one jit):

    bytes ─▶ symbol groups ─▶ chunk transition vectors ─▶ composite scan
          ─▶ replay (class codes) ─▶ record/column ids ─▶ materialize
             (tagging ─▶ stable partition (CSS) ─▶ field index ─▶ type
              conversion, per a static MaterializePlan) ─▶ validation

The stage bodies live in ``core/stages.py`` and are shared with the
distributed and streaming drivers; ``ParserConfig.backend`` selects who runs
the byte-level hot loops (``"reference"`` jnp vs ``"pallas"`` kernels, see
``core/backends.py``).

The pipeline is plan + executor: construction resolves the config into a
static :class:`stages.ParsePlan` once, and ``parse_chunks`` is a single
``jax.jit`` of :func:`stages.execute_plan` over that plan.  Static
configuration (DFA, schema, chunk size, capacities, backend) is baked into
the jitted closure; the only traced input is the padded byte buffer, so
repeated parses of same-shaped partitions reuse one executable — the
property the streaming engine (core/streaming.py) builds its device-carry
step on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as backends_mod
from repro.core import stages as stages_mod
from repro.core import typeconv as typeconv_mod
from repro.core.dfa import PAD_BYTE, Dfa


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    dtype: str = "str"  # int32 | float32 | date | str
    selected: bool = True  # paper §4.3: deselected columns' symbols are
                           # marked irrelevant at tagging and never partake
                           # in partitioning/typeconv

    def __post_init__(self):
        assert self.dtype in typeconv_mod.PARSERS, self.dtype


@dataclasses.dataclass(frozen=True)
class Schema:
    columns: Tuple[Column, ...]

    @classmethod
    def of(cls, *cols: Tuple[str, str]) -> "Schema":
        return cls(tuple(Column(n, d) for n, d in cols))

    @property
    def n_cols(self) -> int:
        return len(self.columns)


@dataclasses.dataclass(frozen=True)
class ParserConfig:
    """Static parse-pipeline configuration, baked into the jitted closure.

    Every knob is hashable config resolved at construction time
    (``__post_init__`` runs ``stages.plan_parse`` so typos fail fast,
    before any tracing).  Knobs:

    ``dfa``
        The format automaton (``make_csv_dfa`` / ``make_log_dfa`` / …):
        byte→group table, transition table, symbol classes (paper §3.1).
    ``schema``
        Column names, dtypes (``int32`` / ``float32`` / ``date`` / ``str``)
        and selection flags.  Deselected columns are dropped at tagging
        (paper §4.3) and never partake in partitioning or conversion.
    ``max_records``
        Field-index capacity per parse: the ``(n_cols, max_records)``
        offset/length matrices are statically this wide.  Records beyond it
        flag ``validation.truncated``.
    ``chunk_size``
        Bytes per chunk in the §3.1 DFA sweep.  Inputs are padded to whole
        chunks; one chunk is the granularity of the transition-vector scan.
    ``tagging``
        §3.2/§4.1 tagging-output layout: ``tagged`` (per-symbol
        record+column tags, the default), ``inline`` (terminator bytes kept
        inline in the CSS) or ``vector`` (separate terminator bit vector).
    ``partition_impl``
        §3.3 stable-partition implementation: ``auto`` (backend-resolved —
        see ``backends.default_partition_impl``), ``argsort``, ``scatter``,
        ``scatter2`` (jnp radix variants) or ``kernel`` (single-pass Pallas
        radix kernel, pallas backend only).
    ``use_matmul_scan``
        §3.1 composite scan as one-hot matmuls instead of gathers (the
        paper's SpMV formulation; useful where gathers are slow).
    ``int_width`` / ``float_width``
        Fixed conversion widths (bytes incl. sign) for int32/float32
        fields.  Fields longer than the width fail conversion (``valid``
        clears) — they also bound the fused kernels' per-field reads.
    ``validate_columns``
        §4.3 validation: require every record to have exactly
        ``schema.n_cols`` columns.
    ``backend``
        Stage-implementation bundle: ``reference`` (pure jnp oracle) or
        ``pallas`` (TPU kernels); see ``core/backends.py``.  The registry
        is open — third-party backends register under new names.
    ``interpret``
        Run Pallas kernels in interpret mode (exact, op-by-op; the only
        mode on CPU containers/CI).  Also steers ``partition_impl="auto"``.
    ``block_chunks``
        Chunks per Pallas grid step in the §3.1 DFA-scan kernels
        (``0`` = kernel default).
    ``fuse_typeconv``
        pallas: convert typed columns in fused gather+convert kernels that
        index the CSS in-kernel (no XLA gather, no ``(R, W)`` byte-matrix
        round-trip).  ``False`` restores the unfused XLA-gather +
        arithmetic-kernel path — the fusion's escape hatch and benchmark
        baseline.
    ``window_rows``
        pallas fused path: rows per CSS-window DMA block.  ``0`` uses the
        numparse kernel default (512); ``-1`` disables windowing and pins
        the whole-CSS-in-VMEM fused kernels (pre-window behaviour, capped
        at VMEM capacity on real hardware — kept as the windowed path's
        benchmark baseline).  Any positive value trades VMEM footprint
        (smaller windows) against grid overhead (more steps).
    ``max_window_bytes``
        pallas fused path: static CSS window tile in bytes.  ``0``
        auto-sizes from ``window_rows`` and the dtype's width (enough for
        every field ≤ width plus a terminator per row); explicit values are
        rounded up to the 128-byte lane alignment.  Columns whose fields
        overflow the tile (a mega-field) fall back at run time — to the
        whole-CSS fused kernel while the CSS is statically small, else to
        per-row windows — so the fallback never compiles an
        unbounded-VMEM kernel either.
    ``fuse_pipeline``
        pallas backend: run the whole replay→tag→partition→convert
        composition as ONE megakernel per partition
        (``kernels/fused_pipeline``) with no ``(R,)`` tag/offset arrays or
        permutation round-trips through HBM.  Resolved softly at plan time
        (``stages.plan_parse`` records the decision on
        ``ParsePlan.execute_path``): backends without a fused executor and
        index-only (``convert=False``) plans stay staged, and partitions
        larger than the backend's static ``fused_max_bytes`` cap take the
        staged tier at trace time.  Bit-identical to the staged path.
        ``None`` (the default) means *unset*: autotune resolution may fill
        it from measurements; unresolved it behaves as ``False`` (staged).
    ``partition_block_tags``
        pallas radix-partition kernel (``partition_impl="kernel"``): tags
        per kernel block.  ``0`` = kernel default.
    ``fused_max_bytes``
        Override of the backend's static fused-path byte cap (partitions
        larger than the cap run the staged tier).  ``0`` = backend default
        (4 MiB on pallas) — the real ceiling is a VMEM property only
        measurable on hardware, which is why it is a tunable.
    ``autotune``
        Consult the measured-config cache (``repro.tune``) at construction:
        every knob field still at its declared default is filled from the
        cache entry for this (backend, device, workload-shape) key, if one
        exists.  Explicitly set knobs always win; a cold cache leaves the
        heuristic defaults — resolution precedence ``explicit knob > cache
        > heuristic default`` (see ``docs/ARCHITECTURE.md`` §Autotuner).
        Cached values were bit-identity-checked against the reference
        backend when measured, so autotuning can never change outputs.
    """

    dfa: Dfa
    schema: Schema
    max_records: int
    chunk_size: int = 64
    tagging: str = "tagged"          # tagged | inline | vector
    partition_impl: str = "auto"     # auto | argsort | scatter | scatter2 |
                                     # kernel (backend-resolved; stages.py)
    use_matmul_scan: bool = False
    int_width: int = 11
    float_width: int = 24
    validate_columns: bool = False
    backend: str = "reference"       # reference | pallas (core/backends.py)
    interpret: bool = True           # Pallas interpret mode (CPU container)
    block_chunks: int = 0            # pallas DFA-scan grid: chunks per step
                                     # (0 = kernel default)
    fuse_typeconv: bool = True       # pallas: fused gather+convert kernels
                                     # (False = XLA gather + arithmetic kernel)
    window_rows: int = 0             # pallas fused: rows per CSS-window DMA
                                     # (0 = kernel default, -1 = whole CSS)
    max_window_bytes: int = 0        # pallas fused: static window tile bytes
                                     # (0 = auto-size from window_rows+width)
    fuse_pipeline: Optional[bool] = None  # pallas: whole-pipeline megakernel
                                     # (replay→tag→partition→convert, one
                                     # kernel per partition; soft-resolves
                                     # to staged on unsupported plans).
                                     # None = unset (autotune-resolvable),
                                     # behaves as False.
    partition_block_tags: int = 0    # pallas radix-partition kernel: tags
                                     # per block (0 = kernel default)
    fused_max_bytes: int = 0         # fused-path byte cap override
                                     # (0 = backend default)
    autotune: bool = False           # fill default-valued knobs from the
                                     # measured-config cache (repro.tune)

    def __post_init__(self):
        if self.autotune:
            # Measured-config resolution (repro.tune): fill every knob
            # field still at its declared default from the cache entry for
            # this (backend, device, workload-shape) key.  Runs before plan
            # validation so resolved values flow through plan_key /
            # config_key exactly like explicit ones.  Lazy import: the tune
            # package imports this module.
            from repro.tune import resolve as tune_resolve

            for name, value in tune_resolve.resolved_knobs(self).items():
                object.__setattr__(self, name, value)
        # fail fast on typos: backend name + partition impl resolution +
        # window-knob ranges (plan_parse exercises the full planning layer)
        stages_mod.plan_parse(self, backends_mod.get_backend(self.backend))

    @property
    def record_delim_byte(self) -> int:
        return self.dfa.group_bytes[0]


#: The per-partition parse output — defined next to the executor in
#: ``core/stages.py``; re-exported here as the public name.
ParseResult = stages_mod.ParseResult


class Parser:
    """User-facing parser: host-side input prep + one jitted plan executor."""

    def __init__(self, cfg: ParserConfig):
        self.cfg = cfg
        self.backend = backends_mod.get_backend(cfg.backend)
        #: Static ParsePlan resolved once; `parse_chunks` and the streaming
        #: engine's carry step both execute exactly this plan.
        self.plan = stages_mod.plan_parse(cfg, self.backend)
        self._jit = jax.jit(
            lambda chunks, st: stages_mod.execute_plan(
                chunks, self.plan, cfg, self.backend, initial_state=st
            )
        )

    # -- host-side -----------------------------------------------------------
    def prepare(self, data: bytes, pad_to: Optional[int] = None) -> np.ndarray:
        """bytes → ``(n_chunks, chunk_size) uint8`` with trailing record
        delimiter + PAD padding.  ``pad_to`` fixes the total byte capacity so
        different partitions share one compiled shape."""
        k = self.cfg.chunk_size
        raw = np.frombuffer(data, np.uint8)
        need_delim = raw.size == 0 or raw[-1] != self.cfg.record_delim_byte
        n = raw.size + (1 if need_delim else 0)
        total = pad_to if pad_to is not None else ((n + k - 1) // k) * k
        if total < n:
            raise ValueError(f"pad_to={pad_to} smaller than input ({n} bytes)")
        buf = np.full(total, PAD_BYTE, np.uint8)
        buf[: raw.size] = raw
        if need_delim:
            buf[raw.size] = self.cfg.record_delim_byte
        return buf.reshape(-1, k)

    # -- device-side ---------------------------------------------------------
    def parse_chunks(self, chunks, initial_state: Optional[jax.Array] = None) -> ParseResult:
        if initial_state is None:
            initial_state = jnp.int32(self.cfg.dfa.start_state)
        return self._jit(jnp.asarray(chunks), jnp.asarray(initial_state, jnp.int32))

    def parse(self, data: bytes) -> ParseResult:
        return self.parse_chunks(self.prepare(data))

    def infer_types(self, result: ParseResult):
        """Paper §4.3 type inference: min numeric type per column via a
        parallel reduction over the already-columnar CSS."""
        out = {}
        for c, col in enumerate(self.cfg.schema.columns):
            if not col.selected:
                continue
            n = self.cfg.max_records
            present = jnp.arange(n) < result.validation.n_records
            code = typeconv_mod.infer_column_type(
                result.css, result.field_offset[c], result.field_length[c],
                present, width=self.cfg.float_width,
            )
            out[col.name] = typeconv_mod.TYPE_CODES[int(code)]
        return out

    # -- export --------------------------------------------------------------
    def to_arrow(self, result: ParseResult) -> Dict[str, dict]:
        """Arrow-layout host export: per column a dict with ``validity``
        (packed bits), plus either ``values`` (numeric) or
        ``offsets``+``data`` (strings).  No pyarrow dependency; layouts match
        the Arrow columnar spec so buffers can be zero-copy wrapped."""
        n = int(result.validation.n_records)
        n = min(n, self.cfg.max_records)
        out = {}
        css = np.asarray(result.css)
        for c, col in enumerate(self.cfg.schema.columns):
            if not col.selected:
                continue
            parsed = result.values[col.name]
            if col.dtype == "str":
                ln = np.asarray(result.field_length[c][:n], np.int32)
                start = int(result.col_start[c])
                count = int(result.col_count[c])
                offsets = np.zeros(n + 1, np.int32)
                np.cumsum(ln, out=offsets[1:])
                data = css[start : start + count]
                if self.cfg.tagging != "tagged":
                    # Terminators/delimiters live inside the CSS in these
                    # modes; re-gather the value bytes densely for export.
                    off_abs = np.asarray(result.field_offset[c][:n])
                    pieces = [css[o : o + l] for o, l in zip(off_abs, ln)]
                    data = np.concatenate(pieces) if pieces else np.zeros(0, np.uint8)
                validity = ~np.asarray(parsed.empty[:n])
                out[col.name] = dict(offsets=offsets, data=data, validity=np.packbits(validity, bitorder="little"))
            else:
                validity = np.asarray(parsed.valid[:n])
                out[col.name] = dict(
                    values=np.asarray(parsed.value[:n]),
                    validity=np.packbits(validity, bitorder="little"),
                )
        return out
