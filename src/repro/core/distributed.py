"""Multi-device ParPaRaw: the paper's scans stretched across a TPU mesh.

The single-device pipeline needs three pieces of global information that
cross shard boundaries, each a tiny associative summary per device:

    1. the DFA state-transition composite      — (|S|,) int32
    2. the record count                        — ()   int32
    3. the (abs/rel, column-offset) pair       — 2 ×  int32

Inside ``shard_map`` every device folds its local chunks, ``all_gather``s
the per-device summaries (O(devices · |S|) bytes — independent of input
size), computes its exclusive prefix locally, and proceeds exactly like the
single-device parser: the *complete* ``stages.execute_plan`` composition —
context → ids → materialize → typeconv → §4.3 validation — runs per shard,
including the pallas kernels and the ``fuse_pipeline`` megakernel path.
The cross-device hooks are packaged as a :class:`stages.ParseStitch`
(:func:`mesh_stitch` below); no collective ever moves input-sized data.
This is the collective-level instance of the paper's decoupled-lookback
scan (DESIGN.md §3), and the reason throughput scales linearly with device
count: per-device work is N/D bytes, the stitching collective is constant.

Validation decomposes along record ownership — a record belongs to the
shard holding its terminating record delimiter.  Each shard's
``fields_per_record`` is exact for the records it owns once the head
record is corrected by the column seed (the field delimiters accumulated
since the last record delimiter *before* the shard — the same (tag, off)
semigroup that seeds the column ids), so the global min/max/conformance
reduce with O(1) ``pmin``/``pmax``/``psum`` collectives; ``end_state_ok``
is contributed by the last shard alone.

Each device emits its own columnar shard (per-host Arrow batches — what a
real ingest pipeline wants); record ids are global so shards concatenate
trivially, and :meth:`DistributedParser.assemble` stitches the boundary
records (whose bytes straddle shards) into a single-parser-identical
Arrow-layout table on the host.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import backends as backends_mod
from repro.core import offsets as offsets_mod
from repro.core import stages as stages_mod
from repro.core import transition as tr
from repro.core import typeconv as typeconv_mod
from repro.core import validation as validation_mod
from repro.core.parser import ParserConfig


class ShardedParse(NamedTuple):
    """Per-device columnar shard with globally consistent record ids.

    Leading axes are sharded over the mesh, so the assembled pytree holds
    per-shard arrays back to back: ``css`` is ``(D·N_local,)``,
    ``col_start`` is ``(D·(n_cols+1),)``, the field index is
    ``(D·n_cols, max_records)``, each ``values`` leaf is
    ``(D·max_records,)`` — reshape with a leading ``D`` to address shard
    ``d``.  ``validation`` carries the *global* §4.3 scalars (replicated)
    with per-shard ``record_ok`` on shard-local record ids.
    """

    classes: jax.Array       # (C_local·K,) uint8 per device (global: (C·K,))
    css: jax.Array           # (N_local,) uint8 partitioned symbols
    col_start: jax.Array     # (n_cols+1,) int32 per shard
    col_count: jax.Array     # (n_cols+1,) int32
    field_offset: jax.Array  # (n_cols, max_records) int32, local CSS positions
    field_length: jax.Array  # (n_cols, max_records) int32
    field_present: jax.Array # (n_cols, max_records) bool
    values: Dict[str, typeconv_mod.Parsed]  # per-shard typed columns
    validation: validation_mod.Validation   # global scalars + local record_ok
    rec_base: jax.Array      # () int32 — first global record id in this shard
    n_records: jax.Array     # () int32 — global record count (replicated)


def _device_prefix_vec(local_comp: jax.Array, axis) -> jax.Array:
    """Exclusive composite of all preceding devices' transition summaries."""
    all_comps = jax.lax.all_gather(local_comp, axis)  # (D, S)
    inc = jax.lax.associative_scan(tr.compose, all_comps, axis=0)
    me = jax.lax.axis_index(axis)
    ident = tr.identity_vector(local_comp.shape[-1])
    prev = inc[jnp.maximum(me - 1, 0)]
    return jnp.where(me == 0, ident, prev)


def _device_prefix_offsets(rec: jax.Array, col_t: jax.Array, col_o: jax.Array, axis):
    """Exclusive record-count and column-offset prefixes across devices."""
    all_rec = jax.lax.all_gather(rec, axis)          # (D,)
    me = jax.lax.axis_index(axis)
    rec_prefix = (jnp.cumsum(all_rec) - all_rec)[me]
    n_total = jnp.sum(all_rec)

    all_t = jax.lax.all_gather(col_t, axis)
    all_o = jax.lax.all_gather(col_o, axis)
    t_inc, o_inc = jax.lax.associative_scan(offsets_mod.combine_col, (all_t, all_o), axis=0)
    prev_t = t_inc[jnp.maximum(me - 1, 0)]
    prev_o = o_inc[jnp.maximum(me - 1, 0)]
    t = jnp.where(me == 0, offsets_mod.REL, prev_t)
    o = jnp.where(me == 0, 0, prev_o)
    return rec_prefix, t, o, n_total


def _and_reduce(x: jax.Array, axis) -> jax.Array:
    """AND across the mesh axis — a () int32 psum, never input-sized."""
    return jax.lax.psum(jnp.logical_not(x).astype(jnp.int32), axis) == 0


def mesh_stitch(cfg, plan: stages_mod.ParsePlan, axis,
                n_devices: int) -> stages_mod.ParseStitch:
    """Cross-device hooks for :func:`stages.execute_plan` under shard_map.

    Every hook exchanges only O(D · |S|) summary data (see
    ``stages.ParseStitch``); ``n_devices`` is the static mesh extent along
    ``axis`` (a name or tuple of names, linearized).
    """
    expected = plan.expected_columns
    accept = np.asarray(cfg.dfa.accept)

    def prefix_fn(vecs):
        return _device_prefix_vec(tr.fold_vectors(vecs), axis)

    def offsets_fn(summ):
        rec_l, t_l, o_l = offsets_mod.fold_summary(summ)
        rec_base, t_p, o_p, n_total = _device_prefix_offsets(rec_l, t_l, o_l, axis)
        local_offs = offsets_mod.scan_chunk_offsets(summ)
        g_t, g_o = offsets_mod.combine_col(
            (jnp.broadcast_to(t_p, local_offs.col_tag.shape),
             jnp.broadcast_to(o_p, local_offs.col_offset.shape)),
            (local_offs.col_tag, local_offs.col_offset),
        )
        offs = offsets_mod.ChunkOffsets(local_offs.rec_offset + rec_base, g_t, g_o)
        return offs, rec_base, o_p, n_total

    def validation_fn(fields_per_rec, n_local, end_state, saw_invalid, n_total):
        # §4.3 across the mesh: per-shard counts are exact for owned
        # records (head seeded by the caller), so every global quantity is
        # an O(1) reduction — the same arithmetic validation.validate runs
        # on the flat class stream, decomposed along record ownership.
        m = fields_per_rec.shape[0]
        is_last = jax.lax.axis_index(axis) == n_devices - 1
        end_ok = _and_reduce(
            jnp.where(is_last, jnp.asarray(accept)[end_state.astype(jnp.int32)], True),
            axis)
        no_inv = _and_reduce(~saw_invalid, axis)
        rec_live = jnp.arange(m) < n_local
        big = jnp.int32(2**31 - 1)
        minc = jax.lax.pmin(jnp.min(jnp.where(rec_live, fields_per_rec, big)), axis)
        maxc = jax.lax.pmax(jnp.max(jnp.where(rec_live, fields_per_rec, 0)), axis)
        if expected is None:
            record_ok = rec_live
        else:
            record_ok = rec_live & (fields_per_rec == expected)
        ok = end_ok & no_inv
        if expected is not None:
            ok &= _and_reduce(jnp.all(record_ok | ~rec_live), axis)
        return validation_mod.Validation(
            ok, end_ok, no_inv, n_total.astype(jnp.int32), minc, maxc, record_ok
        )

    return stages_mod.ParseStitch(prefix_fn, offsets_fn, validation_fn)


def _shard_parse(chunks: jax.Array, cfg: ParserConfig,
                 plan: stages_mod.ParsePlan,
                 stitch: stages_mod.ParseStitch, axis) -> ShardedParse:
    """Runs on every device under shard_map; ``chunks (C_local, K)``."""
    backend = backends_mod.get_backend(cfg.backend)

    # The complete per-partition composition — staged or megakernel-fused,
    # exactly as the single-device Parser runs it — with the cross-device
    # stitch plugged in.
    res = stages_mod.execute_plan(chunks, plan, cfg, backend, stitch=stitch)

    # rec_base for shard concatenation / host assembly: re-fold the chunk
    # summaries.  Identical ops to the fold inside execute_plan (or, on the
    # fused path, inside the backend's stitched summary pass), so XLA CSE
    # dedupes it — and it is O(C·|S|) regardless.
    ctx = stages_mod.determine_contexts(chunks, cfg, backend,
                                        prefix_fn=stitch.prefix_fn)
    _, rec_base, _, _ = stitch.offsets_fn(ctx.summaries)

    return ShardedParse(
        classes=ctx.classes.reshape(-1),
        css=res.css,
        col_start=res.col_start,
        col_count=res.col_count,
        field_offset=res.field_offset,
        field_length=res.field_length,
        field_present=res.field_present,
        values=res.values,
        validation=res.validation,
        rec_base=rec_base.reshape(1),  # rank-1 so shards concatenate
        n_records=res.validation.n_records,
    )


class DistributedParser:
    """shard_map-wrapped ParPaRaw over a device mesh.

    ``max_records`` in the config is *per shard* here.  The input byte
    buffer is sharded along its chunk axis over ``axis_names`` (all data
    axes flattened); outputs keep the same sharding, one columnar shard per
    device.

    ``convert=True`` (the default) runs the full plan per shard — CSS +
    field index + typed columns + global validation all materialize
    device-locally, through whichever backend/tagging/fusion path the
    config picks.  ``convert=False`` keeps the historical index-only
    export (shards ship the CSS + field index; hosts convert), which the
    dry-run roofline harness still uses.
    """

    def __init__(self, cfg: ParserConfig, mesh: Mesh,
                 axis_names: Sequence[str] = ("data",), convert: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.n_devices = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        #: Static ParsePlan resolved once — the same planning layer every
        #: driver adopts (staged or fused per cfg.fuse_pipeline).
        self.plan = stages_mod.plan_parse(
            cfg, backends_mod.get_backend(cfg.backend), convert=convert
        )
        axis = self.axis_names
        stitch = mesh_stitch(cfg, self.plan, axis, self.n_devices)
        spec_in = P(axis, None)
        out_specs = ShardedParse(
            classes=P(axis),
            css=P(axis),
            col_start=P(axis),
            col_count=P(axis),
            field_offset=P(axis, None),
            field_length=P(axis, None),
            field_present=P(axis, None),
            values={name: typeconv_mod.Parsed(P(axis), P(axis), P(axis))
                    for name, _, _ in self.plan.materialize.convert},
            validation=validation_mod.Validation(
                ok=P(), end_state_ok=P(), no_invalid=P(), n_records=P(),
                min_columns=P(), max_columns=P(), record_ok=P(axis),
            ),
            rec_base=P(axis),
            n_records=P(),
        )

        plan = self.plan

        def wrapped(chunks):
            return _shard_parse(chunks, cfg, plan, stitch, axis)

        self._fn = jax.jit(
            shard_map(
                wrapped, mesh=mesh, in_specs=(spec_in,), out_specs=out_specs,
                check_rep=False,
            )
        )

    def prepare(self, data: bytes) -> np.ndarray:
        """``Parser.prepare`` plus padding the chunk *count* to a mesh
        multiple — appended all-PAD chunks are inert (identity transitions,
        no symbols), exactly like the in-chunk PAD tail."""
        from repro.core.parser import Parser

        chunks = Parser(self.cfg).prepare(data)
        k = self.cfg.chunk_size
        n = chunks.shape[0]
        target = -(-n // self.n_devices) * self.n_devices
        if target != n:
            from repro.core.dfa import PAD_BYTE
            pad = np.full((target - n, k), PAD_BYTE, np.uint8)
            chunks = np.concatenate([chunks, pad], axis=0)
        return chunks

    def parse_chunks(self, chunks) -> ShardedParse:
        return self._fn(chunks)

    def lower(self, n_chunks: int, chunk_bytes: int):
        """ShapeDtypeStruct lowering hook — the dry-run harness and the
        collective-accounting tests/bench compile this without data."""
        spec = jax.ShapeDtypeStruct((n_chunks, chunk_bytes), jnp.uint8)
        return self._fn.lower(spec)

    # -- host assembly -----------------------------------------------------

    def assemble(self, shards: ShardedParse) -> Dict[str, dict]:
        """Stitch the per-device shards into one Arrow-layout table,
        bit-identical to ``Parser.to_arrow`` on the unsharded input.

        Only *boundary* records need host work: record ``rec_base[d]`` (the
        first record owned by shard ``d ≥ 1``) may have bytes on earlier
        shards, so its fields are re-gathered by concatenating each
        holding shard's CSS piece — shard ``e`` holds a piece of record
        ``r`` iff ``0 ≤ r − rec_base[e] ≤ n_local[e]`` (its own records
        plus its unterminated tail) — and numeric fields re-parse through
        the reference converters (pinned bit-identical to the kernel
        paths by the parity suites).  Everything else is a pure gather
        from the owning shard.  O(n_records) host work, like the
        non-tagged ``to_arrow`` export.
        """
        cfg = self.cfg
        d_cnt = self.n_devices
        n_cols = len(cfg.schema.columns)
        m = cfg.max_records
        n_total = int(shards.n_records)
        rec_base = np.asarray(shards.rec_base).reshape(d_cnt).astype(np.int64)
        n_local = np.diff(np.append(rec_base, n_total))
        css = np.asarray(shards.css).reshape(d_cnt, -1)
        f_off = np.asarray(shards.field_offset).reshape(d_cnt, n_cols, m)
        f_len = np.asarray(shards.field_length).reshape(d_cnt, n_cols, m)
        f_pres = np.asarray(shards.field_present).reshape(d_cnt, n_cols, m)
        cs = np.asarray(shards.col_start).reshape(d_cnt, n_cols + 1)
        cc = np.asarray(shards.col_count).reshape(d_cnt, n_cols + 1)
        terminated = cfg.tagging != "tagged"

        rid = np.arange(n_total)
        owner = np.searchsorted(rec_base, rid, side="right") - 1
        local = rid - rec_base[owner]
        boundary = {int(rec_base[d]) for d in range(1, d_cnt)
                    if rec_base[d] < n_total}

        def tail_piece(e: int, c: int) -> np.ndarray:
            # Terminated modes index a field only on the shard holding its
            # terminator, so an unterminated tail piece has *no* entry on
            # the shard that holds its bytes.  Those bytes are exactly the
            # suffix of column c's CSS segment after the last terminated
            # field (off+len points at the terminator; skip it).
            ends = f_off[e, c] + f_len[e, c]
            ends = ends[f_pres[e, c].astype(bool)]
            lo = int(ends.max()) + 1 if ends.size else int(cs[e, c])
            return css[e, lo:int(cs[e, c]) + int(cc[e, c])]

        def field_bytes(r: int, c: int) -> np.ndarray:
            pieces = []
            for e in range(d_cnt):
                lr = r - rec_base[e]
                if lr < 0 or lr > n_local[e] or lr >= m:
                    continue
                if terminated and lr == n_local[e] and not f_pres[e, c, lr]:
                    b = tail_piece(e, c)
                    if b.size:
                        pieces.append(b)
                    continue
                length = int(f_len[e, c, lr])
                if length <= 0:
                    continue
                off = int(f_off[e, c, lr])
                pieces.append(css[e, off:off + length])
            return (np.concatenate(pieces) if pieces
                    else np.zeros(0, np.uint8))

        pad = max(cfg.int_width, cfg.float_width, 20)

        def reparse_field(r: int, c: int, dtype: str):
            b = field_bytes(r, c)
            buf = jnp.asarray(np.concatenate([b, np.zeros(pad, np.uint8)]))
            off = jnp.zeros((1,), jnp.int32)
            ln = jnp.full((1,), len(b), jnp.int32)
            p = (typeconv_mod.parse_int(buf, off, ln, width=cfg.int_width)
                 if dtype == "int32" else
                 typeconv_mod.parse_float(buf, off, ln, width=cfg.float_width)
                 if dtype == "float32" else
                 typeconv_mod.parse_date(buf, off, ln))
            valid = bool(p.valid[0])
            value = p.value[0] if valid else np.zeros((), np.asarray(p.value).dtype)
            return value, valid

        out: Dict[str, dict] = {}
        for c, col in enumerate(cfg.schema.columns):
            if not col.selected:
                continue
            if col.dtype == "str":
                datas, lens = [], np.zeros(n_total, np.int32)
                for r in range(n_total):
                    if r in boundary:
                        b = field_bytes(r, c)
                    else:
                        e, lr = owner[r], local[r]
                        o, ln = int(f_off[e, c, lr]), int(f_len[e, c, lr])
                        b = css[e, o:o + ln]
                    datas.append(b)
                    lens[r] = len(b)
                offsets = np.zeros(n_total + 1, np.int32)
                np.cumsum(lens, out=offsets[1:])
                data = (np.concatenate(datas) if datas
                        else np.zeros(0, np.uint8))
                out[col.name] = dict(
                    offsets=offsets, data=data,
                    validity=np.packbits(lens > 0, bitorder="little"))
            else:
                parsed = shards.values[col.name]
                vals = np.asarray(parsed.value).reshape(d_cnt, m)
                valid = np.asarray(parsed.valid).reshape(d_cnt, m)
                v = vals[owner, local].copy()
                ok = valid[owner, local].copy()
                for r in boundary:
                    v[r], ok[r] = reparse_field(r, c, col.dtype)
                out[col.name] = dict(
                    values=v, validity=np.packbits(ok, bitorder="little"))
        return out
