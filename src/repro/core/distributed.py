"""Multi-device ParPaRaw: the paper's scans stretched across a TPU mesh.

The single-device pipeline needs three pieces of global information that
cross shard boundaries, each a tiny associative summary per device:

    1. the DFA state-transition composite      — (|S|,) int32
    2. the record count                        — ()   int32
    3. the (abs/rel, column-offset) pair       — 2 ×  int32

Inside ``shard_map`` every device folds its local chunks, ``all_gather``s
the per-device summaries (O(devices · |S|) bytes — independent of input
size), computes its exclusive prefix locally, and proceeds exactly like the
single-device parser.  This is the collective-level instance of the paper's
decoupled-lookback scan (DESIGN.md §3), and the reason throughput scales
linearly with device count: per-device work is N/D bytes, the stitching
collective is constant.

Each device emits its own columnar shard (per-host Arrow batches — what a
real ingest pipeline wants); record ids are global so shards concatenate
trivially.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import backends as backends_mod
from repro.core import offsets as offsets_mod
from repro.core import stages as stages_mod
from repro.core import transition as tr
from repro.core.parser import ParserConfig


class ShardedParse(NamedTuple):
    """Per-device columnar shard with globally consistent record ids."""

    classes: jax.Array       # (C_local·K,) uint8 per device (global: (C·K,))
    css: jax.Array           # (N_local,) uint8 partitioned symbols
    col_start: jax.Array     # (n_cols+1,) int32 per shard
    col_count: jax.Array     # (n_cols+1,) int32
    field_offset: jax.Array  # (n_cols, max_records) int32, local CSS positions
    field_length: jax.Array  # (n_cols, max_records) int32
    rec_base: jax.Array      # () int32 — first global record id in this shard
    n_records: jax.Array     # () int32 — global record count (replicated)


def _device_prefix_vec(local_comp: jax.Array, axis: str) -> jax.Array:
    """Exclusive composite of all preceding devices' transition summaries."""
    all_comps = jax.lax.all_gather(local_comp, axis)  # (D, S)
    inc = jax.lax.associative_scan(tr.compose, all_comps, axis=0)
    me = jax.lax.axis_index(axis)
    ident = tr.identity_vector(local_comp.shape[-1])
    prev = inc[jnp.maximum(me - 1, 0)]
    return jnp.where(me == 0, ident, prev)


def _device_prefix_offsets(rec: jax.Array, col_t: jax.Array, col_o: jax.Array, axis: str):
    """Exclusive record-count and column-offset prefixes across devices."""
    all_rec = jax.lax.all_gather(rec, axis)          # (D,)
    me = jax.lax.axis_index(axis)
    rec_prefix = (jnp.cumsum(all_rec) - all_rec)[me]
    n_total = jnp.sum(all_rec)

    all_t = jax.lax.all_gather(col_t, axis)
    all_o = jax.lax.all_gather(col_o, axis)
    t_inc, o_inc = jax.lax.associative_scan(offsets_mod.combine_col, (all_t, all_o), axis=0)
    prev_t = t_inc[jnp.maximum(me - 1, 0)]
    prev_o = o_inc[jnp.maximum(me - 1, 0)]
    t = jnp.where(me == 0, offsets_mod.REL, prev_t)
    o = jnp.where(me == 0, 0, prev_o)
    return rec_prefix, t, o, n_total


def _shard_parse(chunks: jax.Array, cfg: ParserConfig,
                 plan: stages_mod.ParsePlan, axis: str) -> ShardedParse:
    """Runs on every device under shard_map; ``chunks (C_local, K)``."""
    backend = backends_mod.get_backend(cfg.backend)

    # ---- §3.1 across the mesh: context determination (shared stage with a
    # cross-device prefix plugged in) --------------------------------------
    ctx = stages_mod.determine_contexts(
        chunks, cfg, backend,
        prefix_fn=lambda vecs: _device_prefix_vec(tr.fold_vectors(vecs), axis),
    )

    # ---- §3.2 across the mesh: record/column offsets ---------------------
    summ = ctx.summaries
    rec_l, t_l, o_l = offsets_mod.fold_summary(summ)
    rec_base, t_p, o_p, n_total = _device_prefix_offsets(rec_l, t_l, o_l, axis)

    local_offs = offsets_mod.scan_chunk_offsets(summ)
    g_t, g_o = offsets_mod.combine_col(
        (jnp.broadcast_to(t_p, local_offs.col_tag.shape),
         jnp.broadcast_to(o_p, local_offs.col_offset.shape)),
        (local_offs.col_tag, local_offs.col_offset),
    )
    offs = offsets_mod.ChunkOffsets(local_offs.rec_offset + rec_base, g_t, g_o)
    ids = stages_mod.identify_symbols(ctx, chunk_offsets=offs)

    # ---- §3.3 locally: materialize (shared stage, index-only plan) -------
    # Record tags are shard-local (0-based) so the field index stays small;
    # rec_base restores global ids.  The plan was resolved once at driver
    # construction with ``convert=False``: shards export the CSS + field
    # index and each host converts its own batch.
    local_rec = ids.record_id - rec_base
    cols, _ = stages_mod.materialize(
        chunks, ctx.classes, local_rec, ids.column_id, plan.materialize,
        cfg, backend
    )

    return ShardedParse(
        classes=ctx.classes.reshape(-1),
        css=cols.css,
        col_start=cols.col_start,
        col_count=cols.col_count,
        field_offset=cols.findex.offset,
        field_length=cols.findex.length,
        rec_base=rec_base.reshape(1),  # rank-1 so shards concatenate
        n_records=n_total,
    )


class DistributedParser:
    """shard_map-wrapped ParPaRaw over a device mesh.

    ``max_records`` in the config is *per shard* here.  The input byte
    buffer is sharded along its chunk axis over ``axis_names`` (all data
    axes flattened); outputs keep the same sharding, one columnar shard per
    device.
    """

    def __init__(self, cfg: ParserConfig, mesh: Mesh, axis_names: Sequence[str] = ("data",)):
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        #: Static ParsePlan (index-only: shards export unconverted) resolved
        #: once — the same planning layer every driver adopts.
        self.plan = stages_mod.plan_parse(
            cfg, backends_mod.get_backend(cfg.backend), convert=False
        )
        axis = self.axis_names
        spec_in = P(axis, None)
        out_specs = ShardedParse(
            classes=P(axis),
            css=P(axis),
            col_start=P(axis),
            col_count=P(axis),
            field_offset=P(axis, None),
            field_length=P(axis, None),
            rec_base=P(axis),
            n_records=P(),
        )

        plan = self.plan

        def wrapped(chunks):
            return _shard_parse(chunks, cfg, plan, axis)

        self._fn = jax.jit(
            shard_map(
                wrapped, mesh=mesh, in_specs=(spec_in,), out_specs=out_specs,
                check_rep=False,
            )
        )

    def parse_chunks(self, chunks) -> ShardedParse:
        return self._fn(chunks)

    def lower(self, n_chunks: int, chunk_bytes: int):
        """ShapeDtypeStruct lowering hook for the dry-run harness."""
        spec = jax.ShapeDtypeStruct((n_chunks, chunk_bytes), jnp.uint8)
        return self._fn.lower(spec)
