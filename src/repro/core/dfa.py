"""DFA specification for ParPaRaw parsing.

The paper (§3.1) drives parsing with a deterministic finite automaton whose
transition table is indexed by (state, symbol-group).  Symbol groups collapse
all byte values with identical transition behaviour (paper §4.5, Table 1) —
delimiter-separated formats only distinguish a handful of bytes, so the group
count stays tiny and the whole table fits in registers / SMEM.

Alongside the paper's transition table we carry an *emission* table of the
same shape that classifies every symbol read in a given state:

    DATA         — part of a field's value
    FIELD_DELIM  — terminates a field
    RECORD_DELIM — terminates a record
    CONTROL      — structural symbol that is not part of any value
                   (quotes, carriage returns, comment bodies, padding)

The emission table is what the paper calls the "three bitmap indexes"
(record-delimiter / field-delimiter / control), folded into one uint8 code so
a single gather produces all three.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# Symbol classes (values matter: tagging/offsets test them).
DATA = 0
FIELD_DELIM = 1
RECORD_DELIM = 2
CONTROL = 3

CLASS_NAMES = ("DATA", "FIELD_DELIM", "RECORD_DELIM", "CONTROL")

#: Byte used to pad inputs up to a chunk multiple.  Mapped to its own symbol
#: group that never changes state and always emits CONTROL.
PAD_BYTE = 0x00

#: Terminator byte for the inline-terminated CSS tagging mode (paper §4.1
#: recommends the ASCII unit separator 0x1F).
TERMINATOR_BYTE = 0x1F


@dataclasses.dataclass(frozen=True)
class Dfa:
    """A parsing DFA plus the symbol-group byte mapping.

    Attributes:
      transition: ``(n_states, n_groups) uint8`` — ``T[s, g]`` is the state
        reached after reading a symbol of group ``g`` in state ``s``.
      emission:   ``(n_states, n_groups) uint8`` — symbol class emitted when a
        symbol of group ``g`` is read in state ``s`` (i.e. *before* the
        transition fires).
      group_of:   ``(256,) uint8`` — byte value → symbol group.
      group_bytes: the distinguished bytes, one per non-catch-all group, in
        group order.  Used by the Pallas kernel's compare-based group matching
        (the TPU analogue of the paper's SWAR lookup registers).
      start_state: the sequential DFA's start state.
      accept:     ``(n_states,) bool`` — states that are valid at end-of-input
        (after the parser's trailing record-delimiter padding).
      invalid_state: index of the sink state tracking invalid transitions, or
        ``None`` for DFAs that accept everything.
    """

    name: str
    transition: np.ndarray
    emission: np.ndarray
    group_of: np.ndarray
    group_bytes: Tuple[int, ...]
    start_state: int
    accept: np.ndarray
    invalid_state: Optional[int]
    state_names: Tuple[str, ...]

    @property
    def n_states(self) -> int:
        return self.transition.shape[0]

    @property
    def n_groups(self) -> int:
        return self.transition.shape[1]

    def __post_init__(self):
        t = self.transition
        e = self.emission
        assert t.shape == e.shape and t.dtype == np.uint8 and e.dtype == np.uint8
        assert self.group_of.shape == (256,) and self.group_of.dtype == np.uint8
        assert int(t.max()) < self.n_states
        assert int(self.group_of.max()) < self.n_groups
        assert 0 <= self.start_state < self.n_states

    # The dataclass holds numpy arrays, which do not hash; jit-static plumbing
    # keys off identity instead.
    def __hash__(self):  # pragma: no cover - trivial
        return id(self)

    def __eq__(self, other):  # pragma: no cover - trivial
        return self is other

    def validate_tables(self) -> None:
        """Check the well-formedness contract every registered format's DFA
        must satisfy (used by the property tests and the format registry,
        and run once per config by ``stages.plan_parse``):

          * emission/transition tables are shape-consistent and in range;
          * every byte maps to a group (the 256-entry LUT is total);
          * each distinguished byte owns exactly one group — the kernels'
            compare-based group matching (``_group_select``) requires it —
            and the catch-all group is the last, byte-less group;
          * the PAD group is inert: it never changes state and always
            emits CONTROL, in *every* state;
          * ``group_bytes[0]`` is a record delimiter somewhere (it is the
            byte ``ParserConfig.record_delim_byte``, which ``prepare`` and
            the streaming flush append to close the final record);
          * the invalid state, if any, is an absorbing CONTROL sink.
        """
        assert self.emission.max() <= CONTROL
        assert self.accept.shape == (self.n_states,)
        assert int(self.group_of.max()) < self.n_groups
        # one distinguished byte per group; catch-all last, with no byte
        assert len(self.group_bytes) == self.n_groups - 1
        assert len(set(self.group_bytes)) == len(self.group_bytes)
        for g, b in enumerate(self.group_bytes):
            assert int(self.group_of[b]) == g, (g, b)
        # PAD is inert and CONTROL in every state
        g_pad = int(self.group_of[PAD_BYTE])
        assert self.group_bytes[g_pad] == PAD_BYTE
        assert (self.transition[:, g_pad] == np.arange(self.n_states)).all()
        assert (self.emission[:, g_pad] == CONTROL).all()
        # group 0 is the record-delimiter byte
        assert (self.emission[:, 0] == RECORD_DELIM).any()
        s_inv = self.invalid_state
        if s_inv is not None:
            # The invalid state is a sink.
            assert (self.transition[s_inv] == s_inv).all()
            # Nothing read in the sink state counts as data or delimiter.
            assert (self.emission[s_inv] == CONTROL).all()


def _lut(groups: dict, n_groups: int, catch_all: int) -> np.ndarray:
    lut = np.full(256, catch_all, np.uint8)
    for byte, g in groups.items():
        lut[byte] = g
    return lut


def make_csv_dfa(
    delimiter: bytes = b",",
    quote: bytes = b'"',
    record_delim: bytes = b"\n",
    comment: Optional[bytes] = None,
    handle_cr: bool = True,
    name: Optional[str] = None,
) -> Dfa:
    """RFC 4180 CSV DFA (paper Fig. 2 / Table 1), optionally with line comments.

    States (paper naming):
      EOR — start of a record (start of input / after a record delimiter)
      ENC — inside a quote-enclosed field
      FLD — inside an unquoted field
      EOF — just after a field delimiter ("end of field")
      ESC — just read a quote while enclosed (either the closing quote or the
            first half of an escaped double-quote)
      INV — invalid-transition sink
      CMT — inside a line comment (only when ``comment`` is given)

    Groups: record-delim, quote, field-delim, [comment], [CR], PAD, catch-all.
    """
    EOR, ENC, FLD, EOF, ESC, INV = range(6)
    state_names = ["EOR", "ENC", "FLD", "EOF", "ESC", "INV"]
    CMT = None
    if comment is not None:
        CMT = len(state_names)
        state_names.append("CMT")
    n_states = len(state_names)

    # --- group layout -------------------------------------------------------
    group_bytes = [record_delim[0], quote[0], delimiter[0]]
    G_REC, G_QUO, G_DEL = 0, 1, 2
    G_CMT = G_CR = G_PAD = None
    if comment is not None:
        G_CMT = len(group_bytes)
        group_bytes.append(comment[0])
    if handle_cr:
        G_CR = len(group_bytes)
        group_bytes.append(0x0D)
    G_PAD = len(group_bytes)
    group_bytes.append(PAD_BYTE)
    G_ANY = len(group_bytes)  # catch-all group has no distinguished byte
    n_groups = G_ANY + 1

    T = np.full((n_states, n_groups), INV, np.uint8)
    E = np.full((n_states, n_groups), CONTROL, np.uint8)

    def rule(state, group, new_state, sym_class):
        T[state, group] = new_state
        E[state, group] = sym_class

    # Record delimiter.
    for s in (EOR, FLD, EOF, ESC):
        rule(s, G_REC, EOR, RECORD_DELIM)
    rule(ENC, G_REC, ENC, DATA)  # newline inside quotes is data
    rule(INV, G_REC, INV, CONTROL)

    # Quote.
    rule(EOR, G_QUO, ENC, CONTROL)   # opening quote
    rule(EOF, G_QUO, ENC, CONTROL)   # opening quote
    rule(ENC, G_QUO, ESC, CONTROL)   # tentative closing quote
    rule(ESC, G_QUO, ENC, DATA)      # doubled quote -> one literal quote
    rule(FLD, G_QUO, INV, CONTROL)   # RFC4180: no quotes mid-unquoted-field
    rule(INV, G_QUO, INV, CONTROL)

    # Field delimiter.
    for s in (EOR, FLD, EOF, ESC):
        rule(s, G_DEL, EOF, FIELD_DELIM)
    rule(ENC, G_DEL, ENC, DATA)
    rule(INV, G_DEL, INV, CONTROL)

    # Catch-all data byte.
    for s in (EOR, FLD, EOF):
        rule(s, G_ANY, FLD, DATA)
    rule(ENC, G_ANY, ENC, DATA)
    rule(ESC, G_ANY, INV, CONTROL)  # junk after a closing quote
    rule(INV, G_ANY, INV, CONTROL)

    # Comment handling: '#' at start-of-record opens a comment that swallows
    # everything up to (and including) its newline; that newline does *not*
    # delimit a record, so comment lines produce no records at all.  This is
    # precisely the "more involved parsing rules" case the paper holds up
    # against format-specific quote-counting tricks (§1, §2).
    if comment is not None:
        rule(EOR, G_CMT, CMT, CONTROL)
        for s in (FLD, EOF):
            rule(s, G_CMT, FLD, DATA)  # '#' mid-record is plain data
        rule(ENC, G_CMT, ENC, DATA)
        rule(ESC, G_CMT, INV, CONTROL)
        rule(INV, G_CMT, INV, CONTROL)
        for g in range(n_groups):
            rule(CMT, g, CMT, CONTROL)
        rule(CMT, G_REC, EOR, CONTROL)  # closes the comment, emits no record
        if handle_cr:
            rule(CMT, G_CR, CMT, CONTROL)

    # Carriage return: structural (part of CRLF) outside quotes, data inside.
    if handle_cr:
        for s in (EOR, FLD, EOF, ESC):
            rule(s, G_CR, s, CONTROL)
        rule(ENC, G_CR, ENC, DATA)
        rule(INV, G_CR, INV, CONTROL)

    # Padding byte: inert everywhere.
    for s in range(n_states):
        rule(s, G_PAD, s, CONTROL)

    groups = {b: g for g, b in enumerate(group_bytes) if g != G_ANY}
    accept = np.zeros(n_states, bool)
    accept[EOR] = True

    return Dfa(
        name=name or ("csv" if comment is None else "csv+comment"),
        transition=T,
        emission=E,
        # All entries are distinguished bytes (the catch-all group has no
        # byte and was never appended) — dropping the last entry here would
        # lose PAD and make the kernels' compare-based matching classify
        # padding as data.
        group_of=_lut(groups, n_groups, G_ANY),
        group_bytes=tuple(group_bytes),
        start_state=EOR,
        accept=accept,
        invalid_state=INV,
        state_names=tuple(state_names),
    )


def make_simple_dfa(
    delimiter: bytes = b",",
    record_delim: bytes = b"\n",
    name: str = "simple",
) -> Dfa:
    """Quote-free delimiter format (the constrained baseline competing systems
    support; paper §2).  Three states so the scan machinery still exercises a
    non-trivial composite."""
    EOR, FLD, EOF = 0, 1, 2
    group_bytes = [record_delim[0], delimiter[0], PAD_BYTE]
    G_REC, G_DEL, G_PAD, G_ANY = 0, 1, 2, 3
    n_states, n_groups = 3, 4
    T = np.zeros((n_states, n_groups), np.uint8)
    E = np.zeros((n_states, n_groups), np.uint8)
    for s in (EOR, FLD, EOF):
        T[s, G_REC], E[s, G_REC] = EOR, RECORD_DELIM
        T[s, G_DEL], E[s, G_DEL] = EOF, FIELD_DELIM
        T[s, G_PAD], E[s, G_PAD] = s, CONTROL
        T[s, G_ANY], E[s, G_ANY] = FLD, DATA
    accept = np.zeros(n_states, bool)
    accept[EOR] = True
    return Dfa(
        name=name,
        transition=T,
        emission=E,
        group_of=_lut({b: g for g, b in enumerate(group_bytes)}, n_groups, G_ANY),
        group_bytes=tuple(group_bytes),
        start_state=EOR,
        accept=accept,
        invalid_state=None,
        state_names=("EOR", "FLD", "EOF"),
    )


def make_log_dfa(name: str = "clf") -> Dfa:
    """Common-Log-Format-style DFA: space-delimited fields with two distinct
    quoting scopes — ``[...]`` timestamps and ``"..."`` request strings.

    Demonstrates the paper's applicability claim: multiple independent
    enclosing contexts, which quote-parity tricks (Mison-style) cannot track.
    """
    EOR, FLD, EOF, QUO, BRK = range(5)
    group_bytes = [0x0A, ord('"'), ord(" "), ord("["), ord("]"), PAD_BYTE]
    G_REC, G_QUO, G_SP, G_LB, G_RB, G_PAD, G_ANY = range(7)
    n_states, n_groups = 5, 7
    T = np.zeros((n_states, n_groups), np.uint8)
    E = np.zeros((n_states, n_groups), np.uint8)

    def rule(s, g, ns, c):
        T[s, g], E[s, g] = ns, c

    for s in (EOR, FLD, EOF):
        rule(s, G_REC, EOR, RECORD_DELIM)
        rule(s, G_SP, EOF, FIELD_DELIM)
        rule(s, G_ANY, FLD, DATA)
        rule(s, G_QUO, QUO, CONTROL)
        rule(s, G_LB, BRK, CONTROL)
        rule(s, G_RB, FLD, DATA)  # stray ']' outside brackets: plain data
        rule(s, G_PAD, s, CONTROL)
    for g, c in ((G_REC, DATA), (G_SP, DATA), (G_ANY, DATA), (G_LB, DATA),
                 (G_RB, CONTROL), (G_PAD, CONTROL), (G_QUO, CONTROL)):
        rule(BRK, g, BRK if g not in (G_RB,) else FLD, c)
    T[BRK, G_RB] = FLD
    for g, c in ((G_REC, DATA), (G_SP, DATA), (G_ANY, DATA), (G_LB, DATA),
                 (G_RB, DATA), (G_PAD, CONTROL), (G_QUO, CONTROL)):
        rule(QUO, g, QUO if g != G_QUO else FLD, c)
    T[QUO, G_QUO] = FLD

    accept = np.zeros(n_states, bool)
    accept[EOR] = True
    return Dfa(
        name=name,
        transition=T,
        emission=E,
        group_of=_lut({b: g for g, b in enumerate(group_bytes)}, n_groups, G_ANY),
        group_bytes=tuple(group_bytes),
        start_state=EOR,
        accept=accept,
        invalid_state=None,
        state_names=("EOR", "FLD", "EOF", "QUO", "BRK"),
    )


def make_jsonl_dfa(max_depth: int = 4, name: str = "jsonl") -> Dfa:
    """JSON-Lines DFA: one top-level object per line (ROADMAP item 4).

    Nesting-depth tagging on the shared FSM engine: the depth-1 ``,`` and
    ``:`` of the record object emit FIELD_DELIM — an object's fields land in
    alternating key/value columns — while everything inside a nested
    container stays DATA, so a nested value is its *raw JSON subtext* in the
    CSS.  A plain DFA cannot count unbounded depth; nesting is bounded by
    ``max_depth`` with one (container, string, escape) state triple per
    depth level, and deeper input falls into the INV sink.

    Dialect notes (the shipped oracle in ``tests/oracles/jsonl.py`` mirrors
    these exactly):

      * Depth-1 string quotes are CONTROL (keys and string values appear
        unquoted in the CSS, like CSV's unquoting); escape sequences are
        kept RAW — ``\\"`` does not close the string, but no unescaping
        happens, the CSS carries the bytes verbatim.
      * Depth-1 spaces outside strings are CONTROL, so ``"a": 1`` feeds the
        int parser a clean ``1``.
      * Nested braces/brackets are not matched by type (``{`` closed by
        ``]`` is accepted) — depth is what the automaton tracks.
      * Raw newlines are only legal between records (inside a string or a
        nested value they are invalid JSON), so the record delimiter needs
        no quote context and blank lines produce no records.
      * Top-level non-object values and stray structural bytes hit INV; the
        parser's validation flags the partition.
    """
    assert max_depth >= 2, "max_depth < 2 cannot hold a nested value"
    state_names = ["EOR", "OBJ", "STR", "ESC", "DONE", "INV"]
    EOR, OBJ, STR, ESC, DONE, INV = range(6)
    NEST, NSTR, NESC = {}, {}, {}
    for d in range(2, max_depth + 1):
        NEST[d] = len(state_names); state_names.append(f"NEST{d}")
        NSTR[d] = len(state_names); state_names.append(f"NSTR{d}")
        NESC[d] = len(state_names); state_names.append(f"NESC{d}")
    n_states = len(state_names)

    group_bytes = [0x0A, ord('"'), ord("\\"), ord(","), ord(":"), ord("{"),
                   ord("}"), ord("["), ord("]"), ord(" "), PAD_BYTE]
    (G_REC, G_QUO, G_ESC, G_COM, G_COL, G_LB, G_RB,
     G_LS, G_RS, G_SP, G_PAD) = range(11)
    G_ANY = 11
    n_groups = 12

    # Unlisted (state, group) pairs are invalid JSON-Lines: default to the
    # absorbing sink, emitting CONTROL.
    T = np.full((n_states, n_groups), INV, np.uint8)
    E = np.full((n_states, n_groups), CONTROL, np.uint8)

    def rule(state, group, new_state, sym_class):
        T[state, group] = new_state
        E[state, group] = sym_class

    # Between records: blank lines and leading spaces produce nothing.
    rule(EOR, G_REC, EOR, CONTROL)
    rule(EOR, G_SP, EOR, CONTROL)
    rule(EOR, G_LB, OBJ, CONTROL)   # record opens with '{'

    # Depth 1, outside strings: the tagging level.
    rule(OBJ, G_QUO, STR, CONTROL)
    rule(OBJ, G_COM, OBJ, FIELD_DELIM)
    rule(OBJ, G_COL, OBJ, FIELD_DELIM)
    rule(OBJ, G_SP, OBJ, CONTROL)
    rule(OBJ, G_LB, NEST[2], DATA)  # nested value opens: raw subtext begins
    rule(OBJ, G_LS, NEST[2], DATA)
    rule(OBJ, G_RB, DONE, CONTROL)  # record object closes
    rule(OBJ, G_ANY, OBJ, DATA)     # unquoted token: numbers, true/false/null

    # Depth-1 strings: quotes dropped, escapes raw.
    rule(STR, G_QUO, OBJ, CONTROL)
    rule(STR, G_ESC, ESC, DATA)
    for g in (G_COM, G_COL, G_LB, G_RB, G_LS, G_RS, G_SP, G_ANY):
        rule(STR, g, STR, DATA)
    for g in (G_QUO, G_ESC, G_COM, G_COL, G_LB, G_RB, G_LS, G_RS, G_SP, G_ANY):
        rule(ESC, g, STR, DATA)

    # After the record's closing brace: only trailing spaces, then newline.
    rule(DONE, G_REC, EOR, RECORD_DELIM)
    rule(DONE, G_SP, DONE, CONTROL)

    # Nested containers, one state triple per depth.
    for d in range(2, max_depth + 1):
        dn, ds, de = NEST[d], NSTR[d], NESC[d]
        deeper = NEST.get(d + 1, INV)       # beyond max_depth: sink
        deeper_cls = DATA if d < max_depth else CONTROL
        shallower = NEST.get(d - 1, OBJ)
        for g in (G_LB, G_LS):
            rule(dn, g, deeper, deeper_cls)
        for g in (G_RB, G_RS):
            rule(dn, g, shallower, DATA)
        rule(dn, G_QUO, ds, DATA)           # nested quotes are raw subtext
        for g in (G_COM, G_COL, G_SP, G_ANY):
            rule(dn, g, dn, DATA)
        rule(ds, G_QUO, dn, DATA)
        rule(ds, G_ESC, de, DATA)
        for g in (G_COM, G_COL, G_LB, G_RB, G_LS, G_RS, G_SP, G_ANY):
            rule(ds, g, ds, DATA)
        for g in (G_QUO, G_ESC, G_COM, G_COL, G_LB, G_RB, G_LS, G_RS, G_SP,
                  G_ANY):
            rule(de, g, ds, DATA)

    for g in range(n_groups):
        rule(INV, g, INV, CONTROL)
    for s in range(n_states):
        rule(s, G_PAD, s, CONTROL)

    accept = np.zeros(n_states, bool)
    accept[EOR] = True
    return Dfa(
        name=name,
        transition=T,
        emission=E,
        group_of=_lut({b: g for g, b in enumerate(group_bytes)}, n_groups, G_ANY),
        group_bytes=tuple(group_bytes),
        start_state=EOR,
        accept=accept,
        invalid_state=INV,
        state_names=tuple(state_names),
    )


def make_zone_dfa(name: str = "zone") -> Dfa:
    """DNS-zone-file DFA: whitespace-delimited resource records with ``;``
    line comments and parenthesized multi-line records ("Parsing Millions
    of DNS Records per Second", PAPERS.md; ROADMAP item 4).

    Whitespace-run collapsing is solved *inside* the automaton: only the
    first space/tab after field content emits FIELD_DELIM; further
    whitespace (and leading whitespace) is CONTROL, so consecutive spaces
    never mint empty fields.  ``(`` switches newline's meaning — inside
    parens it behaves like whitespace, so one record spans lines and the
    streaming carry machinery handles it exactly like a quoted CSV newline.

    Dialect notes (mirrored by ``tests/oracles/zone.py``):

      * Blank lines and full-line comments produce no records; a comment
        after record content is swallowed, and its newline ends the record.
      * A comment inside parens runs to its newline; the record continues
        on the next line.  A ``;`` directly after in-paren field content
        emits FIELD_DELIM (top level needs none — the record delimiter that
        follows closes the field).
      * Nested ``(`` and stray ``)`` are plain data; no paren matching.
      * A record *ending* in ``)`` carries one trailing empty field (the
        whitespace before ``)`` already delimited), like CSV's ``a,b,`` —
        the schema's n_cols clamp drops it.
    """
    EOR, FLD, EOF, CMT, CM0, POF, PFD, PCM = range(8)
    state_names = ("EOR", "FLD", "EOF", "CMT", "CM0", "POF", "PFD", "PCM")
    n_states = 8
    group_bytes = [0x0A, ord(" "), 0x09, ord(";"), ord("("), ord(")"),
                   PAD_BYTE]
    G_REC, G_SP, G_TAB, G_SEM, G_LP, G_RP, G_PAD = range(7)
    G_ANY = 7
    n_groups = 8

    T = np.zeros((n_states, n_groups), np.uint8)
    E = np.zeros((n_states, n_groups), np.uint8)

    def rule(state, group, new_state, sym_class):
        T[state, group] = new_state
        E[state, group] = sym_class

    # EOR: start of line, no record content yet.
    rule(EOR, G_REC, EOR, CONTROL)      # blank line: no record
    for g in (G_SP, G_TAB):
        rule(EOR, g, EOR, CONTROL)      # leading whitespace skipped
    rule(EOR, G_SEM, CM0, CONTROL)      # full-line comment: no record
    rule(EOR, G_LP, POF, CONTROL)
    rule(EOR, G_RP, FLD, DATA)          # stray ')' is data
    rule(EOR, G_ANY, FLD, DATA)

    # FLD: inside a field at top level.
    rule(FLD, G_REC, EOR, RECORD_DELIM)
    for g in (G_SP, G_TAB):
        rule(FLD, g, EOF, FIELD_DELIM)  # first whitespace ends the field
    rule(FLD, G_SEM, CMT, CONTROL)      # comment; record delim follows later
    rule(FLD, G_LP, POF, FIELD_DELIM)   # '(' right after content delimits
    rule(FLD, G_RP, FLD, DATA)
    rule(FLD, G_ANY, FLD, DATA)

    # EOF: after a field delimiter (whitespace run continues).
    rule(EOF, G_REC, EOR, RECORD_DELIM)
    for g in (G_SP, G_TAB):
        rule(EOF, g, EOF, CONTROL)      # collapse the run: no empty fields
    rule(EOF, G_SEM, CMT, CONTROL)
    rule(EOF, G_LP, POF, CONTROL)
    rule(EOF, G_RP, FLD, DATA)
    rule(EOF, G_ANY, FLD, DATA)

    # CMT: comment after record content — its newline ends the record.
    for g in range(n_groups):
        rule(CMT, g, CMT, CONTROL)
    rule(CMT, G_REC, EOR, RECORD_DELIM)

    # CM0: comment on a contentless line — its newline emits nothing.
    for g in range(n_groups):
        rule(CM0, g, CM0, CONTROL)
    rule(CM0, G_REC, EOR, CONTROL)

    # POF: inside parens, whitespace context (newline = whitespace).
    for g in (G_REC, G_SP, G_TAB):
        rule(POF, g, POF, CONTROL)
    rule(POF, G_SEM, PCM, CONTROL)
    rule(POF, G_LP, PFD, DATA)          # nested '(' is plain data
    rule(POF, G_RP, EOF, CONTROL)       # close paren, back to top level
    rule(POF, G_ANY, PFD, DATA)

    # PFD: inside parens, inside a field.
    for g in (G_REC, G_SP, G_TAB):
        rule(PFD, g, POF, FIELD_DELIM)
    rule(PFD, G_SEM, PCM, FIELD_DELIM)  # field ends before the comment
    rule(PFD, G_LP, PFD, DATA)
    rule(PFD, G_RP, EOF, FIELD_DELIM)
    rule(PFD, G_ANY, PFD, DATA)

    # PCM: comment inside parens — its newline resumes the record.
    for g in range(n_groups):
        rule(PCM, g, PCM, CONTROL)
    rule(PCM, G_REC, POF, CONTROL)

    for s in range(n_states):
        rule(s, G_PAD, s, CONTROL)

    accept = np.zeros(n_states, bool)
    accept[EOR] = True
    return Dfa(
        name=name,
        transition=T,
        emission=E,
        group_of=_lut({b: g for g, b in enumerate(group_bytes)}, n_groups, G_ANY),
        group_bytes=tuple(group_bytes),
        start_state=EOR,
        accept=accept,
        invalid_state=None,
        state_names=state_names,
    )
