"""Cache-driven knob resolution — the autotuner's runtime half.

``ParserConfig(autotune=True)`` calls :func:`resolved_knobs` during
construction; the returned values are written onto the (frozen) config
*before* plan validation, so the resolved knobs flow through
``stages.plan_parse`` / ``backend.config_key`` exactly like explicit ones
— one resolution point, every driver downstream (parser, streaming,
distributed, serving registry) sees tuned values.

Resolution precedence, per knob (see ``docs/ARCHITECTURE.md``):

  1. **explicit knob** — a field not at its declared default is caller
     intent and is never touched;
  2. **cache** — the entry under the config's tuning key
     (user cache over committed seed cache), value re-validated against
     the knob's candidate constraints (a stale or hand-edited entry can
     misconfigure nothing);
  3. **heuristic default** — the pre-autotuner behaviour, untouched
     (``partition_impl="auto"`` → ``backend.default_partition_impl``,
     ``fuse_pipeline=None`` → staged, kernel-default geometry).

Cached values can never change parse *outputs*: every candidate the tuner
stores was bit-identity-checked against the reference backend when it was
measured (``tuner.tune_parse``), and the constraint re-check here rejects
values the backend would refuse.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.tune import cache as cache_mod
from repro.tune import space as space_mod


def resolved_knobs(cfg, backend=None) -> Dict[str, Any]:
    """The cache's knob values for ``cfg``, restricted to fields still at
    their declared defaults and values valid for the backend.  Empty on a
    cold cache — the caller's heuristics then apply unchanged."""
    if backend is None:
        from repro.core import backends as backends_mod

        backend = backends_mod.get_backend(cfg.backend)
    entry = cache_mod.chain_lookup(cache_mod.tune_key(cfg)[0])
    if not entry:
        return {}
    knobs = entry.get("knobs")
    if not isinstance(knobs, dict):
        return {}
    out: Dict[str, Any] = {}
    for k in space_mod.knobs_for(backend):
        if k.name not in knobs:
            continue
        if getattr(cfg, k.name, k.default) != k.default:
            continue  # explicit knob wins over the cache
        value = knobs[k.name]
        if not k.valid(backend, value):
            continue  # stale/foreign entry: heuristic default wins
        if value != k.default:
            out[k.name] = value
    return out


def stream_entry(cfg) -> Optional[dict]:
    """The cache's ``stream`` section for ``cfg`` (partition bytes, serve
    tier ladder), or ``None``."""
    entry = cache_mod.chain_lookup(cache_mod.tune_key(cfg)[0])
    if not entry:
        return None
    s = entry.get("stream")
    return s if isinstance(s, dict) else None


def tuned_serve_tiers(cfg, default: Tuple[int, ...]) -> Tuple[int, ...]:
    """The measured recompile-tier ladder for ``cfg``'s workload
    (``serve.ParseService`` batch widths), or ``default`` on a cold cache.

    Validated like every cached value: a non-empty ascending tuple of
    positive ints, else the default."""
    s = stream_entry(cfg)
    tiers = (s or {}).get("serve_tiers")
    if (isinstance(tiers, (list, tuple)) and tiers
            and all(isinstance(t, int) and t >= 1 for t in tiers)
            and list(tiers) == sorted(set(tiers))):
        return tuple(int(t) for t in tiers)
    return tuple(default)


def tuned_stream_partition_bytes(cfg, default: int) -> int:
    """The measured streaming partition size for ``cfg``'s workload, or
    ``default`` on a cold cache."""
    s = stream_entry(cfg)
    v = (s or {}).get("partition_bytes")
    return int(v) if isinstance(v, int) and v > 0 else int(default)
