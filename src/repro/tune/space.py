"""The autotuner's search space: every perf knob declared once.

A :class:`Knob` names a ``ParserConfig`` field, the pipeline stage it
gates, the field's *default* value (the "unset" sentinel — only fields
still at their default are cache-resolvable, which is what makes the
precedence ``explicit knob > cache > heuristic default`` decidable on a
frozen dataclass), and its candidate values per backend.  The tuner
sweeps these; :mod:`repro.tune.resolve` validates cached values against
the same declarations, so a stale or hand-edited cache entry can
misconfigure nothing — invalid values fall back to the heuristic default.

Stages (what a knob gates):

  ``scan``       — the §3.1 DFA sweep (grid geometry, scan formulation)
  ``partition``  — the §3.3 stable partition (impl choice, kernel blocks)
  ``typeconv``   — the §3.3 conversion kernels (fusion, window DMA tiles)
  ``pipeline``   — the staged-vs-fused whole-pipeline execution choice
  ``stream``     — the §4.4 streaming/serving geometry (partition bytes,
                   recompile tier ladder).  Stream knobs are not
                   ``ParserConfig`` fields; they live in the cache entry's
                   ``stream`` section (see ``STREAM_PARTITION_BYTES`` /
                   ``STREAM_TIERS`` and ``tuner.tune_stream``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable ``ParserConfig`` field (see module docstring).

    ``candidates(backend) -> tuple`` returns the values worth measuring on
    that backend — empty means the knob does not apply (the backend's
    traced code never reads it).  ``valid(backend, value)`` is the
    constraint the resolver re-checks on cached values.
    """

    name: str
    stage: str
    default: Any
    candidates: Callable[[Any], Tuple]
    doc: str

    def valid(self, backend, value) -> bool:
        return value in self.candidates(backend)


def _pallas_only(vals):
    return lambda be: vals if be.name == "pallas" else ()


def _has_fused_executor(vals):
    return lambda be: vals if be.execute is not None else ()


#: The search space, in sweep order: cheap/high-leverage knobs first so a
#: tight budget still covers them (the tuner walks coordinates in this
#: order and stops when the candidate budget runs out).
SPACE: Tuple[Knob, ...] = (
    Knob(
        "partition_impl", "partition", "auto",
        lambda be: be.partition_impls,
        "§3.3 stable-partition implementation (jnp radix variants vs the "
        "Pallas radix kernel).  The hand heuristic — scatter on reference, "
        "scatter2-under-interpret/kernel-on-hardware on pallas — becomes "
        "the cold-cache default.",
    ),
    Knob(
        "fuse_pipeline", "pipeline", None,
        _has_fused_executor((False, True)),
        "Staged composition vs the whole-pipeline megakernel "
        "(ParsePlan.execute_path).  Per-format measurements decide: on "
        "interpret-CPU the megakernel loses on clf/jsonl/zone and wins on "
        "csv (see BENCH_parser.json); None = unset, resolved at config "
        "time.",
    ),
    Knob(
        "use_matmul_scan", "scan", False,
        lambda be: (False, True),
        "§3.1 composite scan as one-hot matmuls (the paper's SpMV "
        "formulation) vs gathers — which wins is purely a device property.",
    ),
    Knob(
        "block_chunks", "scan", 0,
        _pallas_only((64, 128, 256, 512)),
        "Chunks per Pallas grid step in the §3.1 DFA-scan kernels "
        "(launch geometry; 0 = kernel default).",
    ),
    Knob(
        "window_rows", "typeconv", 0,
        _pallas_only((0, 128, 256, 1024, -1)),
        "Rows per CSS-window DMA block in the fused numparse kernels "
        "(0 = kernel default, -1 = whole-CSS-in-VMEM).",
    ),
    Knob(
        "max_window_bytes", "typeconv", 0,
        _pallas_only((0, 4096, 16384)),
        "Static CSS window tile bytes (0 = auto-size from window_rows and "
        "the dtype width).",
    ),
    Knob(
        "fuse_typeconv", "typeconv", True,
        _pallas_only((True, False)),
        "Fused gather+convert kernels vs the unfused XLA-gather + "
        "arithmetic-kernel path.",
    ),
    Knob(
        "partition_block_tags", "partition", 0,
        _pallas_only((0, 1024, 4096)),
        "Tags per block in the Pallas radix-partition kernel "
        "(partition_impl='kernel' only; 0 = kernel default).",
    ),
    Knob(
        "fused_max_bytes", "pipeline", 0,
        _has_fused_executor((0, 1 << 20, 16 << 20)),
        "Static byte cap above which a fused plan falls back to the "
        "staged tier (0 = backend default, 4 MiB on pallas) — on real "
        "hardware the VMEM ceiling, measurable only there.",
    ),
)

#: Stream-stage candidates (cache entry ``stream`` section, not
#: ``ParserConfig`` fields): partition sizes for the §4.4 streaming engine
#: and the batch-width ladder the serve layer's recompile tiers are chosen
#: from (``tuner.tune_stream`` measures aggregate GB/s per width and keeps
#: the widths that pay for their compile).
STREAM_PARTITION_BYTES: Tuple[int, ...] = (1 << 13, 1 << 14, 1 << 16, 1 << 17)
STREAM_TIERS: Tuple[int, ...] = (1, 4, 16, 64)


def knobs_for(backend, stage: str = None) -> Tuple[Knob, ...]:
    """The knobs that apply to ``backend`` (non-empty candidate sets),
    optionally filtered to one stage, in sweep order."""
    return tuple(
        k for k in SPACE
        if k.candidates(backend) and (stage is None or k.stage == stage)
    )


def knob(name: str) -> Knob:
    for k in SPACE:
        if k.name == name:
            return k
    raise KeyError(f"unknown knob {name!r}; space: {[k.name for k in SPACE]}")


def apply_assignment(cfg, assignment: Dict[str, Any]):
    """``cfg`` with ``assignment``'s knob values applied.

    ``autotune`` is forced off so the tuner's candidate configs resolve
    exactly the assignment under measurement — never a cache entry.
    """
    return dataclasses.replace(cfg, autotune=False, **assignment)


def defaults_for(backend) -> Dict[str, Any]:
    """The all-defaults assignment for ``backend`` — the sweep's starting
    point and the baseline every tuned config is compared against."""
    return {k.name: k.default for k in knobs_for(backend)}
