"""``python -m repro.tune`` — run the benchmark-driven autotuner.

Sweeps the knob space (``repro.tune.space``) per (workload, backend) on
this machine's device and writes the winning, bit-identity-checked configs
into the persistent cache (``repro.tune.cache``), where
``ParserConfig(autotune=True)`` / ``tuned_parser_config`` resolve them::

    PYTHONPATH=src python -m repro.tune \\
        [--workloads yelp,taxi,csv,jsonl,zone,clf] \\
        [--backends reference,pallas] [--records 250] [--budget 32] \\
        [--rounds 4] [--stream] [--seed | --cache PATH] [-v]

``--seed`` writes the committed seed cache
(``src/repro/tune/default_cache.json``) instead of the user cache — the
nightly interpret-CPU refresh; a fresh checkout then resolves to measured
configs before anyone tunes locally.  ``--stream`` additionally measures
the §4.4 stream knobs (streaming partition size + the serve recompile-tier
ladder) into each entry's ``stream`` section.

Workload fingerprints here deliberately match the benchmark suite's
configs (same schemas, same chunk sizes, same per-format tunings), so a
tune run and a ``bench_parser --tuned`` run resolve the same cache entries.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.configs.parse_formats import tuned_parser_config
from repro.core import ParserConfig, Schema, make_csv_dfa
from repro.data import synth
from repro.tune import cache as cache_mod
from repro.tune import tuner

CSV_WORKLOADS = ("yelp", "taxi")
FORMAT_WORKLOADS = ("csv", "jsonl", "zone", "clf")
ALL_WORKLOADS = CSV_WORKLOADS + FORMAT_WORKLOADS


def workload(name: str, records: int, backend: str):
    """``(cfg, data)`` for one named workload — the same configs the
    benchmark suite runs, with ``autotune=False`` (the tuner must start
    from the heuristic defaults, never from its own cache)."""
    if name in CSV_WORKLOADS:
        rng = np.random.default_rng(0)
        if name == "yelp":
            data = synth.yelp_like(rng, records)
            schema = synth.YELP_SCHEMA
        else:
            data = synth.taxi_like(rng, 4 * records)
            schema = synth.TAXI_SCHEMA
        cfg = ParserConfig(
            dfa=make_csv_dfa(), schema=Schema.of(*schema),
            max_records=1 << 12, chunk_size=64, backend=backend)
    elif name in FORMAT_WORKLOADS:
        data = synth.format_payload(name, records)
        cfg = tuned_parser_config(
            name, max_records=1 << 12, backend=backend, autotune=False)
    else:
        raise ValueError(
            f"unknown workload {name!r}; available: {ALL_WORKLOADS}")
    return cfg, data


def stream_sources(name: str, records: int, n: int):
    """Per-stream sources for ``tune_stream`` (distinct seeds where the
    generator takes one; deterministic formats replicate)."""
    if name == "yelp":
        return [synth.yelp_like(np.random.default_rng(s), records)
                for s in range(n)]
    if name == "taxi":
        return [synth.taxi_like(np.random.default_rng(s), records)
                for s in range(n)]
    return [synth.format_payload(name, records)] * n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune", description=__doc__.splitlines()[0])
    ap.add_argument("--workloads", default=",".join(ALL_WORKLOADS),
                    help=f"comma list from {ALL_WORKLOADS}")
    ap.add_argument("--backends", default="reference,pallas")
    ap.add_argument("--records", type=int, default=250,
                    help="records per workload (taxi runs 4x)")
    ap.add_argument("--budget", type=int, default=32,
                    help="max candidate configs evaluated per (workload, backend)")
    ap.add_argument("--rounds", type=int, default=4,
                    help="round-robin timing rounds per candidate group")
    ap.add_argument("--stream", action="store_true",
                    help="also tune the §4.4 stream knobs (partition size, "
                         "serve tier ladder)")
    ap.add_argument("--stream-tiers", default="1,4",
                    help="serve batch widths to measure with --stream")
    ap.add_argument("--seed", action="store_true",
                    help="write the committed seed cache "
                         "(src/repro/tune/default_cache.json) instead of "
                         "the user cache")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="explicit cache file (overrides --seed/user cache)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.cache:
        path = args.cache
    elif args.seed:
        path = cache_mod.seed_cache_path()
    else:
        path = cache_mod.user_cache_path()
    cache = cache_mod.TuneCache(path)
    workloads = [w.strip() for w in args.workloads.split(",") if w.strip()]
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]

    failures = 0
    for name in workloads:
        for backend in backends:
            cfg, data = workload(name, args.records, backend)
            try:
                rep = tuner.tune_parse(
                    cfg, data, budget=args.budget, rounds=args.rounds,
                    cache=cache, verbose=args.verbose)
            except Exception as e:
                print(f"tune {name}/{backend}: FAILED {e!r}", file=sys.stderr)
                failures += 1
                continue
            rejected = sum(1 for t in rep.trials if t.rejected)
            speedup = (rep.baseline_seconds / rep.seconds
                       if rep.seconds > 0 else float("nan"))
            knobs = {k: v for k, v in sorted(rep.assignment.items())}
            print(f"tune {name}/{backend}: {rep.seconds * 1e6:.0f}us "
                  f"({speedup:.2f}x vs defaults, {rep.evaluated} candidates, "
                  f"{rejected} rejected"
                  f"{', budget exhausted' if rep.budget_exhausted else ''})"
                  f" -> {knobs}")
            if args.stream:
                tiers = tuple(int(t) for t in args.stream_tiers.split(","))
                # full-size sources: partition-size winners measured on
                # truncated streams do not transfer (fixed overhead
                # dominates and small partitions look artificially good)
                srcs = stream_sources(name, args.records, max(tiers))
                sec = tuner.tune_stream(
                    cfg, srcs, tiers=tiers, cache=cache,
                    verbose=args.verbose)
                print(f"tune {name}/{backend}/stream: "
                      f"partition_bytes={sec['partition_bytes']} "
                      f"serve_tiers={sec['serve_tiers']}")
    print(f"# cache: {len(cache)} entries -> {path}")
    cache_mod.reset()  # this process resolves against the fresh file
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
