"""Persistent cache of measured per-device parse configurations.

Layout: versioned JSON, one entry per *tuning key* — the digest of
``(backend, device_kind, platform, interpret, DFA content, schema dtypes,
tagging, chunk_size, conversion widths)``.  Deliberately NOT
``stages.plan_key``: the plan key fingerprints the executable a config
compiles to *including* the knobs under tuning, which would make every
candidate its own cache line; the tuning key fingerprints the workload
shape the knobs are being tuned *for* (same machinery, knob fields
excluded) plus the device, so one entry answers every config that parses
that format on that device.

Two layers, looked up in order:

  * the **user cache** — ``~/.cache/repro-tune/cache.json`` (override with
    ``$REPRO_TUNE_CACHE``), written by ``python -m repro.tune`` runs on
    this machine;
  * the committed **seed cache** — ``src/repro/tune/default_cache.json``,
    interpret-CPU measurements refreshed by the nightly sweep, so a fresh
    checkout resolves to measured configs (e.g. clf/jsonl/zone staged, the
    BENCH-observed megakernel regressions) before anyone tunes locally.

Robustness contract: a missing, corrupt, or version-mismatched cache file
is an *empty* cache, never an exception — the resolver falls back to the
heuristic defaults, exactly the pre-autotuner behaviour.  Entries carry
the full human-readable key echo next to the digest so a cache file can
be audited (and hand-pruned) without re-deriving hashes.

Entry schema::

    {
      "version": 1,
      "entries": {
        "<digest>": {
          "key": {...},                  # human-readable tune_key echo
          "knobs": {"partition_impl": "scatter2", "fuse_pipeline": false, ...},
          "score": {"us_per_call": ..., "gbps": ..., "n_bytes": ...},
          "stream": {"partition_bytes": ..., "serve_tiers": [1, 4], ...},
          "meta": {"jax": "...", "records": ..., "budget_exhausted": ...}
        }
      }
    }
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

VERSION = 1

_ENV_PATH = "REPRO_TUNE_CACHE"


def user_cache_path() -> str:
    """The writable per-machine cache file (``$REPRO_TUNE_CACHE`` wins)."""
    env = os.environ.get(_ENV_PATH)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro-tune", "cache.json")


def seed_cache_path() -> str:
    """The committed interpret-CPU seed cache shipped with the package."""
    return os.path.join(os.path.dirname(__file__), "default_cache.json")


def tune_key(cfg, device=None) -> Tuple[str, Dict[str, Any]]:
    """``(digest, echo)`` for ``cfg`` on ``device`` (default: the process's
    first jax device).

    The echo is the digest's preimage — stored alongside entries so cache
    files stay auditable.  Knob fields (``repro.tune.space.SPACE``) are
    excluded by construction: two configs differing only in tuned knobs
    share one entry.
    """
    from repro.core import stages as stages_mod

    if device is None:
        import jax

        device = jax.devices()[0]
    dfa_digest = hashlib.sha256(
        repr(stages_mod.dfa_key(cfg.dfa)).encode()).hexdigest()[:12]
    echo = {
        "backend": cfg.backend,
        "device_kind": str(device.device_kind),
        "platform": str(device.platform),
        "interpret": bool(getattr(cfg, "interpret", True)),
        "dfa": dfa_digest,
        "schema": [[c.dtype, bool(c.selected)] for c in cfg.schema.columns],
        "tagging": cfg.tagging,
        "chunk_size": int(cfg.chunk_size),
        "int_width": int(cfg.int_width),
        "float_width": int(cfg.float_width),
    }
    digest = hashlib.sha256(
        json.dumps(echo, sort_keys=True).encode()).hexdigest()[:16]
    return digest, echo


class TuneCache:
    """One cache file: load-tolerant, thread-safe, explicit ``save()``.

    ``lookup`` returns the stored entry dict (or ``None``); ``store``
    merges an entry under its digest (section-level merge, so a stream-only
    refresh keeps the knob section and vice versa).
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._entries: Dict[str, dict] = self._load(path)

    @staticmethod
    def _load(path: str) -> Dict[str, dict]:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(data, dict) or data.get("version") != VERSION:
            return {}
        entries = data.get("entries")
        return dict(entries) if isinstance(entries, dict) else {}

    def lookup(self, digest: str) -> Optional[dict]:
        with self._lock:
            e = self._entries.get(digest)
            return json.loads(json.dumps(e)) if e is not None else None

    def store(self, digest: str, entry: dict) -> None:
        with self._lock:
            merged = dict(self._entries.get(digest, {}))
            merged.update(json.loads(json.dumps(entry)))
            self._entries[digest] = merged

    def save(self) -> str:
        with self._lock:
            payload = {"version": VERSION, "entries": self._entries}
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        return self.path

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


# -- the process-wide lookup chain (user cache over seed cache) -------------

_chain_lock = threading.Lock()
_chain: Optional[Tuple[TuneCache, ...]] = None


def _get_chain() -> Tuple[TuneCache, ...]:
    global _chain
    with _chain_lock:
        if _chain is None:
            _chain = (TuneCache(user_cache_path()), TuneCache(seed_cache_path()))
        return _chain


def chain_lookup(digest: str) -> Optional[dict]:
    """Lookup through the user-over-seed chain (memoized load; call
    :func:`reset` after changing ``$REPRO_TUNE_CACHE`` or cache files)."""
    for c in _get_chain():
        e = c.lookup(digest)
        if e is not None:
            return e
    return None


def reset() -> None:
    """Drop the memoized chain (tests and the CLI re-point caches)."""
    global _chain
    with _chain_lock:
        _chain = None
