"""Shared measurement core for the tuner and the benchmark suite.

One definition of how this repo times a parse candidate — compile-excluded
warmup, then *round-robin best-of* rounds (shared-host noise arrives in
bursts long enough to swallow whole per-variant runs, so variants are
interleaved and each keeps its best round) — used by both
``repro.tune.tuner`` and ``benchmarks/bench_parser.py``, so tuned configs
and bench rows are measured by literally the same loop and their numbers
compare.

Also one definition of a parse output's *bit-identity signature*: every
array a :class:`~repro.core.stages.ParseResult` carries, as numpy.  The
tuner compares every candidate's signature against the reference backend
before timing it (tuning can never change outputs); the bench uses the
same signature for its cross-variant ``outputs_match`` pin.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Mapping, NamedTuple

import jax
import numpy as np

DEFAULT_ROUNDS = 6
DEFAULT_WARMUP = 2


class Measured(NamedTuple):
    """One candidate's measurement: best-of wall clock + its last output."""

    seconds: float
    output: Any


def measure_best(
    thunks: Mapping[str, Callable[[], Any]],
    *,
    rounds: int = DEFAULT_ROUNDS,
    warmup: int = DEFAULT_WARMUP,
    timer: Callable[[], float] = time.perf_counter,
) -> Dict[str, Measured]:
    """Round-robin best-of timing of ``thunks`` (label → nullary callable
    returning a jax pytree; blocked-on before the clock stops).

    ``warmup`` calls per thunk run first — compilation and cache warming
    never contaminate a timed round.  ``timer`` is injectable so the tuner
    tests can pin coordinate descent deterministically.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    outs: Dict[str, Any] = {}
    best: Dict[str, float] = {}
    for label, fn in thunks.items():
        for _ in range(warmup):
            jax.block_until_ready(fn())
        best[label] = float("inf")
    for _ in range(rounds):
        for label, fn in thunks.items():
            t0 = timer()
            out = fn()
            jax.block_until_ready(out)
            best[label] = min(best[label], timer() - t0)
            outs[label] = out
    return {label: Measured(best[label], outs[label]) for label in thunks}


def parse_signature(result) -> List[np.ndarray]:
    """Whole-result fingerprint for bit-identity checks: every
    :class:`~repro.core.stages.ParseResult` field — CSS, column geometry,
    field index, every typed column's value/valid/empty planes, every
    validation flag, and the carry scalars — as host numpy arrays."""
    parts: List[np.ndarray] = []
    for f in ("css", "col_start", "col_count", "field_offset",
              "field_length", "field_present", "end_state",
              "last_record_end"):
        parts.append(np.asarray(getattr(result, f)))
    for name in sorted(result.values):
        for f in ("value", "valid", "empty"):
            parts.append(np.asarray(getattr(result.values[name], f)))
    for f in result.validation._fields:
        parts.append(np.asarray(getattr(result.validation, f)))
    return parts


def signatures_equal(a: List[np.ndarray], b: List[np.ndarray]) -> bool:
    """Exact (bit-for-bit) equality of two :func:`parse_signature` outputs."""
    return len(a) == len(b) and all(
        np.array_equal(x, y) for x, y in zip(a, b)
    )
