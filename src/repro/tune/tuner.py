"""The sweep driver: coordinate descent over the knob space, measured by
the shared core, bit-identity-gated, budgeted, partial-result safe.

:func:`tune_parse` walks the knobs of ``repro.tune.space.SPACE`` in sweep
order.  Per knob it measures every candidate (plus the incumbent) with
one round-robin :func:`repro.tune.measure.measure_best` group — candidates
of one knob always time against each other in the same interleaved rounds,
so a noise burst cannot crown a winner — and keeps the fastest.  Before a
candidate is ever *timed* its full parse output is compared bit-for-bit
against the reference backend (:func:`repro.tune.measure.parse_signature`);
a mismatching candidate is rejected, recorded, and can never enter the
cache — **tuning can never change outputs**.

``budget`` caps the number of candidate configs evaluated (each costs one
compile + identity parse + its timing rounds); when it runs out the sweep
stops where it stands and the best-so-far assignment is still returned and
cached — a partial tune is a valid tune.  The cache entry is (re)written
after every completed coordinate for the same reason: an interrupted sweep
leaves its last completed coordinate's winners behind.

:func:`tune_stream` measures the §4.4 stream-stage knobs — the streaming
partition size, then the serve tier ladder (batch widths whose measured
aggregate throughput pays for their compile) — into the same cache entry's
``stream`` section; ``serve.ParseService`` reads the ladder through
``PlanRegistry.tuned_tiers``.

CLI: ``python -m repro.tune`` (see ``repro/tune/__main__.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.parser import Parser
from repro.tune import cache as cache_mod
from repro.tune import measure as measure_mod
from repro.tune import space as space_mod


@dataclasses.dataclass
class Trial:
    """One evaluated candidate: its full assignment and how it fared."""

    assignment: Dict[str, Any]
    seconds: Optional[float] = None     # best-of wall clock (None if rejected)
    rejected: Optional[str] = None      # why it never entered timing


@dataclasses.dataclass
class TuneReport:
    """What a tune run found (and how far the budget let it look)."""

    digest: str                         # cache key the result stored under
    assignment: Dict[str, Any]          # winning knob values (defaults incl.)
    seconds: float                      # winner's best-of wall clock
    baseline_seconds: float             # the all-defaults config, same rounds
    n_bytes: int                        # input size (gbps = n_bytes/seconds)
    trials: List[Trial]
    evaluated: int                      # candidates spent (≤ budget)
    budget_exhausted: bool
    stream: Optional[dict] = None       # tune_stream's section, when run


def _reference_cfg(cfg):
    """The oracle config for identity checks: same format/schema semantics,
    reference backend, every tuned knob at its heuristic default."""
    return dataclasses.replace(
        cfg, backend="reference", autotune=False, use_matmul_scan=False,
        **{k.name: k.default for k in space_mod.SPACE
           if k.name != "use_matmul_scan"},
    )


def _entry(digest: str, echo: dict, assignment: Dict[str, Any],
           seconds: float, n_bytes: int, evaluated: int,
           budget_exhausted: bool) -> dict:
    return {
        "key": echo,
        "knobs": dict(assignment),
        "score": {
            "us_per_call": seconds * 1e6,
            "gbps": n_bytes / seconds / 1e9 if seconds > 0 else 0.0,
            "n_bytes": int(n_bytes),
        },
        "meta": {
            "jax": jax.__version__,
            "evaluated": int(evaluated),
            "budget_exhausted": bool(budget_exhausted),
        },
    }


def tune_parse(
    cfg,
    data: bytes,
    *,
    budget: int = 32,
    rounds: int = 4,
    warmup: int = 1,
    cache: Optional[cache_mod.TuneCache] = None,
    save: bool = True,
    measure_fn: Callable = None,
    stages: Optional[Sequence[str]] = None,
    verbose: bool = False,
) -> TuneReport:
    """Coordinate-descent tune of ``cfg``'s backend knobs on ``data``.

    ``measure_fn`` defaults to :func:`measure.measure_best` and is
    injectable (tests pin descent determinism with a stub clock); the
    bit-identity gate always runs on real outputs regardless.  ``stages``
    optionally restricts the sweep to a subset of knob stages.
    """
    measure_fn = measure_fn or (
        lambda thunks: measure_mod.measure_best(
            thunks, rounds=rounds, warmup=warmup))
    from repro.core import backends as backends_mod

    backend = backends_mod.get_backend(cfg.backend)
    digest, echo = cache_mod.tune_key(cfg)
    knobs = space_mod.knobs_for(backend)
    if stages is not None:
        knobs = tuple(k for k in knobs if k.stage in stages)
    assignment = {k.name: getattr(cfg, k.name, k.default) for k in knobs}

    # Oracle outputs: the reference backend parses the same prepared chunks
    # once; every candidate must reproduce this bit-for-bit to be timed.
    ref = Parser(_reference_cfg(cfg))
    chunks = jnp.asarray(ref.prepare(data))
    ref_sig = measure_mod.parse_signature(
        jax.block_until_ready(ref.parse_chunks(chunks)))
    n_bytes = len(data)

    trials: List[Trial] = []
    evaluated = 0
    exhausted = False
    baseline_seconds: Optional[float] = None
    best_seconds = float("inf")

    def build(cand: Dict[str, Any], count: bool = True):
        """Compile + identity-gate one candidate; None if rejected."""
        nonlocal evaluated
        if count:
            evaluated += 1
        try:
            p = Parser(space_mod.apply_assignment(cfg, cand))
            out = jax.block_until_ready(p.parse_chunks(chunks))
        except Exception as e:  # a candidate that won't build can't win
            trials.append(Trial(dict(cand), rejected=f"error: {e!r}"))
            return None
        if not measure_mod.signatures_equal(
                measure_mod.parse_signature(out), ref_sig):
            trials.append(Trial(
                dict(cand), rejected="output mismatch vs reference backend"))
            return None
        return p

    for k in knobs:
        cands = [assignment[k.name]] + [
            v for v in k.candidates(backend) if v != assignment[k.name]]
        group: Dict[str, Tuple[Dict[str, Any], Parser]] = {}
        for v in cands:
            is_incumbent = v == assignment[k.name]
            if not is_incumbent and evaluated >= budget:
                exhausted = True
                break
            cand = dict(assignment, **{k.name: v})
            p = build(cand)
            if p is not None:
                group[f"{k.name}={v!r}"] = (cand, p)
        if group:
            measured = measure_fn(
                {lbl: (lambda p=p: p.parse_chunks(chunks))
                 for lbl, (cand, p) in group.items()})
            for lbl, m in measured.items():
                trials.append(Trial(dict(group[lbl][0]), seconds=m.seconds))
                if verbose:
                    print(f"# tune {lbl}: {m.seconds * 1e6:.0f}us")
            win = min(measured, key=lambda lbl: measured[lbl].seconds)
            if baseline_seconds is None:
                inc_lbl = f"{k.name}={assignment[k.name]!r}"
                baseline_seconds = measured.get(
                    inc_lbl, measured[win]).seconds
            assignment = dict(group[win][0])
            best_seconds = measured[win].seconds
            # partial-result safety: every completed coordinate lands in
            # the cache before the next one starts
            if cache is not None and save:
                cache.store(digest, _entry(
                    digest, echo, assignment, best_seconds, n_bytes,
                    evaluated, exhausted))
                cache.save()
        if exhausted:
            break

    # Final head-to-head: the descent's winner vs the all-defaults config,
    # timed in the SAME round-robin group.  Per-coordinate groups each time
    # in their own rounds, so cross-coordinate numbers are not comparable
    # (a noise burst between coordinates would skew the ratio); this last
    # group is the fair comparison — and the demotion gate: a "winner" that
    # cannot beat the defaults when interleaved with them is noise, and the
    # defaults are kept (the tuned-no-slower-than-default bench invariant
    # starts here).
    defaults = {k.name: k.default for k in knobs}
    if assignment != defaults and best_seconds < float("inf"):
        # identity-gated like any candidate; neither costs budget (both
        # configs were already evaluated during the descent)
        d = build(defaults, count=False)
        w = build(assignment, count=False)
        if d is not None and w is not None:
            final = measure_fn({
                "defaults": lambda: d.parse_chunks(chunks),
                "tuned": lambda: w.parse_chunks(chunks),
            })
            baseline_seconds = final["defaults"].seconds
            best_seconds = final["tuned"].seconds
            if verbose:
                print(f"# tune final: defaults={baseline_seconds * 1e6:.0f}us "
                      f"tuned={best_seconds * 1e6:.0f}us")
            if baseline_seconds < best_seconds:
                assignment, best_seconds = dict(defaults), baseline_seconds
    if baseline_seconds is None:
        baseline_seconds = best_seconds
    report = TuneReport(
        digest=digest, assignment=assignment, seconds=best_seconds,
        baseline_seconds=baseline_seconds, n_bytes=n_bytes, trials=trials,
        evaluated=evaluated, budget_exhausted=exhausted,
    )
    if cache is not None and save and best_seconds < float("inf"):
        cache.store(digest, _entry(
            digest, echo, assignment, best_seconds, n_bytes, evaluated,
            exhausted))
        cache.save()
    return report


def tune_stream(
    cfg,
    datas: Sequence[bytes],
    *,
    partition_candidates: Sequence[int] = space_mod.STREAM_PARTITION_BYTES,
    tiers: Sequence[int] = space_mod.STREAM_TIERS,
    cache: Optional[cache_mod.TuneCache] = None,
    save: bool = True,
    repeats: int = 2,
    timer: Callable[[], float] = time.perf_counter,
    verbose: bool = False,
) -> dict:
    """Measure the stream-stage knobs for ``cfg``'s workload.

    Two passes over real :class:`~repro.core.streaming.StreamSession`\\ s:

    1. single-stream partition-size sweep over ``partition_candidates`` —
       best end-to-end drain time of ``datas[0]`` wins;
    2. tier ladder at the winning partition size: aggregate GB/s for each
       batch width in ``tiers`` (capped by ``len(datas)``); a width stays
       in the ladder only if it beats the previous kept width's aggregate
       throughput by >2% — widths that don't pay for their compile are
       dropped, and ``serve.ParseService`` then never compiles them.

    Returns (and caches, under the entry's ``stream`` section) e.g.
    ``{"partition_bytes": 65536, "serve_tiers": [1, 4], "gbps": {...}}``.
    """
    from repro.core.streaming import StreamSession

    base = dataclasses.replace(cfg) if getattr(cfg, "autotune", False) else cfg
    parser = Parser(base)

    def drain(session, sources) -> float:
        best = float("inf")
        for _ in range(max(1, repeats) + 1):  # +1 warmup/compile run
            t0 = timer()
            for _ in session.parse_streams([[d] for d in sources]):
                pass
            best = min(best, timer() - t0)
        return best

    per_pb = {}
    for pb in partition_candidates:
        sess = StreamSession(parser, pb, max_carry_bytes=pb, n_streams=1)
        per_pb[pb] = drain(sess, [datas[0]])
        if verbose:
            print(f"# tune stream partition_bytes={pb}: "
                  f"{per_pb[pb] * 1e6:.0f}us")
    best_pb = min(per_pb, key=per_pb.get)

    gbps: Dict[str, float] = {}
    ladder: List[int] = []
    for s in tiers:
        if s > len(datas):
            break
        sources = list(datas[:s])
        sess = StreamSession(parser, best_pb, max_carry_bytes=best_pb,
                             n_streams=s)
        dt = drain(sess, sources)
        g = sum(len(d) for d in sources) / dt / 1e9
        gbps[f"S{s}"] = g
        if not ladder or g > gbps[f"S{ladder[-1]}"] * 1.02:
            ladder.append(s)
        if verbose:
            print(f"# tune stream S={s}: {g:.3f}GB/s")

    section = {
        "partition_bytes": int(best_pb),
        "serve_tiers": [int(s) for s in ladder],
        "gbps": gbps,
        "partition_us": {str(pb): dt * 1e6 for pb, dt in per_pb.items()},
    }
    if cache is not None and save:
        digest, echo = cache_mod.tune_key(cfg)
        cache.store(digest, {"key": echo, "stream": section})
        cache.save()
    return section
