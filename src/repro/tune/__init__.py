"""Benchmark-driven autotuner (ROADMAP item 5; paper §5 per-GPU tuning).

ParPaRaw's headline rate depends on per-device tuning of launch geometry
and chunk sizes; this package replaces our hand-picked kernel knobs with
*measured* per-device configurations:

  * ``space``   — the search space: every perf knob declared ONCE with its
    candidates, the stage it gates, and its validity constraints.
  * ``measure`` — the shared measurement core (compile-excluded warmup +
    round-robin best-of timing + bit-identity signatures) used by both the
    tuner and ``benchmarks/bench_parser.py``, so tuner and bench report
    comparable numbers.
  * ``cache``   — versioned persistent JSON cache of winning configs, one
    entry per ``(backend, workload fingerprint, device_kind, interpret)``:
    a user cache under ``~/.cache/repro-tune/`` layered over the committed
    seed cache (``default_cache.json``, interpret-CPU measurements).
  * ``resolve`` — cache-driven knob resolution consulted by
    ``ParserConfig(autotune=True)``: explicit knob > cache > heuristic
    default, and tuning can never change outputs (every cached candidate
    was bit-identity-checked against the reference backend when measured).
  * ``tuner``   — the coordinate-descent sweep driver (budgeted candidate
    count, partial-result safe) plus the ``python -m repro.tune`` CLI that
    refreshes caches.
"""
from repro.tune.cache import TuneCache, seed_cache_path, tune_key, user_cache_path
from repro.tune.measure import measure_best, parse_signature, signatures_equal
from repro.tune.resolve import resolved_knobs, tuned_serve_tiers
from repro.tune.space import Knob, knobs_for, apply_assignment

__all__ = [
    "TuneCache", "seed_cache_path", "tune_key", "user_cache_path",
    "measure_best", "parse_signature", "signatures_equal",
    "resolved_knobs", "tuned_serve_tiers",
    "Knob", "knobs_for", "apply_assignment",
]
