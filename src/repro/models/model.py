"""Public model API: build(config) → bound init/forward/loss/decode functions.

The same entry points serve smoke tests (1 CPU device, sharding disabled),
the end-to-end training examples, and the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding.specs import NO_SHARDING, Sharding


class Model(NamedTuple):
    cfg: ModelConfig
    sh: Sharding

    # ---- params -----------------------------------------------------------
    def init(self, key):
        return T.init_model(key, self.cfg)

    def param_specs(self):
        return T.model_specs(self.cfg, tp=self.sh.tp)

    # ---- training / prefill -------------------------------------------------
    def forward(self, params, batch: Dict[str, Any], mesh=None, impl=None):
        return T.forward(
            params, batch["tokens"], self.cfg, self.sh, mesh,
            patches=batch.get("patches"), frames=batch.get("frames"), impl=impl,
        )

    def loss(self, params, batch, mesh=None, impl=None):
        labels = batch["labels"]
        if self.cfg.logit_chunk:
            # chunked CE: (B,S,V) fp32 logits never materialise
            x, aux = T.forward_hidden(
                params, batch["tokens"], self.cfg, self.sh, mesh,
                patches=batch.get("patches"), frames=batch.get("frames"),
                impl=impl,
            )
            if self.cfg.n_patches and "patches" in batch:
                x = x[:, self.cfg.n_patches:]
            nll = T.chunked_ce_loss(params, x, labels, self.cfg, self.sh)
            return nll + self.cfg.moe_aux_weight * aux, (nll, aux)
        logits, aux = self.forward(params, batch, mesh=mesh, impl=impl)
        if self.cfg.n_patches and "patches" in batch:
            logits = logits[:, self.cfg.n_patches:]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        take = jnp.take_along_axis(lp, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = -(take * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll + self.cfg.moe_aux_weight * aux, (nll, aux)

    # ---- decode -------------------------------------------------------------
    def init_decode_state(self, batch, max_seq, dtype=None):
        return T.init_decode_state(self.cfg, batch, max_seq, dtype)

    def decode_state_specs(self, seq_axis=None):
        return T.decode_state_specs(self.cfg, self.sh, seq_axis)

    def decode_step(self, params, token, state, mesh=None, active=None):
        return T.decode_step(params, token, state, self.cfg, self.sh, mesh,
                             active=active)


def build_model(cfg: ModelConfig, sharded: bool = False,
                sh: Optional[Sharding] = None) -> Model:
    if sh is None:
        sh = Sharding() if sharded else NO_SHARDING
    return Model(cfg, sh)
