"""Model assembly: blocks, layer-scanned stacks, forward / decode for every
assigned architecture family.

Families map to block kinds:
  dense   — pre-norm GQA attention + SwiGLU MLP            (llama3.2, deepseek,
            starcoder2, qwen2, internvl backbone)
  moe     — attention + MoE FFN (+ leading dense layers)   (kimi-k2, phi3.5-moe)
  ssm     — Mamba-2 (SSD) blocks, attention-free           (mamba2-370m)
  hybrid  — parallel attention + SSM heads sharing a norm,
            sliding-window attention except every Nth layer (hymba-1.5b)
  audio   — encoder-decoder with cross-attention           (whisper-base)
  vlm     — dense decoder with patch-embedding prefix      (internvl2-76b)

All stacks are ``lax.scan``-over-layers (O(1) HLO size in depth — the 512-
device dry-run depends on this) with optional remat.  Hymba's global-vs-
window alternation rides through the scan as a per-layer window scalar, so
the stack stays homogeneous.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding.specs import Sharding

_BIG_WINDOW = 1 << 30  # "no window" sentinel riding through scans


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block_kind(cfg, moe_layer: bool) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.family == "moe" and moe_layer:
        return "moe"
    return "dense"


def init_block(key, cfg, kind: str, cross: bool = False):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    if kind == "ssm":
        p["ssm"] = S.init_ssm(ks[0], cfg)
        return p
    p["attn"] = A.init_attention(ks[1], cfg)
    p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if kind == "hybrid":
        p["ssm"] = S.init_ssm(ks[2], cfg)
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.param_dtype, cfg.mlp_variant)
    elif kind == "moe":
        p["moe"] = M.init_moe(ks[4], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.param_dtype, cfg.mlp_variant)
    if cross:
        p["ln_x"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["cross"] = A.init_attention(ks[6], cfg)
    return p


def block_specs(cfg, kind: str, cross: bool = False, tp="model"):
    p: Dict[str, Any] = {"ln1": L.rmsnorm_specs()}
    if kind == "ssm":
        p["ssm"] = S.ssm_specs(cfg, tp)
        return p
    p["attn"] = A.attention_specs(cfg, tp)
    p["ln2"] = L.rmsnorm_specs()
    if kind == "hybrid":
        p["ssm"] = S.ssm_specs(cfg, tp)
        p["mlp"] = L.mlp_specs(tp, cfg.mlp_variant)
    elif kind == "moe":
        fsdp = "data" if cfg.moe_fsdp else None
        p["moe"] = M.moe_specs(cfg, tp, fsdp=fsdp)
    else:
        p["mlp"] = L.mlp_specs(tp, cfg.mlp_variant)
    if cross:
        p["ln_x"] = L.rmsnorm_specs()
        p["cross"] = A.attention_specs(cfg, tp)
    return p


def block_forward(params, x, cfg, sh, mesh, kind, *, window=None, causal=True,
                  enc_out=None, impl=None):
    """One pre-norm block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind == "ssm":
        return x + S.ssm_forward(params["ssm"], h, cfg, sh), aux
    if kind == "hybrid":
        attn_out = A.attend(params["attn"], h, cfg, sh, causal=causal,
                            window=window, impl=impl)
        ssm_out = S.ssm_forward(params["ssm"], h, cfg, sh)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + A.attend(params["attn"], h, cfg, sh, causal=causal,
                         window=window, impl=impl)
    if enc_out is not None:
        hx = L.rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + A.attend(params["cross"], hx, cfg, sh, kv_x=enc_out, causal=False,
                         impl=impl)
    h2 = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = M.moe_ffn(params["moe"], h2, cfg, sh, mesh)
        x = x + y
    else:
        x = x + L.mlp(params["mlp"], h2, sh)
    x = sh.bsd(x)
    return x, aux


# ---------------------------------------------------------------------------
# Layer windows (Hymba alternation)
# ---------------------------------------------------------------------------

def layer_windows(cfg) -> np.ndarray:
    """Per-layer attention window (big sentinel = global)."""
    wins = np.full(cfg.n_layers, _BIG_WINDOW, np.int32)
    if cfg.attn_window is not None:
        wins[:] = cfg.attn_window
        if cfg.global_layer_every:
            wins[:: cfg.global_layer_every] = _BIG_WINDOW
        wins[0] = _BIG_WINDOW  # first layer global (Hymba keeps anchors)
        wins[cfg.n_layers - 1] = _BIG_WINDOW
    return wins


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------

def _stacked_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_model(key, cfg):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(ks[1], cfg.padded_vocab, cfg.d_model, cfg.param_dtype)
    n_moe = cfg.n_layers - cfg.first_k_dense
    kind = _block_kind(cfg, moe_layer=True)
    cross = cfg.is_encoder_decoder
    params["blocks"] = _stacked_init(
        ks[2], n_moe, lambda k: init_block(k, cfg, kind, cross=cross)
    )
    if cfg.first_k_dense:
        params["dense_blocks"] = _stacked_init(
            ks[3], cfg.first_k_dense, lambda k: init_block(k, cfg, "dense")
        )
    if cfg.is_encoder_decoder:
        params["enc_blocks"] = _stacked_init(
            ks[4], cfg.n_enc_layers, lambda k: init_block(k, cfg, "dense")
        )
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if cfg.n_patches:
        params["patch_proj"] = L.init_linear(ks[5], cfg.d_model, cfg.d_model, cfg.param_dtype)
    return params


def model_specs(cfg, tp="model"):
    def stack(tree):
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda s: isinstance(s, P))

    specs: Dict[str, Any] = {
        "embed": L.embedding_specs(tp),
        "final_norm": L.rmsnorm_specs(),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.embedding_specs(tp)
    kind = _block_kind(cfg, moe_layer=True)
    cross = cfg.is_encoder_decoder
    specs["blocks"] = stack(block_specs(cfg, kind, cross=cross, tp=tp))
    if cfg.first_k_dense:
        specs["dense_blocks"] = stack(block_specs(cfg, "dense", tp=tp))
    if cfg.is_encoder_decoder:
        specs["enc_blocks"] = stack(block_specs(cfg, "dense", tp=tp))
        specs["enc_norm"] = L.rmsnorm_specs()
    if cfg.n_patches:
        specs["patch_proj"] = L.linear_specs(None, None)
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _scan_blocks(blocks, x, cfg, sh, mesh, kind, *, windows, causal=True,
                 enc_out=None, impl=None):
    wins = jnp.asarray(windows, jnp.int32)

    def body(carry, layer):
        xc = carry
        prm, win = layer
        y, aux = block_forward(prm, xc, cfg, sh, mesh, kind, window=win,
                               causal=causal, enc_out=enc_out, impl=impl)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body, x, (blocks, wins))
        return x, jnp.sum(auxs)
    aux_total = jnp.zeros((), jnp.float32)
    n = wins.shape[0]
    for i in range(n):
        prm = jax.tree.map(lambda a: a[i], blocks)
        x, aux = body(x, (prm, wins[i]))
        aux_total += aux
    return x, aux_total


def mask_pad_logits(logits, cfg):
    """Vocab-padding lanes never win: masked to −inf (elementwise, preserves
    the TP sharding of the vocab dim)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(lane < cfg.vocab, logits, jnp.float32(-1e30))


def embed_inputs(params, tokens, cfg, sh, patches=None):
    x = L.embed(params["embed"], tokens).astype(cfg.param_dtype)
    if cfg.n_patches and patches is not None:
        # modality-stub prefix (precomputed patch embeddings, DESIGN.md §5)
        px = L.linear(params["patch_proj"], patches.astype(cfg.param_dtype))
        x = jnp.concatenate([px, x], axis=1)
    return sh.bsd(x)


def encode(params, frames, cfg, sh, mesh, impl=None):
    """Whisper-style encoder over stub frame embeddings (B, T, D)."""
    x = sh.bsd(frames.astype(cfg.param_dtype))
    windows = np.full(cfg.n_enc_layers, _BIG_WINDOW, np.int32)
    x, _ = _scan_blocks(params["enc_blocks"], x, cfg, sh, mesh, "dense",
                        windows=windows, causal=False, impl=impl)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward_hidden(params, tokens, cfg, sh, mesh=None, *, patches=None,
                   frames=None, impl=None) -> Tuple[jax.Array, jax.Array]:
    """Final hidden states (post final-norm) + aux loss — the pre-unembed
    trunk shared by ``forward`` and the chunked-loss path."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, frames, cfg, sh, mesh, impl=impl)
    x = embed_inputs(params, tokens, cfg, sh, patches=patches)
    aux = jnp.zeros((), jnp.float32)
    if cfg.first_k_dense:
        x, a0 = _scan_blocks(
            params["dense_blocks"], x, cfg, sh, mesh, "dense",
            windows=np.full(cfg.first_k_dense, _BIG_WINDOW, np.int32), impl=impl,
        )
        aux += a0
    kind = _block_kind(cfg, moe_layer=True)
    x, a1 = _scan_blocks(
        params["blocks"], x, cfg, sh, mesh, kind,
        windows=layer_windows(cfg)[cfg.first_k_dense:], enc_out=enc_out, impl=impl,
    )
    aux += a1
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def forward(params, tokens, cfg, sh, mesh=None, *, patches=None, frames=None,
            impl=None) -> Tuple[jax.Array, jax.Array]:
    """Token logits for train/prefill.  Returns (logits fp32, aux_loss)."""
    x, aux = forward_hidden(params, tokens, cfg, sh, mesh, patches=patches,
                            frames=frames, impl=impl)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = mask_pad_logits(L.unembed(head, x), cfg)
    logits = sh.bsv(logits)
    return logits, aux


def chunked_ce_loss(params, x, labels, cfg, sh):
    """Sequence-chunked cross entropy: the (B, S, V) fp32 logits tensor never
    materialises — each chunk's logits live only inside its scan step.  The
    dominant train-memory term for big-vocab models (EXPERIMENTS.md §Perf,
    qwen2 iteration 1)."""
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    b, s, d = x.shape
    c = cfg.logit_chunk
    while s % c:
        c -= 1
    nc = s // c
    xc = x.reshape(b, nc, c, d).swapaxes(0, 1)          # (nc, B, c, D)
    lc = labels.reshape(b, nc, c).swapaxes(0, 1)

    def step(carry, inp):
        nll_sum, n_tok = carry
        xi, li = inp
        logits = mask_pad_logits(L.unembed(head, xi), cfg)
        logits = sh.act(logits, sh.dp, None, sh.tp) if sh.enabled else logits
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        take = jnp.take_along_axis(lp, jnp.clip(li, 0)[..., None], axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        return (nll_sum - (take * mask).sum(), n_tok + mask.sum()), None

    (nll_sum, n_tok), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc)
    )
    return nll_sum / jnp.maximum(n_tok, 1.0)


# ---------------------------------------------------------------------------
# Decode (single token, stacked per-layer caches)
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Stacked per-layer caches.  ``kv``/``cross`` are {"k","v"} dicts with a
    leading layer axis (scan-friendly: every leaf has the same leading dim);
    ``length`` is carried separately."""

    kv: Optional[dict]            # {"k","v"}: (L, B, S_max, KV, hd)
    ssm: Optional[S.SSMCache]     # leaves (L, B, …)
    cross_kv: Optional[dict]      # {"k","v"}: (L_dec, B, T_enc, KV, hd)
    length: jax.Array             # (B,) int32 — per-slot decode positions


def init_decode_state(cfg, batch, max_seq, dtype=None) -> DecodeState:
    dtype = dtype or cfg.param_dtype
    n = cfg.n_layers
    kv = ssm = cross = None
    kind = _block_kind(cfg, moe_layer=True)
    if kind != "ssm" or cfg.first_k_dense:
        kv = {
            "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if kind in ("ssm", "hybrid"):
        sc = S.init_ssm_cache(batch, cfg, dtype)
        ssm = S.SSMCache(*[jnp.broadcast_to(a, (n,) + a.shape) for a in sc])
    if cfg.is_encoder_decoder:
        cross = {
            "k": jnp.zeros((n, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    return DecodeState(kv, ssm, cross, jnp.zeros((batch,), jnp.int32))


def decode_state_specs(cfg, sh, seq_axis=None) -> DecodeState:
    kind = _block_kind(cfg, moe_layer=True)
    kv = ssm = cross = None
    kv_spec = P(None, sh.dp, seq_axis, sh.tp, None)
    if kind != "ssm" or cfg.first_k_dense:
        kv = {"k": kv_spec, "v": kv_spec}
    if kind in ("ssm", "hybrid"):
        c = S.ssm_cache_specs(sh)
        ssm = S.SSMCache(P(None, *c.conv), P(None, *c.state))
    if cfg.is_encoder_decoder:
        cs = P(None, sh.dp, None, sh.tp, None)
        cross = {"k": cs, "v": cs}
    return DecodeState(kv, ssm, cross, P(sh.dp))


def _mask_ssm(new, old, active):
    if active is None:
        return new
    def sel(n, o):
        a = active.reshape((active.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return S.SSMCache(sel(new.conv, old.conv), sel(new.state, old.state))


def _block_decode(prm, x, kv_l, ssm_l, cross_l, pos, cfg, sh, kind, win,
                  active=None):
    """One-layer decode; mirrors block_forward with caches.

    ``kv_l``/``cross_l`` are {"k","v"} dicts (no layer axis), ``ssm_l`` an
    SSMCache.  ``active (B,) bool`` masks continuous-batching slots whose
    recurrent state must not advance.  Returns (x, new_kv, new_ssm)."""
    h = L.rmsnorm(prm["ln1"], x, cfg.norm_eps)
    new_kv, new_ssm = kv_l, ssm_l
    if kind == "ssm":
        y, upd_ssm = S.ssm_decode(prm["ssm"], h, ssm_l, cfg, sh)
        return x + y, new_kv, _mask_ssm(upd_ssm, ssm_l, active)
    window = None if win is None else jnp.minimum(win, jnp.int32(2**30))
    kv_in = A.KVCache(kv_l["k"], kv_l["v"], pos)
    a_out, upd = A.decode_attend(prm["attn"], h, kv_in, cfg, sh, window=window)
    new_kv = {"k": upd.k, "v": upd.v}
    if kind == "hybrid":
        s_out, upd_ssm = S.ssm_decode(prm["ssm"], h, ssm_l, cfg, sh)
        new_ssm = _mask_ssm(upd_ssm, ssm_l, active)
        x = x + 0.5 * (a_out + s_out)
    else:
        x = x + a_out
    if cross_l is not None:
        hx = L.rmsnorm(prm["ln_x"], x, cfg.norm_eps)
        b = x.shape[0]
        hd = cfg.head_dim
        q = L.linear(prm["cross"]["wq"], hx).reshape(b, 1, cfg.n_heads, hd)
        o = A.multihead_attention(q, cross_l["k"], cross_l["v"], causal=False,
                                  impl="dense")
        x = x + L.linear(prm["cross"]["wo"], o.reshape(b, 1, cfg.n_heads * hd))
    h2 = L.rmsnorm(prm["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, _ = M.moe_gather(prm["moe"], h2, cfg, sh)  # dropless decode path
        x = x + y
    else:
        x = x + L.mlp(prm["mlp"], h2, sh)
    return x, new_kv, new_ssm


def _decode_scan(blocks, x, kv, ssm, cross, wins, pos, cfg, sh, kind,
                 active=None):
    """Scan one homogeneous stack of layers through a decode step."""
    n = wins.shape[0]
    dummy = jnp.zeros((n, 1), jnp.int8)
    layers = (
        blocks,
        kv if kv is not None else {"_": dummy},
        ssm if ssm is not None else S.SSMCache(dummy, dummy),
        cross if cross is not None else {"_": dummy},
        wins,
    )

    def wrapped(xc, layer):
        prm, kv_l, ssm_l, cross_l, win = layer
        kv_in = kv_l if kv is not None else None
        ssm_in = ssm_l if ssm is not None else None
        cross_in = cross_l if cross is not None else None
        y, nkv, nssm = _block_decode(prm, xc, kv_in, ssm_in, cross_in, pos,
                                     cfg, sh, kind, win, active=active)
        return y, (nkv if kv is not None else kv_l,
                   nssm if ssm is not None else ssm_l)

    if cfg.scan_layers:
        x, (new_kv, new_ssm) = jax.lax.scan(wrapped, x, layers)
    else:  # unrolled (roofline probes: per-layer cost must be visible)
        outs = []
        for i in range(n):
            layer_i = jax.tree.map(lambda a: a[i], layers)
            x, out_i = wrapped(x, layer_i)
            outs.append(out_i)
        new_kv, new_ssm = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *outs)
    return x, (new_kv if kv is not None else None,
               new_ssm if ssm is not None else None)


def decode_step(params, token, state: DecodeState, cfg, sh, mesh=None,
                active=None):
    """One token for the whole stack.  ``token (B,) int32`` → logits (B, V).

    ``active (B,) bool`` (optional) gates position advancement and SSM-state
    updates per slot — the continuous-batching hook used by serve/engine.
    """
    x = L.embed(params["embed"], token[:, None]).astype(cfg.param_dtype)
    pos = state.length
    kind = _block_kind(cfg, moe_layer=True)
    wins_all = jnp.asarray(layer_windows(cfg), jnp.int32)
    fk = cfg.first_k_dense

    def split(tree, lo, hi):
        if tree is None:
            return None
        return jax.tree.map(lambda a: a[lo:hi], tree)

    new_kv_parts, new_ssm = [], None
    if fk:
        x, (nkv0, _) = _decode_scan(
            params["dense_blocks"], x, split(state.kv, 0, fk), None, None,
            wins_all[:fk], pos, cfg, sh, "dense", active=active,
        )
        new_kv_parts.append(nkv0)
    x, (nkv1, new_ssm) = _decode_scan(
        params["blocks"], x, split(state.kv, fk, cfg.n_layers),
        split(state.ssm, fk, cfg.n_layers) if state.ssm is not None else None,
        state.cross_kv, wins_all[fk:], pos, cfg, sh, kind, active=active,
    )
    new_kv_parts.append(nkv1)
    new_kv = None
    if state.kv is not None:
        parts = [p for p in new_kv_parts if p is not None]
        new_kv = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *parts) \
            if len(parts) > 1 else parts[0]

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = mask_pad_logits(L.unembed(head, x), cfg)[:, 0]
    logits = logits if not sh.enabled else sh.act(logits, sh.dp, sh.tp)
    adv = jnp.ones_like(pos) if active is None else active.astype(pos.dtype)
    new_state = DecodeState(kv=new_kv, ssm=new_ssm if state.ssm is not None else None,
                            cross_kv=state.cross_kv, length=pos + adv)
    return logits, new_state
