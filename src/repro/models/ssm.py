"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

The chunked SSD algorithm is matmul-dominated by construction (its selling
point: the quadratic intra-chunk term and the state passing are all einsums
→ MXU-friendly), with one tiny inter-chunk associative scan.  Decode is the
dual recurrent form: O(1) state update per token — which is why the 500k
long-context decode shape is assigned to the SSM/hybrid archs only.

Layer: in_proj → [z | xBC | dt]; causal depthwise conv on xBC; SSD core;
gated RMSNorm; out_proj.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def _ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_ssm(key, cfg):
    d = cfg.d_model
    d_inner, h, conv_dim = _ssm_dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_ngroups
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * g * n + h
    dt = jnp.exp(
        jax.random.uniform(k3, (h,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": L.init_linear(k1, d, d_in_proj, cfg.param_dtype),
        "conv_w": L.truncnorm(k2, (cfg.ssm_conv, conv_dim), 1.0 / math.sqrt(cfg.ssm_conv), cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": L.init_rmsnorm(d_inner, cfg.param_dtype),
        "out_proj": L.init_linear(k4, d_inner, d, cfg.param_dtype),
    }


def ssm_specs(cfg, tp="model"):
    return {
        "in_proj": L.linear_specs(None, tp),
        "conv_w": P(None, tp),
        "conv_b": P(tp),
        "A_log": P(tp),
        "dt_bias": P(tp),
        "D": P(tp),
        "norm": L.rmsnorm_specs(),
        "out_proj": L.linear_specs(tp, None),
    }


def _split_in_proj(zxbcdt, cfg):
    d_inner, h, _ = _ssm_dims(cfg)
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B, S, C): kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _segsum(x):
    """(..., Q) → (..., Q, Q) cumulative segment sums: out[i,j] = Σ_{j<k≤i}."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int):
    """SSD core (train/prefill).

    x: (B, S, H, P) — per-head inputs; dt: (B, S, H) fp32 (post-softplus);
    a: (H,) fp32 negative; b_mat/c_mat: (B, S, G, N) fp32 with G | H —
    groups are kept as an einsum axis instead of being materialised per
    head (the 16×-broadcast was hymba's dominant HBM term; EXPERIMENTS.md
    §Perf hymba iteration 3).  G == H degenerates to per-head.
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    g = b_mat.shape[2]
    hg = h // g
    q = min(chunk, s)
    assert s % q == 0 and h % g == 0
    c = s // q

    # heads grouped contiguously: head = g_idx · hg + j
    xr = (x.astype(jnp.float32) * dt[..., None]).reshape(bsz, c, q, g, hg, p)
    da = (dt * a[None, None, :]).reshape(bsz, c, q, g, hg)
    br = b_mat.reshape(bsz, c, q, g, n).astype(jnp.float32)
    cr = c_mat.reshape(bsz, c, q, g, n).astype(jnp.float32)

    da_h = jnp.moveaxis(da, 2, -1)                               # (B,C,G,Hg,Q)
    lmat = jnp.exp(_segsum(da_h))                                # (B,C,G,Hg,Q,Q)
    y_diag = jnp.einsum("bcqgn,bcsgn,bcghqs,bcsghp->bcqghp", cr, br, lmat, xr)

    da_cum = jnp.cumsum(da_h, axis=-1)                           # (B,C,G,Hg,Q)
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)
    states = jnp.einsum("bcsgn,bcghs,bcsghp->bcghpn", br, decay_states, xr)

    # inter-chunk recurrence: S_c = S_{c-1}·exp(Σda_c) + states_c (exclusive)
    chunk_decay = jnp.exp(da_cum[..., -1])                       # (B,C,G,Hg)

    def op(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return (a1 * a2, s1 * a2[..., None, None] + s2)

    dec_inc, st_inc = jax.lax.associative_scan(
        op, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)), axis=0
    )
    st_inc = jnp.moveaxis(st_inc, 0, 1)                          # (B,C,G,Hg,P,N)
    final_state = st_inc[:, -1]
    prev = jnp.concatenate(
        [jnp.zeros_like(st_inc[:, :1]), st_inc[:, :-1]], axis=1
    )                                                            # exclusive

    state_decay_out = jnp.exp(da_cum)                            # (B,C,G,Hg,Q)
    y_off = jnp.einsum("bcqgn,bcghpn,bcghq->bcqghp", cr, prev, state_decay_out)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state.reshape(bsz, h, p, n)


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_dim)
    state: jax.Array  # (B, H, P, N) fp32


def init_ssm_cache(batch, cfg, dtype):
    d_inner, h, conv_dim = _ssm_dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, h, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    )


def ssm_cache_specs(sh):
    return SSMCache(conv=P(sh.dp, None, sh.tp), state=P(sh.dp, sh.tp, None, None))




def ssm_forward(params, x, cfg, sh):
    """Full-sequence SSM layer (train/prefill).  x (B, S, D)."""
    bsz, s, d = x.shape
    d_inner, h, conv_dim = _ssm_dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = L.linear(params["in_proj"], x)
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xs.reshape(bsz, s, h, cfg.ssm_headdim)
    # groups stay an einsum axis inside ssd_chunked — no H/G-fold broadcast
    bh = b_mat.astype(jnp.float32).reshape(bsz, s, g, n)
    ch = c_mat.astype(jnp.float32).reshape(bsz, s, g, n)

    y, _ = ssd_chunked(xh, dt, a, bh, ch, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return L.linear(params["out_proj"], y)


def ssm_decode(params, x, cache: SSMCache, cfg, sh):
    """One-token recurrent step.  x (B, 1, D) → (out, new_cache)."""
    bsz = x.shape[0]
    d_inner, h, conv_dim = _ssm_dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    p = cfg.ssm_headdim

    zxbcdt = L.linear(params["in_proj"], x)[:, 0]
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, K, C)
    w = params["conv_w"]
    xbc_c = jnp.einsum("bkc,kc->bc", conv_in, w) + params["conv_b"]
    xbc_c = jax.nn.silu(xbc_c)
    new_conv = conv_in[:, 1:]

    xs, b_mat, c_mat = jnp.split(xbc_c, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a[None, :])                                  # (B, H)

    xh = xs.reshape(bsz, h, p).astype(jnp.float32)
    bh = jnp.repeat(b_mat.reshape(bsz, g, n), h // g, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c_mat.reshape(bsz, g, n), h // g, axis=1).astype(jnp.float32)

    new_state = (
        cache.state * decay[..., None, None]
        + jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], bh)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch) + params["D"][None, :, None] * xh
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.linear(params["out_proj"], y)[:, None, :]
    return out, SSMCache(new_conv, new_state)


def ssd_reference(x, dt, a, b_mat, c_mat):
    """Naive O(S²)-free sequential recurrence oracle for tests."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * a[None, :])
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x[:, t].astype(jnp.float32) * dt[:, t][..., None], b_mat[:, t]
        )
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, c_mat[:, t]))
    return jnp.stack(ys, axis=1), state
