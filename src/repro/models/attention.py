"""Grouped-query attention: training (dense / chunked-flash), prefill, and
KV-cache decode — plus the mesh-wide distributed decode combine.

Implementations:
  * ``dense``   — materialised scores; fine up to a few k context.
  * ``chunked`` — pure-jnp blockwise online softmax (lax.scan over KV
    blocks); O(S·B) memory, lowers on any backend — the dry-run path for the
    32k shapes.
  * ``pallas``  — the kernels/flashattn TPU kernel (interpret-validated).

Decode uses a ring-buffer-free static KV cache with ``dynamic_update_slice``
and position masking; sliding-window archs (Hymba) keep a rolling window
cache instead, bounding memory for the 500k shapes.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

_NEG = -1e30


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is ≤ cap (chunked attention block pick —
    handles odd totals like 32768 tokens + 256 VLM patches)."""
    c = min(cap, n)
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(k1, cfg.d_model, cfg.n_heads * hd, cfg.param_dtype, bias=cfg.qkv_bias),
        "wk": L.init_linear(k2, cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype, bias=cfg.qkv_bias),
        "wv": L.init_linear(k3, cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype, bias=cfg.qkv_bias),
        "wo": L.init_linear(k4, cfg.n_heads * hd, cfg.d_model, cfg.param_dtype),
    }


def attention_specs(cfg, tp="model"):
    return {
        "wq": L.linear_specs(None, tp, bias=cfg.qkv_bias),
        "wk": L.linear_specs(None, tp, bias=cfg.qkv_bias),
        "wv": L.linear_specs(None, tp, bias=cfg.qkv_bias),
        "wo": L.linear_specs(tp, None),
    }


# ---------------------------------------------------------------------------
# Score computation
# ---------------------------------------------------------------------------

def _dense_attn(q, k, v, causal, window, q_offset=0):
    """q (B,Sq,H,hd), k/v (B,Skv,KV,hd) → (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(hd)
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def _chunked_attn(q, k, v, causal, window, chunk_q=512, chunk_kv=1024):
    """Blockwise online-softmax in pure jnp (flash decomposition).

    Memory O(chunk_q · chunk_kv) per (batch, head) instead of O(S²); the
    sequential KV loop is a ``lax.scan`` so the HLO stays depth-1.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    cq = _largest_divisor(sq, chunk_q)
    ck = _largest_divisor(skv, chunk_kv)
    nq, nk = sq // cq, skv // ck
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, nq, cq, kv, group, hd)
    kc = k.reshape(b, nk, ck, kv, hd)
    vc = v.reshape(b, nk, ck, kv, hd)

    def q_block(qi, q_blk):
        # q_blk (b, cq, kv, group, hd)
        def kv_step(carry, inp):
            m_prev, l_prev, acc = carry
            kj, (k_blk, v_blk) = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            q_pos = qi * cq + jnp.arange(cq)[:, None]
            k_pos = kj * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= q_pos >= k_pos
            if window is not None:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(s <= _NEG / 2, 0.0, p)
            alpha = jnp.exp(m_prev - m_new)
            alpha = jnp.where(m_prev <= _NEG / 2, 0.0, alpha)
            l_new = alpha * l_prev + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, group, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, group, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, group, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))),
        )
        denom = jnp.where(l == 0.0, 1.0, l)
        o = (acc / denom[..., None])           # (b, kv, group, cq, hd)
        return jnp.moveaxis(o, 3, 1).reshape(b, cq, kv * group, hd)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


def multihead_attention(q, k, v, *, causal=True, window=None, impl="dense",
                        q_offset=0, chunk_q=512, chunk_kv=1024):
    if impl == "chunked":
        return _chunked_attn(q, k, v, causal, window, chunk_q, chunk_kv)
    if impl == "pallas":
        from repro.kernels.flashattn import flash_attention
        o = flash_attention(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
            causal=causal, window=window,
        )
        return jnp.moveaxis(o, 1, 2)
    return _dense_attn(q, k, v, causal, window, q_offset)


# ---------------------------------------------------------------------------
# Block-level forward (projections + RoPE + attention)
# ---------------------------------------------------------------------------

def attend(params, x, cfg, sh, *, kv_x=None, causal=True, window=None,
           positions=None, impl=None):
    """Full attention sub-layer.  ``kv_x`` enables cross-attention."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    # TP constraints go on the *flat* head dims (H·hd always divides the TP
    # axis; raw KV head counts like 5 or 8 do not — DESIGN.md §4)
    q = sh.act(L.linear(params["wq"], x), sh.dp, None, sh.tp)
    k = sh.act(L.linear(params["wk"], kv_x), sh.dp, None, sh.tp)
    v = sh.act(L.linear(params["wv"], kv_x), sh.dp, None, sh.tp)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, skv, cfg.n_kv_heads, hd)
    v = v.reshape(b, skv, cfg.n_kv_heads, hd)
    if cfg.rope_theta and kv_x is x:
        pos = positions if positions is not None else jnp.arange(s)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    impl = impl or ("chunked" if s >= cfg.attn_chunk_threshold else "dense")
    o = multihead_attention(q, k, v, causal=causal, window=window, impl=impl,
                            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    o = sh.bshd(o)
    return L.linear(params["wo"], o.reshape(b, s, cfg.n_heads * hd))


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, KV, hd)
    v: jax.Array        # (B, S_max, KV, hd)
    length: jax.Array   # () int32 — tokens currently cached


def init_kv_cache(batch, max_seq, n_kv, head_dim, dtype):
    return KVCache(
        k=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def kv_cache_specs(sh, seq_axis=None):
    from jax.sharding import PartitionSpec as P
    return KVCache(
        k=P(sh.dp, seq_axis, sh.tp, None),
        v=P(sh.dp, seq_axis, sh.tp, None),
        length=P(),
    )


def decode_attend(params, x, cache: KVCache, cfg, sh, *, window=None):
    """One-token decode step: update cache, attend against it.

    ``x (B, 1, D)``; ``cache.length (B,)`` carries *per-slot* positions so
    the serving engine's continuous batching can mix requests at different
    depths in one decode batch.  Returns (out, new_cache); the caller owns
    the length increment (it may mask inactive slots).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    pos = cache.length  # (B,)
    q = L.linear(params["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k = L.linear(params["wk"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = L.linear(params["wv"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    if cfg.rope_theta:
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[:, None], cfg.rope_theta)

    upd = jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0))
    )
    new_k = upd(cache.k, k.astype(cache.k.dtype), pos)
    new_v = upd(cache.v, v.astype(cache.v.dtype), pos)

    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   new_k.astype(jnp.float32)) / math.sqrt(hd)
    k_pos = jnp.arange(new_k.shape[1])
    mask = k_pos[None, :] <= pos[:, None]                      # (B, S)
    if window is not None:
        mask &= k_pos[None, :] > (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, new_v.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    out = L.linear(params["wo"], o)
    return out, KVCache(new_k, new_v, pos)


def distributed_decode_combine(partial_max, partial_sumexp, partial_pv, axis):
    """Flash-decoding across the mesh: each shard attends over its slice of a
    sequence-sharded KV cache; this combines the per-shard (m, l, Σp·v)
    triples into exact softmax attention with two tiny collectives."""
    m_glob = jax.lax.pmax(partial_max, axis)
    scale = jnp.exp(partial_max - m_glob)
    l_glob = jax.lax.psum(partial_sumexp * scale, axis)
    pv_glob = jax.lax.psum(partial_pv * scale[..., None], axis)
    return pv_glob / jnp.where(l_glob == 0, 1.0, l_glob)[..., None]
