"""Mixture-of-experts FFN: two dispatch strategies.

``dense``  — GShard-style capacity dispatch via one-hot einsums.  Memory is
O(T·E·C) in the dispatch mask, fine for small expert counts (phi3.5's 16).

``a2a``    — expert parallelism for large E (kimi-k2's 384): a shard_map
region where tokens are routed, exchanged with a capacity-bounded
``all_to_all`` along the tensor-parallel axis, run through the local experts
with ``jax.lax.ragged_dot`` (grouped GEMM — the MegaBlocks-style path), and
returned by the inverse ``all_to_all``.  Expert weights are additionally
FSDP-sharded along the data axis and gathered at use (ZeRO-3), which is what
lets a 1T-param model's optimizer state fit the pod (DESIGN.md §4/§5).

Both paths drop overflow tokens against a capacity factor (the standard
trade; the router aux loss keeps load balanced) and add optional shared
experts (kimi) computed densely.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import layers as L


def init_moe(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": L.init_linear(kr, d, e, jnp.float32),
        "w_gate": L.truncnorm(k1, (e, d, f), 1.0 / (d ** 0.5), cfg.param_dtype),
        "w_up": L.truncnorm(k2, (e, d, f), 1.0 / (d ** 0.5), cfg.param_dtype),
        "w_down": L.truncnorm(k3, (e, f, d), 1.0 / (f ** 0.5), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks, d, cfg.moe_d_ff * cfg.n_shared_experts, cfg.param_dtype)
    return p


def moe_specs(cfg, tp="model", fsdp: Optional[str] = None):
    """Experts on tp; optionally FSDP-shard the d_model dim on the data axis."""
    p = {
        "router": L.linear_specs(None, None),
        "w_gate": P(tp, fsdp, None),
        "w_up": P(tp, fsdp, None),
        "w_down": P(tp, None, fsdp),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_specs(tp)
    return p


def _router(params, x2d, cfg):
    """Returns (weights (T, k) fp32, expert ids (T, k) int32, aux loss)."""
    logits = L.linear(params["router"], x2d.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.moe_renormalize:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E · Σ_e f_e · p_e
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)
    return top_w, top_e, aux


# ---------------------------------------------------------------------------
# dense (one-hot) dispatch — small E
# ---------------------------------------------------------------------------

def moe_dense(params, x, cfg, sh):
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    top_w, top_e, aux = _router(params, x2d, cfg)
    e = cfg.n_experts
    cap = int(max(1, (t * cfg.top_k * cfg.capacity_factor) // e))

    # position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)          # (T, k, E)
    flat = onehot.reshape(t * cfg.top_k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                        # (T·k, E)
    slot = jnp.sum(pos * flat, axis=-1).reshape(t, cfg.top_k)    # (T, k)
    keep = slot < cap
    w = jnp.where(keep, top_w, 0.0)

    disp = (
        jax.nn.one_hot(top_e, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1, dtype=x.dtype)[:, :, None, :]
    ).sum(1)[..., :cap]                                          # (T, E, C)
    comb = (
        jax.nn.one_hot(top_e, e, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1, dtype=jnp.float32)[:, :, None, :]
        * w[..., None, None]
    ).sum(1)[..., :cap]                                          # (T, E, C)

    xe = jnp.einsum("tec,td->ecd", disp, x2d)                    # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])         # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb, ye.astype(jnp.float32)).astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], x2d)
    return y.reshape(b, s, d), aux


def moe_gather(params, x, cfg, sh):
    """Dropless per-token expert gather — the decode path.

    Decode batches are small, so gathering each token's top-k expert weights
    (the memory-bound regime MoE decode lives in anyway) is exact: no
    capacity, no dropped tokens, and decode logits match the teacher-forced
    forward pass bit-for-bit when the train path doesn't drop either.
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    top_w, top_e, aux = _router(params, x2d, cfg)
    y = jnp.zeros_like(x2d, dtype=jnp.float32)
    for i in range(cfg.top_k):
        e = top_e[:, i]
        wg = params["w_gate"][e]          # (T, D, F) gather
        wu = params["w_up"][e]
        wd = params["w_down"][e]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", x2d, wg))
        h = h * jnp.einsum("td,tdf->tf", x2d, wu)
        y = y + top_w[:, i, None] * jnp.einsum("tf,tfd->td", h, wd).astype(jnp.float32)
    y = y.astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], x2d)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# all_to_all expert parallelism — large E
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _gather_int8(w, axis_name, shard_axis):
    """All-gather an FSDP weight shard in int8 + fp32 scales, dequantise to
    the original dtype.  Scales are per (expert, out-feature) over the
    sharded (d_model) axis, so each shard dequantises independently.

    Backward is the straight-through all-gather transpose: a (bf16)
    ``psum_scatter`` of the cotangent back onto the shard — quantisation is
    forward-only, so optimiser state stays exact (1-bit-Adam-style trade).
    """
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=shard_axis,
                    keepdims=True) / 127.0 + 1e-12
    wq = jnp.round(w.astype(jnp.float32) / scale).astype(jnp.int8)
    wq_g = jax.lax.all_gather(wq, axis_name, axis=0, tiled=False)
    sc_g = jax.lax.all_gather(scale.astype(jnp.float32), axis_name, axis=0,
                              tiled=False)
    w_g = (wq_g.astype(jnp.float32) * sc_g).astype(w.dtype)
    # (S, E, …) → concatenate the shards back along the sharded axis
    w_g = jnp.moveaxis(w_g, 0, shard_axis)       # (E, S, Dl, F) or (E, F, S, Dl)
    shp = list(w.shape)
    shp[shard_axis] = -1
    return w_g.reshape(shp)


def _gather_int8_fwd(w, axis_name, shard_axis):
    return _gather_int8(w, axis_name, shard_axis), w.shape


def _gather_int8_bwd(axis_name, shard_axis, shard_shape, g):
    gw = jax.lax.psum_scatter(g, axis_name, scatter_dimension=shard_axis,
                              tiled=True)
    return (gw.astype(jnp.float32).reshape(shard_shape),)


_gather_int8.defvjp(_gather_int8_fwd, _gather_int8_bwd)


def _moe_a2a_local(params, x_local, cfg, tp_axis, fsdp_axis):
    """Per-device body (inside shard_map).  ``x_local (T_l, D)``."""
    m = jax.lax.psum(1, tp_axis)                       # tp world size
    t_l, d = x_local.shape
    e = cfg.n_experts
    e_local = e // m
    k = cfg.top_k

    top_w, top_e, aux = _router(params, x_local, cfg)
    aux = jax.lax.pmean(aux, tp_axis)

    # ---- build send buffers: route (token, slot) pairs to owner ranks ----
    flat_e = top_e.reshape(-1)                          # (T_l·k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t_l), k)
    dest = flat_e // e_local                            # owning tp rank
    cap = int(max(1, (t_l * k * cfg.capacity_factor) // m))

    oh = jax.nn.one_hot(dest, m, dtype=jnp.int32)       # (T_l·k, M)
    slot = (jnp.cumsum(oh, axis=0) - oh)
    slot = jnp.sum(slot * oh, axis=-1)                  # (T_l·k,)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)

    def scatter(src, fill, dtype):
        buf = jnp.full((m, cap + 1) + src.shape[1:], fill, dtype)
        return buf.at[dest, slot_c].set(src, mode="drop")[:, :cap]

    send_x = scatter(x_local[flat_t], 0, x_local.dtype)            # (M, C, D)
    send_e = scatter((flat_e % e_local).astype(jnp.int32), -1, jnp.int32)
    # ---- exchange along the tp axis --------------------------------------
    recv_x = jax.lax.all_to_all(send_x, tp_axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, tp_axis, 0, 0, tiled=False)
    recv_x = recv_x.reshape(m * cap, d)
    recv_e = recv_e.reshape(m * cap)

    # ---- local experts: sort + grouped GEMM (ragged_dot) ------------------
    eid = jnp.where(recv_e < 0, e_local, recv_e)        # empty slots → pad group
    order = jnp.argsort(eid, stable=True)
    xs = recv_x[order]
    group_sizes = jnp.bincount(eid, length=e_local + 1)[:e_local].astype(jnp.int32)

    # FSDP: gather the d_model shards of this device's expert weights at use.
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if fsdp_axis is not None:
        if cfg.moe_fsdp_int8:
            # int8-compressed weight gather (−50% AG bytes; per-(expert,
            # out-feature) scales, dequantised shard-wise after the gather —
            # EXPERIMENTS.md §Perf kimi iteration)
            wg = _gather_int8(wg, fsdp_axis, shard_axis=1)
            wu = _gather_int8(wu, fsdp_axis, shard_axis=1)
            wd = _gather_int8(wd, fsdp_axis, shard_axis=2)
        else:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, wg, group_sizes))
    h = h * jax.lax.ragged_dot(xs, wu, group_sizes)
    ys = jax.lax.ragged_dot(h, wd, group_sizes)         # (M·C, D)

    inv = jnp.argsort(order)
    recv_y = ys[inv].reshape(m, cap, d)
    send_y = jax.lax.all_to_all(recv_y, tp_axis, 0, 0, tiled=False)  # back

    # ---- combine: weighted scatter-add back into token order -------------
    y_flat = send_y.reshape(m * cap, d)
    src_idx = dest * cap + slot_c                        # (T_l·k,) positions
    contrib = jnp.where(keep, flat_w, 0.0)[:, None] * y_flat[
        jnp.clip(src_idx, 0, m * cap - 1)
    ].astype(jnp.float32)
    y = jnp.zeros((t_l, d), jnp.float32).at[flat_t].add(contrib)
    return y.astype(x_local.dtype), aux


def moe_a2a(params, x, cfg, sh, mesh):
    """shard_map wrapper: tokens sharded over (dp…, tp), experts over tp."""
    b, s, d = x.shape
    tp = sh.tp
    fsdp = sh.dp[-1] if cfg.moe_fsdp else None
    p_x = P(sh.dp, tp, None)        # sequence-sharded over tp inside MoE
    in_specs = (
        {
            "router": {"w": P(None, None)},
            "w_gate": P(tp, fsdp, None),
            "w_up": P(tp, fsdp, None),
            "w_down": P(tp, None, fsdp),
            **({"shared": jax.tree.map(lambda _: P(None, None), params["shared"])}
               if "shared" in params else {}),
        },
        p_x,
    )

    def body(prm, xl):
        bl, sl, _ = xl.shape
        y, aux = _moe_a2a_local(
            {k: v for k, v in prm.items() if k != "shared"},
            xl.reshape(bl * sl, d), cfg, tp, fsdp,
        )
        if "shared" in prm:
            y = y + L.mlp(prm["shared"], xl.reshape(bl * sl, d))
        # aux is pmean'd over tp inside; also average over dp lanes
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(p_x, P()), check_rep=False,
    )(params, x)
    return y, aux


def moe_ffn(params, x, cfg, sh, mesh=None):
    if cfg.moe_impl == "a2a" and mesh is not None:
        return moe_a2a(params, x, cfg, sh, mesh)
    return moe_dense(params, x, cfg, sh)
