"""Shared model layers: norms, rotary embeddings, linears, SwiGLU MLP.

Functional style: ``init_*`` returns a param pytree (+ a parallel
PartitionSpec pytree from the ``*_specs`` helpers); apply functions are pure.
All matmuls run in the param dtype (bf16 for production configs) with fp32
norms/softmax where it matters.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def truncnorm(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_specs():
    return {"scale": P(None)}


def rmsnorm(params, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_specs():
    return {"scale": P(None), "bias": P(None)}


def layernorm(params, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"] + params["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncnorm(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_specs(in_spec, out_spec, bias=False):
    p = {"w": P(in_spec, out_spec)}
    if bias:
        p["b"] = P(out_spec)
    return p


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """``x (..., S, H, hd)``, ``positions (..., S)`` broadcastable."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, variant="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": init_linear(k2, d_model, d_ff, dtype),
        "down": init_linear(k3, d_ff, d_model, dtype),
    }
    if variant == "swiglu":
        p["gate"] = init_linear(k1, d_model, d_ff, dtype)
    return p


def mlp_specs(tp="model", variant="swiglu"):
    p = {
        "up": linear_specs(None, tp),
        "down": linear_specs(tp, None),
    }
    if variant == "swiglu":
        p["gate"] = linear_specs(None, tp)
    return p


def mlp(params, x, sh=None):
    if "gate" in params:  # SwiGLU
        h = jax.nn.silu(linear(params["gate"], x)) * linear(params["up"], x)
    else:  # plain GELU MLP (starcoder2-style)
        h = jax.nn.gelu(linear(params["up"], x))
    if sh is not None:
        h = sh.bsf(h)
    return linear(params["down"], h)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype):
    return {"table": truncnorm(key, (vocab, d_model), 1.0, dtype)}


def embedding_specs(tp="model"):
    return {"table": P(tp, None)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Logits against the (possibly separate) output table, fp32 accumulate."""
    return jnp.einsum(
        "bsd,vd->bsv", x, params["table"], preferred_element_type=jnp.float32
    )
