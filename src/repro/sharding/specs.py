"""Logical-axis → mesh-axis sharding rules (GSPMD side of the framework).

Mesh axes (launch/mesh.py):
    single pod: ("data", "model")            = (16, 16)
    multi-pod:  ("pod", "data", "model")     = (2, 16, 16)

Logical rules (MaxText-style):  batch → (pod, data);  heads / d_ff / vocab /
experts → model;  long-context KV sequence → data (sequence parallelism for
the 500k decode shapes).  ``Sharding`` is threaded through model code and
no-ops gracefully outside a mesh so smoke tests run on one CPU device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Sharding:
    """Axis names for the active mesh (None disables constraints)."""

    dp: Tuple[str, ...] = ("data",)   # batch / fsdp axes ("pod" folded in)
    tp: str = "model"                 # tensor-parallel axis
    sp: Optional[str] = None          # sequence-parallel axis (long decode)
    enabled: bool = True

    # ---- activation constraint helpers ------------------------------------
    def act(self, x, *spec):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def batch(self):
        return self.dp

    # common activation layouts
    def bsd(self, x):   # (batch, seq, d_model)
        return self.act(x, self.dp, None, None)

    def bshd(self, x):  # (batch, seq, heads, head_dim) — heads on tp
        return self.act(x, self.dp, None, self.tp, None)

    def bsf(self, x):   # (batch, seq, d_ff) — ff on tp
        return self.act(x, self.dp, None, self.tp)

    def bsv(self, x):   # (batch, seq, vocab) — vocab on tp
        return self.act(x, self.dp, None, self.tp)


NO_SHARDING = Sharding(enabled=False, dp=(), tp=None, sp=None)


def single_pod() -> Sharding:
    return Sharding(dp=("data",), tp="model")


def multi_pod() -> Sharding:
    return Sharding(dp=("pod", "data"), tp="model")


def for_mesh(mesh) -> Sharding:
    names = mesh.axis_names
    if "pod" in names:
        return multi_pod()
    return single_pod()
