"""Three-term roofline analysis from dry-run artifacts (CPU container,
TPU v5e target).

Terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = HLO_collective_bytes_per_device / ICI_BW

``cost_analysis`` on XLA:CPU counts a ``while`` body once (verified
empirically — DESIGN.md §6), so scanned-layer models undercount by ~n_layers.
We therefore combine three compiles per cell: the full-depth one (memory
analysis + schedule proof) and L=1 / L=2 probes, extrapolating

    cost(L) = cost(1) + (L − 1) · (cost(2) − cost(1))

which is exact for homogeneous stacks and, via the L1/L2 split, also
separates kimi-k2's leading dense layer from its MoE layers.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _cell_file(arch, shape, mesh_kind, layers=None):
    sfx = f"_L{layers}" if layers else ""
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.join(RESULTS_DIR, f"{safe}__{shape}__{mesh_kind}{sfx}.json")


def _coll_total(d: dict) -> float:
    return float(sum(d.get("collective_bytes", {}).values()))


def extrapolated_costs(arch, shape, mesh_kind, n_layers) -> Optional[dict]:
    """Differential (L1, L2) extrapolation of flops/bytes/collective bytes."""
    l1 = _load(_cell_file(arch, shape, mesh_kind, 1))
    l2 = _load(_cell_file(arch, shape, mesh_kind, 2))
    if not l1 or not l2 or l1["status"] != "ok" or l2["status"] != "ok":
        return None

    def extrap(key_fn):
        c1, c2 = key_fn(l1), key_fn(l2)
        per_layer = max(c2 - c1, 0.0)
        return c1 + (n_layers - 1) * per_layer

    return {
        "flops": extrap(lambda d: d["flops_per_device"]),
        "bytes": extrap(lambda d: d["bytes_per_device"]),
        "collective_bytes": extrap(_coll_total),
        "per_layer_flops": max(l2["flops_per_device"] - l1["flops_per_device"], 0.0),
        "per_layer_coll": max(_coll_total(l2) - _coll_total(l1), 0.0),
    }


def model_flops(cfg, shape) -> float:
    """Analytic "useful" FLOPs per step: 6·N_active·D (+ causal attention)."""
    n_active = cfg.active_param_count()
    s, b = shape.seq_len, shape.global_batch
    hd, hq = cfg.head_dim, cfg.n_heads
    if shape.kind == "train":
        tokens = s * b
        attn = 6 * cfg.n_layers * s * hd * hq  # fwd+bwd, causal-halved
        return tokens * (6 * n_active + attn)
    if shape.kind == "prefill":
        tokens = s * b
        attn = 2 * cfg.n_layers * s * hd * hq  # fwd only, causal-halved... 2·s·hd·h
        return tokens * (2 * n_active + attn)
    # decode: one token per sequence
    ctx = s if cfg.family not in ("ssm",) else 0
    if cfg.family == "hybrid" and cfg.attn_window:
        ctx = cfg.attn_window  # windowed layers dominate
    attn = 4 * cfg.n_layers * ctx * hd * cfg.n_kv_heads
    return b * (2 * n_active + attn)


def roofline_row(arch, shape_name, mesh_kind="single") -> Optional[dict]:
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    full = _load(_cell_file(arch, shape_name, mesh_kind))
    if full is None:
        return None
    if full["status"] != "ok":
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": full["status"], "reason": full.get("reason", full.get("error"))}
    ext = extrapolated_costs(arch, shape_name, mesh_kind, cfg.n_layers)
    if ext is None:
        ext = {
            "flops": full["flops_per_device"],
            "bytes": full["bytes_per_device"],
            "collective_bytes": _coll_total(full),
        }
        ext["extrapolated"] = False
    else:
        ext["extrapolated"] = True

    t_compute = ext["flops"] / PEAK_FLOPS
    t_memory = ext["bytes"] / HBM_BW
    t_coll = ext["collective_bytes"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    n_dev = full["devices"]
    mf = model_flops(cfg, shape)
    hlo_total = ext["flops"] * n_dev
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": full["mesh"],
        "status": "ok",
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "hbm_gib_per_device": (full["memory"]["temp_bytes"]
                               + full["memory"]["argument_bytes"]) / 2**30,
        "extrapolated": ext.get("extrapolated", True),
        "compile_s": full["compile_s"],
    }


def full_table(mesh_kind="single"):
    from repro.configs import ARCH_IDS, SHAPES

    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            row = roofline_row(arch, shape, mesh_kind)
            if row is not None:
                rows.append(row)
    return rows


def format_markdown(rows) -> str:
    out = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | roofline frac | MODEL/HLO | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | — | — | — | "
                f"{r.get('status')} | — | — | — |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['hbm_gib_per_device']:.1f} |"
        )
    return "\n".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = full_table(args.mesh)
    print(format_markdown(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
