"""Synthetic delimiter-separated datasets mirroring the paper's two
evaluation workloads (§5):

  * ``yelp_like``   — few long text-heavy columns, quoted fields containing
    delimiters/newlines (avg ~720 B/record);
  * ``taxi_like``   — many short numeric/temporal columns
    (avg ~88 B/record, ~5 B/field) stressing type conversion.

Used by benchmarks (paper Figs. 9–13 analogues), tests, and the training
examples' data pipeline.
"""
from __future__ import annotations

import numpy as np

_WORDS = (
    "the food was great amazing terrible service slow fast delicious cold "
    "warm friendly staff would recommend never again five stars one star "
    "best worst pizza burger sushi coffee place downtown"
).split()


def yelp_like(rng: np.random.Generator, n_records: int, avg_text: int = 600) -> bytes:
    """id,stars,useful,text,date — text quoted with embedded ',' '\\n' '\"'."""
    rows = []
    for i in range(n_records):
        n_words = max(3, int(rng.poisson(avg_text / 6)))
        words = rng.choice(_WORDS, size=n_words)
        text = " ".join(words.tolist())
        # sprinkle structural characters inside the quoted text
        if rng.random() < 0.8:
            text += ", really"
        if rng.random() < 0.5:
            text += "\nsecond line"
        if rng.random() < 0.3:
            text += ' said ""wow"" loudly'
        stars = rng.integers(1, 6)
        useful = rng.integers(0, 100)
        date = f"{rng.integers(2005, 2022):04d}-{rng.integers(1, 13):02d}-{rng.integers(1, 29):02d}"
        rows.append(f'{i},{stars},{useful},"{text}",{date}\n')
    return "".join(rows).encode()


YELP_SCHEMA = (("id", "int32"), ("stars", "int32"), ("useful", "int32"),
               ("text", "str"), ("date", "date"))


def taxi_like(rng: np.random.Generator, n_records: int) -> bytes:
    """17 short numeric/temporal columns (NYC-taxi-shaped)."""
    rows = []
    for i in range(n_records):
        t0 = (f"{rng.integers(2018, 2019):04d}-{rng.integers(1, 13):02d}-"
              f"{rng.integers(1, 29):02d} {rng.integers(0, 24):02d}:"
              f"{rng.integers(0, 60):02d}:{rng.integers(0, 60):02d}")
        vals = [
            str(rng.integers(1, 3)), t0, t0,
            str(rng.integers(1, 7)),
            f"{rng.random() * 30:.2f}",
            str(rng.integers(1, 265)), str(rng.integers(1, 265)),
            str(rng.integers(1, 5)),
            f"{rng.random() * 80:.2f}", f"{rng.random() * 5:.2f}",
            f"{rng.random() * 0.5:.2f}", f"{rng.random() * 20:.2f}",
            f"{rng.random() * 10:.2f}", "0.3",
            f"{rng.random() * 100:.2f}", str(rng.integers(0, 3)),
            f"{rng.random():.2f}",
        ]
        rows.append(",".join(vals) + "\n")
    return "".join(rows).encode()


TAXI_SCHEMA = tuple(
    [("vendor", "int32"), ("pickup", "date"), ("dropoff", "date"),
     ("passengers", "int32"), ("distance", "float32"),
     ("pu_loc", "int32"), ("do_loc", "int32"), ("ratecode", "int32"),
     ("fare", "float32"), ("extra", "float32"), ("mta", "float32"),
     ("tip", "float32"), ("tolls", "float32"), ("surcharge", "float32"),
     ("total", "float32"), ("payment", "int32"), ("congestion", "float32")]
)


def skewed(rng: np.random.Generator, n_records: int, big_bytes: int = 1 << 20) -> bytes:
    """Paper Fig. 11 (right): one giant record among normal ones."""
    data = yelp_like(rng, n_records // 2)
    big = b'999999,5,0,"' + b"x" * big_bytes + b'",2020-01-01\n'
    return data + big + yelp_like(rng, n_records - n_records // 2 - 1)


def format_payload(fmt: str, n: int) -> bytes:
    """Deterministic synthetic corpus per registered dialect (csv / jsonl /
    zone / clf).  No RNG — the benchmark and autotuner logs must describe a
    byte-stable input across runs, so tuned configs and bench rows measured
    on different days still refer to the same bytes."""
    if fmt == "csv":
        lines = ["%d,user_%d,%d.%02d,2024-01-%02d"
                 % (i, i, i % 97, i % 100, i % 28 + 1) for i in range(n)]
    elif fmt == "jsonl":
        lines = ['{"id": %d, "name": "user_%d", "score": %d.%02d}'
                 % (i, i, i % 97, i % 100) for i in range(n)]
    elif fmt == "zone":
        lines = ["host%d %d IN A 10.0.%d.%d"
                 % (i, 300 + i % 3600, i % 256, i * 7 % 256)
                 for i in range(n)]
        # every 16th record spans lines via parens (the carry-relevant
        # shape) and trails a comment
        for i in range(0, n, 16):
            lines[i] = ("host%d %d ( IN\n\tA ) 10.0.%d.%d;rr"
                        % (i, 300 + i % 3600, i % 256, i * 7 % 256))
    elif fmt == "clf":
        lines = ['10.0.0.%d [01/Jan/2024 00:%02d:%02d] "GET /item/%d" %d'
                 % (i % 256, i // 60 % 60, i % 60, i, 200 + i % 300)
                 for i in range(n)]
    else:
        raise ValueError(f"no payload generator for format {fmt!r}")
    return ("\n".join(lines) + "\n").encode()
