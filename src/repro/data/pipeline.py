"""ParPaRaw-fed training data pipeline: raw CSV bytes → token batches.

This is where the paper's technique becomes a first-class framework feature:
the training loop consumes batches whose text column was parsed out of raw
delimiter-separated bytes *on-accelerator* by the streaming ParPaRaw
pipeline (no host-side CSV parsing anywhere).

    CSV stream ──▶ StreamingParser (device) ──▶ text CSS + field index
               ──▶ byte-level tokens ──▶ packed (B, S) batches

The byte-level tokenizer maps utf-8 bytes to ids [3, 259) with PAD=0,
BOS=1, EOS=2 — vocabulary-compatible with every assigned arch (all vocabs
≥ 512 in reduced configs).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core.streaming import StreamingParser

PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
BYTE_OFFSET = 3


def tokenize_bytes(data: np.ndarray) -> np.ndarray:
    return data.astype(np.int32) + BYTE_OFFSET


def detokenize(tokens: np.ndarray) -> bytes:
    toks = tokens[(tokens >= BYTE_OFFSET)]
    return bytes((toks - BYTE_OFFSET).astype(np.uint8))


@dataclasses.dataclass
class PipelineConfig:
    text_column: str = "text"
    seq_len: int = 128
    batch_size: int = 8
    partition_bytes: int = 1 << 16
    max_carry_bytes: int = 1 << 16
    max_records_per_partition: int = 4096
    chunk_size: int = 64


class CSVTokenPipeline:
    """Streams (tokens, labels) batches out of a raw CSV byte source."""

    def __init__(self, schema: Schema, cfg: PipelineConfig):
        self.cfg = cfg
        pcfg = ParserConfig(
            dfa=make_csv_dfa(), schema=schema,
            max_records=cfg.max_records_per_partition,
            chunk_size=cfg.chunk_size,
        )
        self.parser = Parser(pcfg)
        self.schema = schema

    def _documents(self, source) -> Iterator[np.ndarray]:
        """Yields one token array per record's text field."""
        sp = StreamingParser(self.parser, self.cfg.partition_bytes,
                             self.cfg.max_carry_bytes)
        col = [i for i, c in enumerate(self.schema.columns)
               if c.name == self.cfg.text_column][0]
        for result, n in sp.parse_stream(source):
            css = np.asarray(result.css)
            offs = np.asarray(result.field_offset[col][:n])
            lens = np.asarray(result.field_length[col][:n])
            for o, l in zip(offs, lens):
                if l > 0:
                    yield tokenize_bytes(css[o : o + l])

    def batches(self, source, start_step: int = 0) -> Iterator[dict]:
        """Packs documents into (B, S) with BOS/EOS, next-token labels.

        ``start_step`` skips ahead deterministically — the checkpoint/resume
        contract (train/loop.py) restores the pipeline offset this way.
        """
        s, b = self.cfg.seq_len, self.cfg.batch_size
        buf = np.full((0,), 0, np.int32)
        step = 0
        rows = []
        for doc in self._documents(source):
            buf = np.concatenate([buf, [BOS_ID], doc, [EOS_ID]]).astype(np.int32)
            while buf.size >= s + 1:
                rows.append(buf[: s + 1])
                buf = buf[s + 1:]
                if len(rows) == b:
                    if step >= start_step:
                        block = np.stack(rows)
                        yield {"tokens": block[:, :-1], "labels": block[:, 1:]}
                    step += 1
                    rows = []
