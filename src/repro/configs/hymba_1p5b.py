"""hymba-1.5b [hybrid] — parallel attention + Mamba heads (arXiv:2411.13676).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention except every 8th layer (global), which bounds the
KV cache — this arch runs the 500k long-context decode shape.  Hymba's
learned meta tokens are omitted (backbone-only scope, DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    # d_inner=3200 → 64 SSD heads of width 50; 4 B/C groups (16 heads per
    # group) so head counts divide both the group count and the 16-way TP axis
    ssm_state=16, ssm_expand=2, ssm_headdim=50, ssm_ngroups=4,
    attn_window=1024, global_layer_every=8,
)

REDUCED = ModelConfig(
    name="hymba-1.5b-reduced", family="hybrid",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=160, vocab=512,
    ssm_state=8, ssm_expand=2, ssm_headdim=20, ssm_ngroups=1,
    attn_window=32, global_layer_every=2, remat=False, ssm_chunk=16,
)
