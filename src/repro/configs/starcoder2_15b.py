"""starcoder2-15b [dense] — GQA kv=4, RoPE (arXiv:2402.19173).

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, mlp_variant="gelu",
)

REDUCED = ModelConfig(
    name="starcoder2-15b-reduced", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab=512, mlp_variant="gelu", remat=False,
)
