"""deepseek-7b [dense] — llama-arch, MHA (kv == heads) (arXiv:2401.02954).

30L d_model=4096 32H (kv=32) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400,
)

REDUCED = ModelConfig(
    name="deepseek-7b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, remat=False,
)
