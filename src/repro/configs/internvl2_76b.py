"""internvl2-76b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The vision
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
patch embeddings; the LM backbone is fully implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, rope_theta=1e6,
    n_patches=256,
)

REDUCED = ModelConfig(
    name="internvl2-76b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, n_patches=8, remat=False,
)
