"""mamba2-370m [ssm] — SSD / state-space duality (arXiv:2405.21060).

48L d_model=1024, attention-free, vocab=50280, ssm_state=128.
O(1)-state decode → runs the 500k long-context shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    rope_theta=0.0,
)

REDUCED = ModelConfig(
    name="mamba2-370m-reduced", family="ssm",
    n_layers=2, d_model=64, vocab=512,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_ngroups=1,
    rope_theta=0.0, remat=False, ssm_chunk=16,
)
