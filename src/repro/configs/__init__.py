from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable",
           "ARCH_IDS", "get_config"]
