from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.parse_formats import FormatTuning, TUNINGS, tuned_parser_config, tuning_for
from repro.configs.registry import ARCH_IDS, get_config

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable",
           "ARCH_IDS", "get_config",
           "FormatTuning", "TUNINGS", "tuned_parser_config", "tuning_for"]
