"""Per-format parse tuning presets on top of the core format registry.

``repro.core.formats`` owns *what* a format is (DFA, tagging, schema);
this module owns *how to run it well*: field-width and partition-size
knobs per dialect, derived from the shapes the format actually produces
(zone TTLs are short ints, CLF request strings are long, JSONL nests blow
up field lengths).  Kept in ``configs`` so core carries no tuning policy
and benchmarks/services share one source of defaults.

    >>> from repro.configs.parse_formats import tuned_parser_config
    >>> cfg = tuned_parser_config("jsonl", backend="pallas", max_records=4096)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import formats
from repro.core.parser import ParserConfig


@dataclasses.dataclass(frozen=True)
class FormatTuning:
    """Per-format knob overrides applied under any caller overrides.

    ``chunk_size`` trades scan depth against per-chunk state-vector work
    for the format's typical record length; ``int_width``/``float_width``
    bound the fused typeconv windows (smaller widths → smaller VMEM
    tiles); ``stream_partition_bytes`` is the streaming partition size at
    which carry re-parse overhead stays <~1% for the format's record
    lengths (multi-line zone records need headroom).
    """

    chunk_size: int = 64
    int_width: int = 11
    float_width: int = 24
    stream_partition_bytes: int = 1 << 16


TUNINGS: Dict[str, FormatTuning] = {
    "csv": FormatTuning(),
    "csv+comment": FormatTuning(),
    "tsv": FormatTuning(),
    "simple": FormatTuning(chunk_size=32),
    # CLF records are long (request strings) but its only numeric column is
    # a 3-digit status code: narrow int windows, bigger chunks.
    "clf": FormatTuning(chunk_size=128, int_width=6),
    # JSONL: nested raw-subtext fields stretch records; TTL-free floats
    # keep the default width.
    "jsonl": FormatTuning(chunk_size=128),
    # Zone: TTLs are ≤ 10 digits, records can span lines via parens, so
    # streaming partitions get extra carry headroom.
    "zone": FormatTuning(chunk_size=64, int_width=10,
                         stream_partition_bytes=1 << 17),
}

_DEFAULT = FormatTuning()


def tuning_for(name: str) -> FormatTuning:
    formats.get_format(name)  # raise on unknown formats, not silent default
    return TUNINGS.get(name, _DEFAULT)


def tuned_parser_config(name: str, **overrides) -> ParserConfig:
    """`formats.parser_config` with this module's tuning filled in.

    Caller overrides win over tuning; tuning wins over core defaults.
    ``autotune`` defaults on: knobs left unset resolve from the measured
    per-device cache (``repro.tune``) when an entry exists — the static
    :class:`FormatTuning` values here are the cold-cache floor, the cache
    carries what measurement actually picked (e.g. the committed seed
    cache resolves clf/jsonl/zone to the staged path on interpret-CPU,
    where BENCH_parser.json shows the megakernel regressing).
    """
    t = tuning_for(name)
    for knob in ("chunk_size", "int_width", "float_width"):
        overrides.setdefault(knob, getattr(t, knob))
    overrides.setdefault("autotune", True)
    return formats.parser_config(name, **overrides)
