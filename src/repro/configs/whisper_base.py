"""whisper-base [audio] — encoder-decoder backbone (arXiv:2212.04356).

6L (enc) + 6L (dec), d_model=512 8H d_ff=2048 vocab=51865.  The conv/mel
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
frame embeddings (B, 1500, d_model); the transformer backbone (encoder
self-attn, decoder self+cross attn, KV-cache decode) is fully implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    is_encoder_decoder=True, n_enc_layers=6, enc_frames=1500,
    # tiny d_model → dense 4k×4k score matrices dominate memory; chunk early
    attn_chunk_threshold=2048,
)

REDUCED = ModelConfig(
    name="whisper-base-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    is_encoder_decoder=True, n_enc_layers=2, enc_frames=16, remat=False,
)
