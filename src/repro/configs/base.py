"""Model/shape/run configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0            # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0  # 0 disables RoPE
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mlp_variant: str = "swiglu"   # swiglu | gelu (starcoder2)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert FFN width
    n_shared_experts: int = 0
    first_k_dense: int = 0       # leading dense layers (kimi-k2)
    moe_impl: str = "dense"      # dense | a2a
    moe_fsdp: bool = False       # ZeRO-3 expert weights over the data axis
    moe_fsdp_int8: bool = False  # int8-compressed FSDP weight gathers
    capacity_factor: float = 1.25
    moe_renormalize: bool = True
    moe_aux_weight: float = 0.01

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Hymba) ------------------------------------------------------
    attn_window: Optional[int] = None   # sliding window (non-global layers)
    global_layer_every: int = 0         # 0 = all layers global

    # --- encoder-decoder (Whisper) --------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500              # stub frontend sequence length

    # --- VLM (InternVL) --------------------------------------------------------
    n_patches: int = 0                  # stub patch embeddings prepended

    # --- numerics / perf knobs ---------------------------------------------
    param_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True
    attn_chunk_threshold: int = 8192    # seq len above which chunked attn kicks in
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    logit_chunk: int = 0                # 0 = unchunked loss

    #: embedding/LM-head tables are padded to this multiple so the vocab dim
    #: always divides the 16-way TP axis (standard practice; pad logits are
    #: masked to −inf in the loss and decode paths)
    vocab_pad_multiple: int = 256

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory is bounded (SSM state / sliding window)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.attn_window is not None
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn_mats = 3 if self.mlp_variant == "swiglu" else 2
        dense_ffn = ffn_mats * d * self.d_ff
        moe_ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts \
            + self.n_shared_experts * 3 * d * self.moe_d_ff
        if self.family == "ssm":
            d_inner = self.ssm_expand * d
            h = d_inner // self.ssm_headdim
            gn = self.ssm_ngroups * self.ssm_state
            per = d * (2 * d_inner + 2 * gn + h) + d_inner * d
            total += self.n_layers * per
        elif self.family == "moe":
            n_moe = self.n_layers - self.first_k_dense
            total += self.first_k_dense * (attn + dense_ffn) + n_moe * (attn + moe_ffn)
        elif self.family == "hybrid":
            d_inner = self.ssm_expand * d
            h = d_inner // self.ssm_headdim
            gn = self.ssm_ngroups * self.ssm_state
            ssm = d * (2 * d_inner + 2 * gn + h) + d_inner * d
            total += self.n_layers * (attn + ssm + dense_ffn)
        else:
            n_dec = self.n_layers
            total += n_dec * (attn + dense_ffn)
            if self.is_encoder_decoder:
                total += self.n_enc_layers * (attn + dense_ffn) + n_dec * attn  # cross
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        act_ffn = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff + d * self.n_experts
        n_moe = self.n_layers - self.first_k_dense
        total = self.vocab * d * 2
        total += self.first_k_dense * (attn + 3 * d * self.d_ff) + n_moe * (attn + act_ffn)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 524k-token decode requires sub-quadratic "
            "attention / bounded cache (see DESIGN.md §5)"
        )
    return True, ""
