"""qwen2-1.5b [dense] — GQA kv=2 with QKV bias (arXiv:2407.10671).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, qkv_bias=True, tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen2-1.5b-reduced", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=512, qkv_bias=True, tie_embeddings=True, remat=False,
)
