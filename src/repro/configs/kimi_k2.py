"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
(paper-table config; arXiv:2501.kimi2).

61L d_model=7168 64H (GQA kv=8) d_ff(per-expert)=2048 vocab=163840,
1 leading dense layer + 1 shared expert (DeepSeek-V3-style layout).
Expert parallelism via all_to_all + ragged_dot; expert weights FSDP-sharded
over the data axis (ZeRO-3) — see DESIGN.md §5 for the memory analysis.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab=163840, head_dim=112,
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    first_k_dense=1, moe_impl="a2a", moe_fsdp=True,
)

REDUCED = ModelConfig(
    name="kimi-k2-reduced", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, head_dim=16,
    n_experts=8, top_k=2, moe_d_ff=32, n_shared_experts=1,
    first_k_dense=1, moe_impl="dense", remat=False,
)
