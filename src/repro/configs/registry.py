"""Architecture registry: --arch <id> → ModelConfig."""
from __future__ import annotations

import importlib

_MODULES = {
    "internvl2-76b": "repro.configs.internvl2_76b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "llama3.2-3b": "repro.configs.llama32_3b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "whisper-base": "repro.configs.whisper_base",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.REDUCED if reduced else mod.CONFIG
