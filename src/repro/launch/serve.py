"""Serving launcher: --arch <id> batched generation via the continuous-
batching engine.

    python -m repro.launch.serve --arch qwen2-1.5b --reduced --requests 8
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=args.slots, max_seq=args.max_seq)
    print(f"[serve] arch={cfg.name} slots={args.slots} max_seq={args.max_seq}")

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(3, cfg.vocab, size=int(rng.integers(2, 10)))
        engine.submit(Request(rid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new_tokens))
    finished = engine.run_until_done()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in finished.values())
    print(f"[serve] {len(finished)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on this host)")
    for rid in sorted(finished):
        print(f"  req {rid}: {finished[rid][:8].tolist()}…")


if __name__ == "__main__":
    main()
