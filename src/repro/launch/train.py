"""Production training launcher: --arch <id> on the active mesh.

On a real pod this is the multi-host entry (jax.distributed.initialize is
invoked when coordinator env vars are present); on a dev box it runs the
same code path on whatever devices exist.

    python -m repro.launch.train --arch qwen2-1.5b --reduced --steps 20
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_launch")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm grid like 4x2 (data x model)")
    args = ap.parse_args()

    if "COORDINATOR_ADDRESS" in os.environ:  # multi-host pod entry
        import jax
        jax.distributed.initialize()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.sharding.specs import Sharding
    from repro.train import optimizer as opt_mod
    from repro.train.checkpoint import CheckpointManager
    from repro.train.loop import run_training
    from repro.train.train_step import (
        TrainConfig, init_train_state, jit_train_step, train_state_specs,
    )

    n_dev = len(jax.devices())
    if args.mesh == "auto":
        dm = (n_dev, 1)
    else:
        dm = tuple(int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh(dm, ("data", "model"))
    sh = Sharding(dp=("data",), tp="model", enabled=True)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, sh=sh)
    print(f"[train] arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"mesh={dm} devices={n_dev}")

    ocfg = opt_mod.OptimizerConfig(name=args.optimizer, total_steps=args.steps)
    opt = opt_mod.make_optimizer(ocfg)
    tc = TrainConfig(optimizer=ocfg, microbatches=args.microbatches)
    batch_specs = {"tokens": P(("data",), None), "labels": P(("data",), None)}
    if cfg.n_patches:
        batch_specs["patches"] = P(("data",), None, None)
    if cfg.is_encoder_decoder:
        batch_specs["frames"] = P(("data",), None, None)

    with mesh:
        step_fn = jit_train_step(model, opt, tc, mesh, batch_specs)
        state = init_train_state(model, jax.random.PRNGKey(0), opt)
        # place state according to the specs
        to_sh = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        specs = to_sh(train_state_specs(model, ocfg))
        state = jax.tree.map(jax.device_put, state, specs)

        rng = np.random.default_rng(0)

        def data_factory(start):
            def gen():
                while True:
                    toks = rng.integers(0, cfg.vocab, (args.batch, args.seq_len + 1))
                    batch = {
                        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
                    }
                    if cfg.n_patches:
                        batch["patches"] = jnp.zeros(
                            (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
                    if cfg.is_encoder_decoder:
                        batch["frames"] = jnp.zeros(
                            (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
                    yield batch
            return gen()

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        run_training(step_fn, state, data_factory, total_steps=args.steps,
                     ckpt=ckpt, ckpt_every=args.ckpt_every)


if __name__ == "__main__":
    main()
