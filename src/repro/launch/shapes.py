"""input_specs(): ShapeDtypeStruct stand-ins + PartitionSpecs for every
(arch × shape) dry-run cell.  No device allocation anywhere.

Sharding layout decisions (DESIGN.md §4):
  * train/prefill: batch over the data axes, activations TP via constraints;
  * decode_32k:    batch over data axes, KV-cache sequence over "model"
    (flash-decoding-style sequence sharding — KV head counts (2–8) don't
    divide the 16-way TP axis, sequence always does);
  * long_500k:     global_batch=1 → KV/conv caches shard sequence over
    ("data","model") (524288/256 = 2048 per chip); SSM state over heads.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.model import Model
from repro.models.ssm import SSMCache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, dp) -> Tuple[Dict, Dict]:
    b, s = shape.global_batch, shape.seq_len
    structs = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.n_patches:
        structs["patches"] = sds((b, cfg.n_patches, cfg.d_model), jnp.float32)
        specs["patches"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        structs["frames"] = sds((b, cfg.enc_frames, cfg.d_model), jnp.float32)
        specs["frames"] = P(dp, None, None)
    return structs, specs


def decode_specs(model: Model, shape: ShapeConfig, dp) -> Tuple[Tuple, Tuple]:
    """(structs, specs) for (token, DecodeState)."""
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    state = jax.eval_shape(lambda: T.init_decode_state(cfg, b, s))
    if shape.name == "long_500k":
        seq_axis = ("data", "model") if b == 1 else "model"
        batch_axis = None if b == 1 else dp
    else:
        seq_axis = "model"
        batch_axis = dp
    kv_spec = P(None, batch_axis, seq_axis, None, None)
    specs = T.DecodeState(
        kv={"k": kv_spec, "v": kv_spec} if state.kv is not None else None,
        ssm=(
            SSMCache(
                P(None, batch_axis, None, "model"),
                P(None, batch_axis, "model", None, None),
            )
            if state.ssm is not None else None
        ),
        cross_kv=(
            {"k": P(None, batch_axis, None, None, None),
             "v": P(None, batch_axis, None, None, None)}
            if state.cross_kv is not None else None
        ),
        length=P(batch_axis),
    )
    token = sds((b,), jnp.int32)
    token_spec = P(batch_axis)
    return (token, state), (token_spec, specs)
