import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init), which is why this module sets XLA_FLAGS at the very
top and why nothing else in the repo sets it globally.

For each cell this proves, without touching real hardware:
  * the sharding config is coherent (lower succeeds, no sharding conflicts),
  * the collective schedule exists (parsed from the compiled HLO),
  * per-device memory is known (``compiled.memory_analysis()``),
  * FLOPs/bytes are known (``compiled.cost_analysis()``; see
    repro/roofline for the scan-aware differential accounting).

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 4]

Results accumulate in results/dryrun/<cell>.json (idempotent; --force to
redo).
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from collections import Counter

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the HLO text.

    Ops inside ``while`` bodies appear once; the roofline layer multiplies
    per-layer contributions via L=1/L=2 differencing (DESIGN.md §6).
    """
    totals = Counter()
    counts = Counter()
    # e.g.:  %ag = bf16[2,1024,128]{2,1,0} all-gather(%x), replica_groups=...
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f"{c}-start(" in line:
                op = c
                break
        if op is None:
            continue
        m = shape_re.search(line.split("=", 1)[1] if "=" in line else line)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        if dt == "tuple" or dt not in _DTYPE_BYTES:
            # tuple shapes: sum every element shape on the line
            nbytes = 0
            for mm in shape_re.finditer(line):
                if mm.group(1) in _DTYPE_BYTES:
                    n = 1
                    for d in mm.group(2).split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[mm.group(1)]
                    break  # first shape = output
        else:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes = n * _DTYPE_BYTES[dt]
        totals[op] += nbytes
        counts[op] += 1
    return dict(totals), dict(counts)


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or ():
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool, n_layers=None,
               overrides=None, tag="", microbatches=1):
    """Lower + compile one cell; returns a JSON-able result dict."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch import shapes as shp
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import build_model
    from repro.sharding.specs import multi_pod as sh_multi, single_pod as sh_single
    from repro.train import optimizer as opt_mod
    from repro.train import train_step as ts

    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    if n_layers is not None:
        import dataclasses
        # Roofline differential probes: UNROLL the stack (scan_layers=False).
        # XLA's cost analysis counts a while-loop body once regardless of
        # trip count, so scanned L=1/L=2 probes would difference to ~zero;
        # unrolled bodies are counted per layer (DESIGN.md §6).
        overrides = {"n_layers": n_layers, "scan_layers": False}
        if cfg.first_k_dense and n_layers <= cfg.first_k_dense:
            overrides["first_k_dense"] = 0
        if cfg.is_encoder_decoder:
            overrides["n_enc_layers"] = n_layers
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = sh_multi() if multi_pod else sh_single()
    model = build_model(cfg, sh=sh)
    dp = sh.dp

    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    pspecs = to_sh(model.param_specs())
    params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            structs, bspecs = shp.train_batch_specs(cfg, shape, dp)
            opt_cfg = opt_mod.OptimizerConfig(
                name="adafactor" if cfg.moe_fsdp else "adamw"
            )
            opt = opt_mod.make_optimizer(opt_cfg)
            tc = ts.TrainConfig(optimizer=opt_cfg, microbatches=microbatches)
            step_fn = ts.make_train_step(model, opt, tc, mesh)
            state_specs = to_sh(ts.train_state_specs(model, opt_cfg))
            state_struct = jax.eval_shape(
                lambda p: ts.TrainState(p, opt.init(p), jax.numpy.zeros((), jax.numpy.int32)),
                params_struct,
            )
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_specs, to_sh(bspecs)),
                out_shardings=(state_specs, None),
                donate_argnums=(0,),
            ).lower(state_struct, structs)
        elif shape.kind == "prefill":
            structs, bspecs = shp.train_batch_specs(cfg, shape, dp)
            structs = {k: v for k, v in structs.items() if k != "labels"}
            bspecs = {k: v for k, v in bspecs.items() if k != "labels"}

            def prefill(params, batch):
                logits, _ = model.forward(params, batch, mesh=mesh)
                return logits

            lowered = jax.jit(
                prefill, in_shardings=(pspecs, to_sh(bspecs)),
            ).lower(params_struct, structs)
        else:  # decode
            (token, state), (tspec, sspecs) = shp.decode_specs(model, shape, dp)

            def serve_step(params, tok, st):
                return model.decode_step(params, tok, st, mesh=mesh)

            lowered = jax.jit(
                serve_step,
                in_shardings=(pspecs, to_sh(tspec), to_sh(sspecs)),
                out_shardings=(None, to_sh(sspecs)),
                donate_argnums=(2,),
            ).lower(params_struct, token, state)
    lower_s = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t1

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    coll_bytes, coll_counts = parse_collective_bytes(compiled.as_text())
    n_dev = 512 if multi_pod else 256
    return {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_layers": cfg.n_layers,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "devices": n_dev,
    }


def build_parser_cell(mib_per_device: int, multi_pod: bool,
                      chunk_bytes: int = 64, use_matmul: bool = False,
                      partition_impl: str = "scatter"):
    """Lower + compile the distributed ParPaRaw parse itself on the
    production mesh — the paper's technique as its own roofline cell."""
    import jax
    import jax.numpy as jnp

    from repro.core import ParserConfig, Schema, make_csv_dfa
    from repro.core.distributed import DistributedParser
    from repro.data import synth
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_dev = 512 if multi_pod else 256
    bytes_per_dev = mib_per_device << 20
    n_chunks = bytes_per_dev // chunk_bytes * n_dev
    max_records = max(1024, bytes_per_dev // 512)  # ~720 B/record yelp-like

    cfg = ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.YELP_SCHEMA),
        max_records=max_records, chunk_size=chunk_bytes,
        use_matmul_scan=use_matmul, partition_impl=partition_impl,
    )
    t0 = time.time()
    # Index-only export: the roofline cell isolates the paper's scan and
    # partition collectives; the converted path (convert=True, the driver
    # default) is exercised by the distributed tests and bench workload.
    dp = DistributedParser(cfg, mesh, axis_names=axes, convert=False)
    lowered = dp.lower(n_chunks, chunk_bytes)
    lower_s = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t1
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    coll_bytes, coll_counts = parse_collective_bytes(compiled.as_text())
    return {
        "status": "ok",
        "arch": "parparaw-parser",
        "shape": f"parse_{mib_per_device}mib"
                 + (f"_c{chunk_bytes}" if chunk_bytes != 64 else "")
                 + ("_mm" if use_matmul else "")
                 + (f"_{partition_impl}" if partition_impl != "scatter" else ""),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "input_bytes_per_device": bytes_per_dev,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "devices": n_dev,
    }


def run_cell(arch, shape_name, mesh_kind, n_layers=None, overrides=None,
             microbatches=1):
    try:
        return build_cell(arch, shape_name, mesh_kind == "multi", n_layers,
                          overrides=overrides, microbatches=microbatches)
    except Exception as e:  # noqa: BLE001 — a failing cell is a result
        return {
            "status": "error",
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }


def cell_path(arch, shape, mesh_kind, n_layers=None):
    sfx = f"_L{n_layers}" if n_layers else ""
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.join(RESULTS_DIR, f"{safe}__{shape}__{mesh_kind}{sfx}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count (roofline differential probes)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--parser-mib", type=int, default=None,
                    help="build the distributed-parser cell (MiB/device)")
    ap.add_argument("--parser-chunk", type=int, default=64)
    ap.add_argument("--parser-matmul", action="store_true")
    ap.add_argument("--parser-partition", default="scatter")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides key=value (perf iterations)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.parser_mib is not None:
        for mk in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
            out = build_parser_cell(
                args.parser_mib, mk == "multi", chunk_bytes=args.parser_chunk,
                use_matmul=args.parser_matmul,
                partition_impl=args.parser_partition,
            )
            path = cell_path("parparaw-parser", out["shape"], mk)
            with open(path, "w") as f:
                json.dump(out, f, indent=1)
            print(json.dumps(out, indent=1))
        return

    if not args.all:
        assert args.arch and args.shape
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mk in meshes:
            out = run_cell(args.arch, args.shape, mk, args.layers,
                           overrides=_parse_overrides(args.set),
                           microbatches=args.microbatches)
            path = cell_path(args.arch + args.tag, args.shape, mk, args.layers)
            with open(path, "w") as f:
                json.dump(out, f, indent=1)
            brief = {k: v for k, v in out.items() if k not in ("trace",)}
            print(json.dumps(brief, indent=1))
            if out["status"] == "ok":
                print(f"[dryrun] {args.arch} × {args.shape} × {mk}: "
                      f"compile {out['compile_s']}s, "
                      f"temp {out['memory']['temp_bytes']/2**30:.2f} GiB/device")
        return

    # --all: fan out one subprocess per cell (isolated device state, parallel)
    from repro.configs import ARCH_IDS, SHAPES
    jobs = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mk in meshes:
                variants = [None]
                if mk == "single":
                    variants += [1, 2]  # roofline differential probes
                for nl in variants:
                    path = cell_path(arch, shape, mk, nl)
                    if os.path.exists(path) and not args.force:
                        continue
                    jobs.append((arch, shape, mk, nl))
    print(f"[dryrun] {len(jobs)} cells to build")
    running = []
    while jobs or running:
        while jobs and len(running) < args.jobs:
            arch, shape, mk, nl = jobs.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk]
            if nl is not None:
                cmd += ["--layers", str(nl)]
            env = dict(os.environ)
            running.append(((arch, shape, mk, nl), subprocess.Popen(
                cmd, env=env, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)))
        done = [r for r in running if r[1].poll() is not None]
        for (key, proc) in done:
            running.remove((key, proc))
            status = "ok" if proc.returncode == 0 else f"rc={proc.returncode}"
            print(f"[dryrun] finished {key}: {status}", flush=True)
        time.sleep(1.0)


if __name__ == "__main__":
    main()
