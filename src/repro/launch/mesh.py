"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py fakes
512 hosts).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Whatever devices exist, arranged for tests (e.g. (4,2) under the
    8-device subprocess override)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)
