"""Multi-tenant parse service — a long-lived serving layer over
:class:`~repro.core.streaming.StreamSession` (ROADMAP's "millions of
users" axis, in the shape of an inference serving stack: a driver in
front of a registry of compiled executables).

Design:

* **Registry sharing** — tenants are grouped by
  :func:`repro.core.stages.plan_key` + session geometry; every group
  shares one compiled :class:`Parser` and one :class:`StreamSession` per
  batch width (:class:`~repro.serve.registry.PlanRegistry`).

* **Admission / tier batching** — the dispatcher packs waiting tenants of
  one group into the vmapped ``n_streams`` axis.  The batch width is the
  smallest *recompile tier* (default S∈{1,4,16,64}) that fits the group,
  so the service compiles a handful of step widths total instead of one
  per tenant count; spare lanes run inert (empty sources).  A group whose
  session is mid-batch waits — new tenants are admitted onto the same
  session (and the failed tenants' lanes) as soon as it frees.

* **Thread/queue front end** — ingest, dispatch, and fetch overlap:
  each batch runs on a worker thread driving the session's own
  dispatch-ahead loop; per-tenant results flow through bounded queues
  (``queue.Queue(maxsize=...)``) whose blocking ``put`` is the
  backpressure — a slow consumer stalls its producer, bytes and results
  are never dropped.  Push-model tenants feed a :class:`ByteQueue`
  (bounded the same way, toward the producer) instead of a pull iterable.

* **Fault isolation** — the engine contract
  (:class:`~repro.core.streaming.StreamOverflow`, see
  ``core/streaming.py``) guarantees an overflowing lane fails alone; the
  service maps that lane fault onto the owning tenant's channel as a
  :class:`TenantOverflow` and every other tenant of the batch completes
  untouched.  No exception crosses tenant boundaries; engine-level faults
  outside the per-lane contract surface as :class:`TenantError` on every
  unfinished tenant of the batch and the session is reset.

Synchronous mode (``start=False`` + :meth:`ParseService.step`) runs one
admission decision per call on the caller's thread — what the tests use
to pin scheduling deterministically.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.parser import ParseResult
from repro.core.streaming import StreamOverflow, StreamStats
from repro.serve.registry import PlanRegistry

_EOS = object()


@dataclasses.dataclass
class TenantResult:
    """One parsed partition on a tenant's channel (records ``[0, n)``)."""
    tenant: str
    result: ParseResult
    n_records: int


@dataclasses.dataclass
class TenantOverflow:
    """The tenant's record exceeded its session capacity: the tenant is
    failed and its lane retired for the batch — other tenants continue."""
    tenant: str
    error: StreamOverflow


@dataclasses.dataclass
class TenantError:
    """An engine fault outside the per-lane overflow contract aborted the
    tenant's batch (the session was reset; other *batches* continue)."""
    tenant: str
    error: BaseException


class ByteQueue:
    """Bounded push-model ingest source.

    Producers :meth:`write` byte chunks and :meth:`close`; the parsing
    side iterates.  ``write`` blocks while the queue holds ``max_chunks``
    undelivered chunks — backpressure to the producer; nothing is ever
    dropped.
    """

    def __init__(self, max_chunks: int = 16):
        self._q: "queue.Queue" = queue.Queue(maxsize=int(max_chunks))
        self._closed = False

    def write(self, data: bytes) -> None:
        if self._closed:
            raise ValueError("write to closed ByteQueue")
        self._q.put(bytes(data))

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._q.put(_EOS)

    def __iter__(self) -> Iterator[bytes]:
        while True:
            item = self._q.get()
            if item is _EOS:
                return
            yield item


class Tenant:
    """Per-tenant handle: result channel + finalized stats.

    ``results()`` yields :class:`TenantResult` / :class:`TenantOverflow` /
    :class:`TenantError` in partition order and returns when the tenant's
    stream completes (or fails); ``wait()`` blocks until then and returns
    the tenant's :class:`StreamStats` for its batch.  The channel is a
    bounded queue: a consumer that stops reading stalls the service's
    worker on this tenant's lane results (backpressure, never drops).
    """

    def __init__(self, name: str, cfg, source, partition_bytes: int,
                 max_carry_bytes: int, max_queued: int):
        self.name = name
        self.cfg = cfg
        self.source = source
        self.partition_bytes = int(partition_bytes)
        self.max_carry_bytes = int(max_carry_bytes)
        self.group: Tuple = ()          # (plan_key, geometry) — set at submit
        self.lane: Optional[int] = None          # lane of the batch it ran in
        self.session_key: Optional[Tuple] = None  # registry key of that session
        self.stats: Optional[StreamStats] = None  # finalized per-batch stats
        self.failed = False
        self.submitted = 0.0            # monotonic admission timestamp
        self._q: "queue.Queue" = queue.Queue(maxsize=int(max_queued))
        self._done = threading.Event()

    def results(self) -> Iterator[Union[TenantResult, TenantOverflow, TenantError]]:
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._done.is_set():
                    return
                continue
            yield item

    def wait(self, timeout: Optional[float] = None) -> StreamStats:
        if not self._done.wait(timeout):
            raise TimeoutError(f"tenant {self.name!r} not done after {timeout}s")
        assert self.stats is not None
        return self.stats

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def _finish(self, stats: StreamStats) -> None:
        self.stats = stats
        self.failed = self.failed or stats.failed
        self._done.set()  # results() drains the queue, then terminates


class ParseService:
    """The multi-tenant parse service (see module docstring).

    Args:
      tiers: allowed batch widths (``n_streams``), ascending.  A batch of
        *n* compatible tenants runs at the smallest tier ≥ *n* (groups
        larger than the top tier split across batches).  ``None`` (the
        default) resolves the ladder *per tenant group* from the autotuner
        cache (``PlanRegistry.tuned_tiers`` — widths whose measured
        aggregate throughput paid for their compile, per
        ``repro.tune.tuner.tune_stream``), with ``DEFAULT_TIERS`` as the
        cold-cache fallback.  An explicit ladder disables cache resolution
        entirely (explicit knob > cache > heuristic default).
      max_queued_partitions: per-tenant result-channel bound (the
        backpressure depth).
      admission_wait: how long the dispatcher holds a group open for
        late-arriving compatible tenants before launching its batch.
      mesh: optional device mesh — every batch's session lane-shards its
        ``n_streams`` axis over ``mesh_axis`` (see
        :class:`~repro.core.streaming.StreamSession`), spreading tenant
        lanes across devices with per-lane fault isolation unchanged.
        Tiers are filtered to multiples of the axis size so every batch
        width shards evenly; raises if no tier survives.
      mesh_axis: the mesh axis tenant lanes shard over.
      start: spawn the dispatcher thread.  ``start=False`` gives the
        synchronous test mode — call :meth:`step` to run one admission
        decision (and its whole batch) on the calling thread.
    """

    DEFAULT_TIERS = (1, 4, 16, 64)

    def __init__(self, *, tiers: Optional[Sequence[int]] = None,
                 max_queued_partitions: int = 8,
                 admission_wait: float = 0.02,
                 mesh=None, mesh_axis: str = "streams",
                 start: bool = True):
        self._tuned_tiers = tiers is None
        self.tiers = tuple(sorted(
            int(t) for t in (self.DEFAULT_TIERS if tiers is None else tiers)))
        if not self.tiers or self.tiers[0] < 1:
            raise ValueError(f"tiers must be positive, got {tiers}")
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None:
            if mesh_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no axis {mesh_axis!r}: {mesh.axis_names}")
            d = int(mesh.shape[mesh_axis])
            kept = tuple(t for t in self.tiers if t % d == 0)
            if not kept:
                raise ValueError(
                    f"no tier in {self.tiers} divisible by mesh axis "
                    f"{mesh_axis!r} size {d}")
            self.tiers = kept
        # per-group measured ladders (tiers=None mode), resolved at submit
        self._group_tiers: Dict[Tuple, Tuple[int, ...]] = {}
        self.max_queued_partitions = int(max_queued_partitions)
        self.admission_wait = float(admission_wait)
        self.registry = PlanRegistry()
        self._cv = threading.Condition()
        self._pending: List[Tenant] = []
        self._busy: set = set()          # groups with a batch in flight
        self._workers: List[threading.Thread] = []
        self._closed = False
        self._seq = itertools.count()
        self._dispatcher: Optional[threading.Thread] = None
        if start:
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="parse-service-dispatch",
                daemon=True)
            self._dispatcher.start()

    # -- front door ----------------------------------------------------------
    def submit(self, cfg, source, *, partition_bytes: int,
               max_carry_bytes: Optional[int] = None,
               name: Optional[str] = None) -> Tenant:
        """Admit a tenant: parse ``source`` (an iterable of byte chunks, a
        :class:`ByteQueue`, or plain ``bytes``) under ``cfg`` in
        ``partition_bytes`` takes.  Returns the tenant's handle
        immediately; results stream on its channel."""
        if isinstance(source, (bytes, bytearray, memoryview)):
            source = [bytes(source)]
        t = Tenant(
            name or f"tenant-{next(self._seq)}", cfg, source,
            partition_bytes, max_carry_bytes or partition_bytes,
            self.max_queued_partitions,
        )
        # Resolved at submit so an invalid config fails the caller here,
        # not a worker thread later.
        t.group = (self.registry.key(cfg), t.partition_bytes, t.max_carry_bytes)
        if self._tuned_tiers and t.group not in self._group_tiers:
            # tiers=None mode: this group's measured ladder from the
            # autotuner cache (mesh-filtered like the default ladder;
            # cold cache → the default ladder unchanged)
            ladder = self.registry.tuned_tiers(cfg, self.tiers)
            if self.mesh is not None:
                d = int(self.mesh.shape[self.mesh_axis])
                ladder = tuple(s for s in ladder if s % d == 0) or self.tiers
            self._group_tiers[t.group] = tuple(sorted(ladder))
        with self._cv:
            if self._closed:
                raise RuntimeError("ParseService is closed")
            t.submitted = time.monotonic()
            self._pending.append(t)
            self._cv.notify_all()
        return t

    def group_tiers(self, group: Tuple) -> Tuple[int, ...]:
        """The tier ladder serving ``group``: its measured per-group ladder
        in ``tiers=None`` mode, else the service-wide one."""
        return self._group_tiers.get(group, self.tiers)

    def tier_for(self, n: int, group: Optional[Tuple] = None) -> int:
        """Smallest tier ≥ n (the top tier for oversized groups)."""
        for t in self.group_tiers(group) if group is not None else self.tiers:
            if t >= n:
                return t
        return self.group_tiers(group)[-1] if group is not None else self.tiers[-1]

    # -- scheduling ----------------------------------------------------------
    def _take_batch_locked(self, flush: bool = False):
        """One admission decision (holding ``_cv``): the oldest pending
        group whose session is free and whose admission window has
        elapsed (or that already fills the top tier).  Returns
        ``(group, batch)`` or ``None``."""
        now = time.monotonic()
        seen = set()
        for t in self._pending:
            g = t.group
            if g in seen:
                continue
            seen.add(g)
            if g in self._busy:
                continue
            members = [u for u in self._pending if u.group == g]
            top = self.group_tiers(g)[-1]
            ready = (flush or self._closed
                     or len(members) >= top
                     or now - members[0].submitted >= self.admission_wait)
            if not ready:
                continue
            batch = members[:top]
            for u in batch:
                self._pending.remove(u)
            self._busy.add(g)
            return g, batch
        return None

    def step(self) -> Optional[List[Tenant]]:
        """Synchronous mode: run one admission decision and its whole
        batch on the calling thread.  Returns the tenants served, or
        ``None`` if nothing was eligible.

        The batch's result channels are unbounded for the call: with no
        concurrent consumer, a bounded ``put`` would deadlock the calling
        thread — backpressure is a property of the threaded front end.
        """
        with self._cv:
            picked = self._take_batch_locked(flush=True)
        if picked is None:
            return None
        group, batch = picked
        for t in batch:
            t._q.maxsize = 0
        self._run_batch(group, batch)
        return batch

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    picked = self._take_batch_locked()
                    if picked is not None:
                        break
                    if self._closed and not self._pending and not self._busy:
                        return
                    self._cv.wait(timeout=0.05)
            group, batch = picked
            w = threading.Thread(
                target=self._run_batch, args=(group, batch),
                name=f"parse-service-batch-{batch[0].name}", daemon=True)
            self._workers.append(w)
            w.start()

    # -- batch execution -----------------------------------------------------
    def _run_batch(self, group: Tuple, batch: List[Tenant]) -> None:
        key, partition_bytes, max_carry_bytes = group
        tier = self.tier_for(len(batch), group)
        skey, session = self.registry.session(
            batch[0].cfg, partition_bytes, max_carry_bytes, tier, key=key,
            mesh=self.mesh, mesh_axis=self.mesh_axis)
        for lane, t in enumerate(batch):
            t.lane, t.session_key = lane, skey
        # Spare lanes run inert: empty source → one empty flush round.
        sources = [t.source for t in batch] + [()] * (tier - len(batch))
        finished = [False] * len(batch)
        gen = session.parse_streams(sources)
        try:
            for lane, result, n in gen:
                if lane >= len(batch):
                    continue
                t = batch[lane]
                if isinstance(result, StreamOverflow):
                    # Per-lane fault → this tenant's channel only; the
                    # session keeps every other lane running.
                    t.failed = True
                    t._q.put(TenantOverflow(t.name, result))
                else:
                    t._q.put(TenantResult(t.name, result, n))
            for lane, t in enumerate(batch):
                t._finish(dataclasses.replace(session.call_stats[lane]))
                finished[lane] = True
        except BaseException as e:
            # Outside the per-lane contract (bad source iterable, engine
            # bug, ...): fail the batch's unfinished tenants, settle the
            # session for the next batch, keep the service alive.
            for lane, t in enumerate(batch):
                if not finished[lane]:
                    t.failed = True
                    t._q.put(TenantError(t.name, e))
                    t._finish(StreamStats(failed=True))
            gen.close()
            session.reset()
        finally:
            with self._cv:
                self._busy.discard(group)
                self._cv.notify_all()

    # -- lifecycle -----------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Stop admissions; with ``wait`` drain pending/in-flight batches."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            if self._dispatcher is not None:
                self._dispatcher.join()
            for w in self._workers:
                w.join()

    def __enter__(self) -> "ParseService":
        return self

    def __exit__(self, *exc) -> None:
        self.close(wait=True)
