"""Batched serving engine: continuous-batching prefill + decode.

Host-side driver around the model's prefill (forward) and decode_step:
  * requests are admitted into fixed decode slots (static shapes — one
    compiled decode executable);
  * prefill runs per-request (right-padded to the prefill bucket), its KV
    cache scatter-inserted into the batch cache at the request's slot;
  * every engine tick decodes one token for all live slots, retiring
    finished requests and admitting queued ones (continuous batching).

This is the serving analogue of the paper's streaming parser: fixed device
buffers, host-driven admission, and async dispatch keeping the device busy.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4, max_seq: int = 256):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.state = model.init_decode_state(slots, max_seq)
        self.live: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free = deque(range(slots))
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(model.decode_step)
        self._next_tok = np.zeros(slots, np.int32)
        self.finished: Dict[int, np.ndarray] = {}

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        req.generated = []
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.popleft()
            self.live[req.rid] = req
            self.slot_of[req.rid] = slot
            # reclaim the slot: per-slot position back to zero
            self.state = self.state._replace(
                length=self.state.length.at[slot].set(0)
            )
            # per-slot "prefill": teacher-force the prompt with only this
            # slot active (other slots' positions and SSM states are masked)
            for tok in req.prompt[:-1]:
                self._step_slot(slot, int(tok))
            self._next_tok[slot] = int(req.prompt[-1])

    def _step_slot(self, slot, token):
        toks = self._next_tok.copy()
        toks[slot] = token
        active = np.zeros(self.slots, bool)
        active[slot] = True
        logits, self.state = self._decode(
            self.params, jnp.asarray(toks), self.state, active=jnp.asarray(active)
        )
        return logits

    # -- decode tick ----------------------------------------------------------
    def tick(self) -> int:
        """One decode step for all live slots; returns #tokens produced."""
        self._admit()
        if not self.live:
            return 0
        active = np.zeros(self.slots, bool)
        for rid in self.live:
            active[self.slot_of[rid]] = True
        logits, self.state = self._decode(
            self.params, jnp.asarray(self._next_tok), self.state,
            active=jnp.asarray(active),
        )
        chosen = np.asarray(jnp.argmax(logits, axis=-1))
        produced = 0
        for rid in list(self.live):
            slot = self.slot_of[rid]
            req = self.live[rid]
            tok = int(chosen[slot])
            req.generated.append(tok)
            produced += 1
            done = (req.eos_id is not None and tok == req.eos_id) or \
                len(req.generated) >= req.max_new_tokens
            if done:
                self.finished[rid] = np.asarray(req.generated, np.int32)
                del self.live[rid]
                self.free.append(slot)
                del self.slot_of[rid]
            else:
                self._next_tok[slot] = tok
        return produced

    def run_until_done(self, max_ticks: int = 10000):
        ticks = 0
        while (self.live or self.queue) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
