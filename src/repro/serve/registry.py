"""Executable registry for the multi-tenant parse service.

Tenants hand the service a :class:`~repro.core.parser.ParserConfig` each;
compiling one parser (and one streaming session per batch width) per
*tenant* would make admission O(compile).  The registry instead keys
everything on :func:`repro.core.stages.plan_key` — the conservative
fingerprint of the executable a config traces to — so tenants with
compatible schemas share ONE compiled :class:`Parser`, and sessions are
additionally keyed on their static geometry ``(partition_bytes,
max_carry_bytes, n_streams)``.  With the service's recompile tiers
(``n_streams`` drawn from S∈{1,4,16,64} instead of the exact tenant
count) the steady state compiles a handful of executables total, however
many tenants pass through.

Thread-safe: the service's dispatcher and worker threads share one
registry.  ``parser_builds`` / ``session_builds`` count cache misses —
tests pin tier/recompile behaviour on them (alongside jit's own
``_cache_size``).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core import backends as backends_mod
from repro.core import stages as stages_mod
from repro.core.parser import Parser
from repro.core.streaming import StreamSession


def mesh_key(mesh) -> Optional[Tuple]:
    """Hashable identity of a device mesh for session cache keys: two
    meshes over the same axes and the same devices in the same order
    share sessions; ``None`` (single-device) is its own key."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


class PlanRegistry:
    """Plan-keyed cache of compiled :class:`Parser`\\ s and
    :class:`StreamSession`\\ s (see module docstring)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._parsers: Dict[Tuple, Parser] = {}
        self._sessions: Dict[Tuple, StreamSession] = {}
        self.parser_builds = 0
        self.session_builds = 0

    def key(self, cfg) -> Tuple:
        """The sharing key for ``cfg`` (see ``stages.plan_key``)."""
        return stages_mod.plan_key(
            cfg, backends_mod.get_backend(cfg.backend))

    def tuned_tiers(self, cfg, default: Tuple[int, ...]) -> Tuple[int, ...]:
        """The measured recompile-tier ladder for ``cfg``'s workload from
        the autotuner cache (``repro.tune``), or ``default`` when no entry
        exists — the service's per-group tier source when constructed
        without an explicit ladder (``ParseService(tiers=None)``).  Tuned
        ladders drop batch widths whose measured aggregate throughput does
        not pay for their compile (``tuner.tune_stream``)."""
        from repro.tune import resolve as tune_resolve

        return tune_resolve.tuned_serve_tiers(cfg, tuple(default))

    def parser(self, cfg, key: Optional[Tuple] = None) -> Tuple[Tuple, Parser]:
        """The shared parser for ``cfg``'s plan key (built on first use)."""
        k = key if key is not None else self.key(cfg)
        with self._lock:
            p = self._parsers.get(k)
            if p is None:
                p = Parser(cfg)
                self._parsers[k] = p
                self.parser_builds += 1
        return k, p

    def session(self, cfg, partition_bytes: int, max_carry_bytes: int,
                n_streams: int, key: Optional[Tuple] = None,
                mesh=None, mesh_axis: str = "streams",
                ) -> Tuple[Tuple, StreamSession]:
        """The shared session for ``cfg``'s plan key at this geometry.

        One session per ``(plan_key, partition_bytes, max_carry_bytes,
        n_streams, mesh_key)`` — its jitted step (and the step's jit
        cache) is reused across every batch the service runs at that
        width.  With ``mesh``, the session's lanes are sharded over
        ``mesh_axis`` (``n_streams`` must divide by its size — the
        service's tier filter guarantees that).
        """
        k, parser = self.parser(cfg, key)
        sk = (k, int(partition_bytes), int(max_carry_bytes), int(n_streams),
              mesh_key(mesh))
        with self._lock:
            s = self._sessions.get(sk)
            if s is None:
                s = StreamSession(
                    parser, partition_bytes,
                    max_carry_bytes=max_carry_bytes, n_streams=n_streams,
                    mesh=mesh, mesh_axis=mesh_axis,
                )
                self._sessions[sk] = s
                self.session_builds += 1
        return sk, s
