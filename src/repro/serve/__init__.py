"""Multi-tenant parse service over the streaming engine (ROADMAP item 2).

``PlanRegistry`` shares compiled executables among tenants with equal plan
keys; ``ParseService`` is the long-lived front end — admission/batching
into the vmapped stream axis with recompile tiers, bounded-queue
backpressure, per-tenant stats, and per-tenant fault isolation.
"""
from repro.serve.registry import PlanRegistry
from repro.serve.service import (
    ByteQueue,
    ParseService,
    Tenant,
    TenantError,
    TenantOverflow,
    TenantResult,
)

__all__ = [
    "ByteQueue",
    "ParseService",
    "PlanRegistry",
    "Tenant",
    "TenantError",
    "TenantOverflow",
    "TenantResult",
]
