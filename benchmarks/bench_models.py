"""Model-side micro-benchmarks: reduced-config train-step and decode-step
wall-clock per architecture (CPU host numbers — the TPU projection lives in
the roofline table)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn


def train_and_decode_steps():
    from repro.configs import ARCH_IDS, get_config
    from repro.models.model import build_model
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import TrainConfig, init_train_state, make_train_step

    rng = np.random.default_rng(0)
    for arch in ARCH_IDS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        ocfg = opt_mod.OptimizerConfig()
        opt = opt_mod.make_optimizer(ocfg)
        state = init_train_state(model, jax.random.PRNGKey(0), opt)
        b, s = 2, 32
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        }
        if cfg.n_patches:
            batch["patches"] = jnp.zeros((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((b, cfg.enc_frames, cfg.d_model), jnp.float32)
        step = jax.jit(make_train_step(model, opt, TrainConfig(optimizer=ocfg)))

        def run_train(st, bt):
            _, metrics = step(st, bt)
            return metrics["loss"]

        dt, _ = time_fn(run_train, state, batch, warmup=1, iters=3)
        emit(f"model/{arch}/train_step_reduced", dt * 1e6, f"b{b}s{s}")

        dstate = model.init_decode_state(b, max_seq=64)
        dstep = jax.jit(model.decode_step)
        tok = jnp.zeros((b,), jnp.int32)

        def run_decode(t, st):
            logits, _ = dstep(state.params, t, st)
            return logits

        dt, _ = time_fn(run_decode, tok, dstate, warmup=1, iters=3)
        emit(f"model/{arch}/decode_step_reduced", dt * 1e6, f"b{b}")


def run():
    train_and_decode_steps()
