"""Shared benchmark utilities: timing, CSV-line output protocol."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.data import synth

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def yelp_parser(chunk_size=64, max_records=1 << 15, **kw) -> Parser:
    return Parser(ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.YELP_SCHEMA),
        max_records=max_records, chunk_size=chunk_size, **kw,
    ))


def taxi_parser(chunk_size=64, max_records=1 << 14, **kw) -> Parser:
    return Parser(ParserConfig(
        dfa=make_csv_dfa(), schema=Schema.of(*synth.TAXI_SCHEMA),
        max_records=max_records, chunk_size=chunk_size, **kw,
    ))


def dataset(kind: str, n_records: int, seed=0) -> bytes:
    rng = np.random.default_rng(seed)
    if kind == "yelp":
        return synth.yelp_like(rng, n_records)
    if kind == "taxi":
        return synth.taxi_like(rng, n_records)
    if kind == "skewed":
        return synth.skewed(rng, n_records)
    raise ValueError(kind)


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / seconds / 1e9
