"""Micro-benchmarks of ParPaRaw's algorithmic alternatives (the §Perf
hypothesis material):

  * composite-scan operator: gather (VPU) vs one-hot matmul (MXU)
  * partition: counting-scatter (single radix pass) vs stable argsort
  * numeric conversion: fixed-width gather Horner vs segmented-scan Horner
  * dfa_scan Pallas kernel (interpret) vs jnp reference — correctness-cost
    visibility only; interpret-mode timings are not TPU timings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn, yelp_parser
from repro.core import make_csv_dfa
from repro.core import partition as partition_mod
from repro.core import transition as tr
from repro.core import typeconv


def scan_variants():
    dfa = make_csv_dfa()
    data = dataset("yelp", 2000)
    p = yelp_parser()
    chunks = jnp.asarray(p.prepare(data))
    groups = tr.byte_groups(chunks, dfa)
    vecs = tr.chunk_transition_vectors(groups, dfa)

    f_g = jax.jit(lambda v: tr.exclusive_scan_vectors(v, use_matmul=False))
    f_m = jax.jit(lambda v: tr.exclusive_scan_vectors(v, use_matmul=True))
    dt, _ = time_fn(f_g, vecs)
    emit("scan/compose_gather", dt * 1e6, f"chunks={vecs.shape[0]}")
    dt, _ = time_fn(f_m, vecs)
    emit("scan/compose_matmul", dt * 1e6, f"chunks={vecs.shape[0]}")


def partition_variants():
    rng = np.random.default_rng(0)
    tags = jnp.asarray(rng.integers(0, 6, size=1 << 20), jnp.int32)
    f_sc = jax.jit(lambda t: partition_mod.partition_scatter(t, 5).perm)
    f_as = jax.jit(lambda t: partition_mod.partition_argsort(t, 5).perm)
    dt, _ = time_fn(f_sc, tags)
    emit("partition/counting_scatter", dt * 1e6, "n=1M,c=5")
    dt, _ = time_fn(f_as, tags)
    emit("partition/argsort", dt * 1e6, "n=1M,c=5")


def typeconv_variants():
    rng = np.random.default_rng(0)
    n_fields = 1 << 14
    strs = [str(int(rng.integers(0, 10**8))) for _ in range(n_fields)]
    css = np.frombuffer(("".join(strs)).encode(), np.uint8)
    lens = np.asarray([len(s) for s in strs], np.int32)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)

    f_g = jax.jit(lambda c, o, l: typeconv.parse_int(c, o, l, width=9).value)
    dt, _ = time_fn(f_g, jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens))
    emit("typeconv/gather_horner", dt * 1e6, f"fields={n_fields}")

    fid = np.repeat(np.arange(n_fields), lens).astype(np.int32)
    fstart = np.zeros(css.size, bool)
    fstart[offs] = True
    f_s = jax.jit(lambda c, s, i: typeconv.parse_int_segmented(c, s, i, n_fields).value)
    dt, _ = time_fn(f_s, jnp.asarray(css), jnp.asarray(fstart), jnp.asarray(fid))
    emit("typeconv/segmented_horner", dt * 1e6, f"css={css.size}B")


def kernel_vs_ref():
    from repro.kernels.dfa_scan import ops as kops
    from repro.kernels.dfa_scan import ref as kref
    dfa = make_csv_dfa()
    rng = np.random.default_rng(0)
    alphabet = np.frombuffer(b',"\nabcd ', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=4096 * 64)].reshape(4096, 64))
    dt, _ = time_fn(lambda c: kops.chunk_vectors(c, dfa), chunks, iters=2)
    emit("kernel/dfa_scan_interpret", dt * 1e6, "4096x64B;interpret-mode")
    dt, _ = time_fn(lambda c: kref.chunk_vectors(c, dfa), chunks, iters=2)
    emit("kernel/dfa_scan_jnp_ref", dt * 1e6, "4096x64B")


def run():
    scan_variants()
    partition_variants()
    typeconv_variants()
    kernel_vs_ref()
