"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One suite per paper table/figure (bench_parser: Figs. 9–13), plus the
algorithm-variant micro-benches (bench_scan — §Perf hypothesis inputs) and
the model-zoo step timings (bench_models).  Output protocol: CSV lines
``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "parser", "scan", "models"])
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.suite in ("all", "parser"):
        from benchmarks import bench_parser
        bench_parser.run()
    if args.suite in ("all", "scan"):
        from benchmarks import bench_scan
        bench_scan.run()
    if args.suite in ("all", "models"):
        from benchmarks import bench_models
        bench_models.run()


if __name__ == "__main__":
    main()
