"""Parser benchmarks mirroring the paper's evaluation figures.

  * fig9  — chunk-size sweep (time per parse vs chunk bytes)
  * fig10 — parsing rate vs input size
  * fig11 — tagging modes (tagged / inline / vector) + skewed input
  * fig12 — streaming partition-size sweep
  * fig13 — end-to-end vs baselines (python csv, numpy split, chunked-
            at-newline "Inst.Loading-style" constrained parser)
  * backends — backend=reference vs backend=pallas through the unified
            stage pipeline (core/stages.py), so the perf trajectory tracks
            the kernel path.  NOTE: on this CPU container the Pallas
            kernels run in interpret mode — the number is a correctness-
            under-load datapoint, not the TPU projection.

All wall-clock on the CPU backend (this container's "device"); the TPU-
projected numbers live in EXPERIMENTS.md §Roofline from the dry-run.
"""
from __future__ import annotations

import csv as pycsv
import io
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, gbps, taxi_parser, time_fn, yelp_parser
from repro.core.streaming import StreamingParser

N_YELP = 2000    # ~1.3 MB
N_TAXI = 8000    # ~0.7 MB


def fig9_chunk_size():
    data = dataset("yelp", N_YELP)
    for chunk in (16, 31, 32, 64, 128, 256):
        p = yelp_parser(chunk_size=chunk)
        chunks = p.prepare(data)
        dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
        emit(f"fig9/yelp/chunk{chunk}", dt * 1e6, f"{gbps(len(data), dt):.3f}GB/s")


def fig10_input_size():
    for kind, base in (("yelp", 250), ("taxi", 1000)):
        for mult in (1, 4, 16):
            data = dataset(kind, base * mult)
            p = yelp_parser() if kind == "yelp" else taxi_parser()
            chunks = p.prepare(data)
            dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
            emit(f"fig10/{kind}/{len(data)//1024}KiB", dt * 1e6,
                 f"{gbps(len(data), dt):.3f}GB/s")


def fig11_tagging_modes():
    data = dataset("yelp", N_YELP)
    for mode in ("tagged", "inline", "vector"):
        p = yelp_parser(tagging=mode)
        chunks = p.prepare(data)
        dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
        emit(f"fig11/yelp/{mode}", dt * 1e6, f"{gbps(len(data), dt):.3f}GB/s")
    skew = dataset("skewed", 400)
    p = yelp_parser(max_records=1 << 12)
    chunks = p.prepare(skew)
    dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
    emit("fig11/skewed/tagged", dt * 1e6, f"{gbps(len(skew), dt):.3f}GB/s")


def backend_sweep(n_records=250):
    """reference vs pallas through the same jitted pipeline (small input:
    interpret-mode kernels are slow on CPU; the sweep is about keeping the
    kernel path honest in the perf log, and flags any output divergence).

    Two workloads: yelp (int/str-heavy — the DFA+partition path dominates)
    and taxi (17 short numeric/temporal columns — float/date conversion
    kernels dominate, the §3.3 kernel-completion datapoint)."""
    for kind, mk, n in (("yelp", yelp_parser, n_records),
                        ("taxi", taxi_parser, 4 * n_records)):
        data = dataset(kind, n)
        results = {}
        for backend in ("reference", "pallas"):
            p = mk(max_records=1 << 12, backend=backend)
            chunks = jnp.asarray(p.prepare(data))
            dt, out = time_fn(p.parse_chunks, chunks, warmup=1, iters=2)
            results[backend] = out
            emit(f"backends/{kind}/{backend}", dt * 1e6,
                 f"{gbps(len(data), dt):.3f}GB/s;records={int(out.validation.n_records)}")
        r, q = results["reference"], results["pallas"]
        same = np.array_equal(np.asarray(r.css), np.asarray(q.css))
        vals_same = all(
            np.array_equal(np.asarray(getattr(r.values[c], f)),
                           np.asarray(getattr(q.values[c], f)))
            for c in r.values for f in ("value", "valid", "empty"))
        emit(f"backends/{kind}/outputs_match", 0.0,
             f"css={same};values={vals_same}")


def fig12_partition_size():
    data = dataset("yelp", N_YELP * 2)
    for part_kib in (64, 256, 1024):
        p = yelp_parser(max_records=1 << 13)
        sp = StreamingParser(p, part_kib * 1024, max_carry_bytes=1 << 16)
        for _ in sp.parse_stream([data]):  # warm-up: compile the partition shape
            pass
        t0 = time.perf_counter()
        n = 0
        for _, nrec in sp.parse_stream([data]):
            n += nrec
        dt = time.perf_counter() - t0
        emit(f"fig12/yelp/part{part_kib}KiB", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s;records={n}")


def _baseline_python_csv(data: bytes, kind: str):
    rows = list(pycsv.reader(io.StringIO(data.decode())))
    # include type conversion like ParPaRaw does
    if kind == "yelp":
        for r in rows:
            int(r[0]); int(r[1]); int(r[2]); r[3]; r[4]
    else:  # taxi: ints/floats/dates per TAXI_SCHEMA
        for r in rows:
            int(r[0]); r[1]; r[2]; int(r[3]); float(r[4])
            int(r[5]); int(r[6]); int(r[7])
            for x in r[8:15]:
                float(x)
            int(r[15]); float(r[16])
    return len(rows)


def _baseline_numpy_split(data: bytes):
    """Constrained splitter (no quote support — the format-specific trick the
    paper's §2 baselines use; WRONG on quoted yelp data, shown for rate only)."""
    arr = np.frombuffer(data, np.uint8)
    newlines = np.flatnonzero(arr == ord("\n"))
    commas = np.flatnonzero(arr == ord(","))
    return len(newlines) + 0 * len(commas)


def _baseline_chunked_newline(data: bytes, n_threads=8):
    """Mühlbauer-style chunking: split at newlines after chunk boundaries,
    then sequential-parse each chunk (here: single-core loop standing in for
    the thread pool; counts records only)."""
    n = len(data)
    bounds = [0]
    for i in range(1, n_threads):
        pos = data.find(b"\n", i * n // n_threads)
        bounds.append(pos + 1 if pos >= 0 else n)
    bounds.append(n)
    total = 0
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        total += data.count(b"\n", lo, hi)
    return total


def fig13_end_to_end():
    for kind in ("yelp", "taxi"):
        data = dataset(kind, N_YELP if kind == "yelp" else N_TAXI)
        p = yelp_parser() if kind == "yelp" else taxi_parser(max_records=1 << 13)
        sp = StreamingParser(p, 1 << 18, max_carry_bytes=1 << 16)
        sp.parse_all([data])  # warm-up: compile the partition shape
        t0 = time.perf_counter()
        out = sp.parse_all([data])
        dt_par = time.perf_counter() - t0
        emit(f"fig13/{kind}/parparaw", dt_par * 1e6, f"{gbps(len(data), dt_par):.3f}GB/s")

        t0 = time.perf_counter()
        _baseline_python_csv(data, kind)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/python_csv", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s;speedup={dt/dt_par:.2f}x")

        t0 = time.perf_counter()
        _baseline_numpy_split(data)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/numpy_split_constrained", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s")

        t0 = time.perf_counter()
        _baseline_chunked_newline(data)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/chunked_newline_constrained", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s")


def run():
    fig9_chunk_size()
    fig10_input_size()
    fig11_tagging_modes()
    backend_sweep()
    fig12_partition_size()
    fig13_end_to_end()
