"""Parser benchmarks mirroring the paper's evaluation figures.

  * fig9  — chunk-size sweep (time per parse vs chunk bytes)
  * fig10 — parsing rate vs input size
  * fig11 — tagging modes (tagged / inline / vector) + skewed input
  * fig12 — streaming partition-size sweep
  * fig13 — end-to-end vs baselines (python csv, numpy split, chunked-
            at-newline "Inst.Loading-style" constrained parser)
  * materialize_sweep — backend × partition-impl × fused/unfused typeconv
            through the unified stage pipeline (core/stages.py), emitting
            machine-readable ``BENCH_parser.json`` so the perf trajectory
            of the backend-owned materialization path (partition kernel +
            fused gather+convert) is tracked across PRs.  NOTE: on this
            CPU container the Pallas kernels run in interpret mode — the
            numbers are correctness-under-load datapoints and relative
            fused-vs-unfused comparisons, not the TPU projection.
  * distributed_sweep — mesh-sharded end-to-end parsing
            (``DistributedParser``): GB/s over D ∈ {1, 2, 4, 8} virtual
            devices (one subprocess per D) on yelp + taxi, per-variant
            collective byte counts off the compiled executable (must be
            O(D·|S|), input-size-independent) and the
            ``sharded_vs_single`` bit-identity pin (``assemble`` vs
            ``Parser.to_arrow``).
  * stream_sweep — the §4.4 device-resident streaming engine
            (``StreamSession``): end-to-end GB/s for S ∈ {1, 4, 16}
            concurrent streams, batched (one vmapped dispatch per round)
            vs sequential (one stream at a time through a single-stream
            session), with the batched-vs-sequential speedup and the
            honest throughput denominators (``bytes_in`` vs
            ``bytes_reparsed``) recorded per variant.
  * formats_sweep — GB/s per *registered format* (csv / jsonl / zone /
            clf through ``repro.core.formats`` + the per-format tuning in
            ``repro.configs.parse_formats``) × backend, with cross-variant
            bit-identity per format — prices the paper's format-agnostic
            engine claim: a new format is a new table, and here is what it
            costs relative to CSV on identical machinery.

Standalone CLI::

    PYTHONPATH=src python -m benchmarks.bench_parser \
        [--backend all] [--workload all] [--json BENCH_parser.json] \
        [--records 250] [--tuned] [--check-tuned]

``--tuned`` adds autotuned variants (``ParserConfig(autotune=True)`` —
cache-resolved knobs from ``repro.tune``) next to every default-config
variant, plus per-workload ``tuned_vs_default`` ratio blocks.
``--check-tuned`` exits non-zero if any tuned config is more than 5%
slower than its default — the nightly guard that a stale cache entry
can't silently regress the tuned path.

A partial run (``--workload formats`` etc.) merges its rows into an
existing ``BENCH_parser.json`` instead of clobbering the other workloads'
entries; the existing file's ``meta`` is kept (full-run provenance).

All wall-clock on the CPU backend (this container's "device"); the TPU-
projected numbers live in EXPERIMENTS.md §Roofline from the dry-run.

``BENCH_parser.json`` schema (one object per run)::

    {
      "meta": {
        "interpret": bool,        # Pallas interpret mode (always true on CPU)
        "n_records_base": int,    # --records (taxi runs 4x this)
        "device_kind": str,       # jax.devices()[0].device_kind — the
        "platform": str,          #   environment fingerprint: numbers from
        "jax_version": str,       #   different fingerprints are not
        "cpu_count": int          #   comparable (same axes as the autotune
      },                          #   cache key)
      "workloads": {
        "<yelp|taxi>": {
          "n_records": int,       # records in the generated input
          "bytes": int,           # raw input size
          "outputs_match": bool,  # every variant bit-identical to the first
          "variants": {
            "<label>": {          # VARIANTS key, e.g. "pallas/fused"
              "us_per_call": float,       # best-of e2e parse wall clock
              "materialize_us": float,    # best-of materialize-stage-only
                                          #   (absent for fused-pipeline:
                                          #   the megakernel has no
                                          #   separable materialize stage)
              "gbps": float,              # bytes / us_per_call
              "records": int,             # records the parse reported
              "partition_impl": str,      # resolved (never "auto")
              "fuse_typeconv": bool,
              "typeconv_path": str,       # reference | unfused |
                                          #   fused-windowed | fused-wholecss
              "execute_path": str         # staged | fused — the resolved
            }                             #   whole-pipeline tier
          },
          "fused_vs_unfused": {           # pallas/fused vs pallas/unfused,
            "speedup": float,             # materialize_us ratio (unfused/
            "no_slower": bool             # fused); the PR-3 fusion metric
          },
          "windowed_vs_wholecss": {       # pallas/fused vs pallas/
            "speedup": float,             # fused-wholecss, same ratio; the
            "no_slower": bool             # window-DMA accountability metric
          },
          "fused_vs_staged": {            # pallas/fused-pipeline vs pallas/
            "speedup": float,             # fused, us_per_call ratio (staged/
            "no_slower": bool             # fused); whole-pipeline-fusion
          },                              # accountability metric
          "tuned_vs_default": {           # --tuned only: "<backend>/tuned"
            "<backend>": {                #   (autotune=True) vs the backend
              "speedup": float,           #   default variant, us_per_call
              "no_slower": bool           #   ratio (default/tuned); 5% noise
            }                             #   margin — the autotuner's
          }                               #   do-no-harm gate
        },
        "formats": {                      # per-registered-format workload
          "<csv|jsonl|zone|clf>": {
            "n_records": int,             # records in the synthetic corpus
            "bytes": int,                 # raw input size
            "outputs_match": bool,        # all variants bit-identical
            "variants": {
              "<reference|pallas|pallas-fused>": {  # + "<backend>-tuned"
                "us_per_call": float,     # best-of e2e parse wall clock
                "gbps": float,            # bytes / us_per_call
                "records": int,           # records the parse reported
                "execute_path": str       # staged | fused (resolved tier)
              }
            },
            "tuned_vs_default": {         # --tuned only, same shape/margin
              "<backend>": {"speedup": float, "no_slower": bool}
            }                             #   as the yelp/taxi block
          }
        },
        "stream": {                       # §4.4 streaming-engine workload
          "n_records_per_stream": int,    # CLI --records (reference streams;
                                          #   pallas streams run smaller —
                                          #   see the per-variant field)
          "partition_bytes": int,
          "max_carry_bytes": int,
          "variants": {
            "<backend>/S<K>": {           # K concurrent streams, batched
              "s_total": float,           # end-to-end wall clock (round-
                                          #   robin best-of after a warm-up
                                          #   run — tune/measure.py core)
              "gbps": float,              # sum of bytes_in / s_total — the
                                          #   honest number: carry re-parses
                                          #   are NOT in the numerator
              "records": int,
              "n_records_per_stream": int,# records actually generated per
                                          #   stream for THIS variant
              "bytes": int,               # total source bytes (all streams)
              "partition_bytes": int,     # partition size this variant ran
                                          #   (tuned variants resolve it from
                                          #   the cache's stream section)
              "bytes_reparsed": int,      # carry bytes parsed again (device
                                          #   traffic = bytes + reparsed)
              "partitions": int
            }
          },
          "stream_batched_vs_sequential": {
            "<backend>": {                # backend incl. "pallas-fused"
              "S<K>": {                   # batched K-stream session vs K
                "speedup": float,         #   sequential single-stream runs
                "outputs_match": bool     # per-partition bit-identity
              }
            }
          },
          "fused_vs_staged": {            # pallas-fused vs pallas sessions
            "S<K>": {
              "speedup": float,           # staged s_total / fused s_total
              "no_slower": bool
            }
          },
          "tuned_vs_default": {           # --tuned only: "<backend>-tuned/
            "<backend>": {                #   S<K>" vs "<backend>/S<K>",
              "S<K>": {"speedup": float,  #   s_total ratio (default/tuned);
                       "no_slower": bool} #   10% margin — end-to-end drains
            }                             #   are noisier than single parses
          }
        },
        "distributed": {                  # mesh-sharded end-to-end workload
          "n_records_base": int,          # CLI --records (pallas variants run
                                          #   smaller, like the other sweeps)
          "per_device": {
            "D<K>": {                     # K virtual devices (subprocess with
                                          #   --xla_force_host_platform_
                                          #   device_count=K; "skipped" when
                                          #   the topology is unavailable)
              "devices": int,
              "workloads": {
                "<yelp|taxi>": {
                  "variants": {
                    "<reference|pallas|pallas-fused>": {
                      "n_records": int,
                      "bytes": int,       # raw input size
                      "us_per_call": float,  # best-of sharded e2e parse
                      "gbps": float,
                      "collective_bytes": {str: int},   # per-op bytes moved
                                          #   by the compiled executable —
                                          #   O(D*|S|), input-size-free
                      "collective_counts": {str: int},  # per-op instr counts
                      "sharded_vs_single": bool  # assemble() bit-identical
                    }                            #   to Parser.to_arrow
                  }
                }
              }
            }
          }
        },
        "serve": {                        # multi-tenant ParseService workload
          "n_records_per_tenant": int,    # CLI --records (pallas tenants run
                                          #   smaller — per-variant field)
          "partition_bytes": int,
          "max_carry_bytes": int,
          "variants": {
            "<backend>/S<K>": {           # K tenants, one batched session
              "s_total": float,           # batch wall clock (post warm-up
                                          #   wave on the same service — the
                                          #   timed wave holds zero compiles)
              "gbps": float,              # AGGREGATE: sum of per-tenant
                                          #   bytes_in / s_total
              "fairness": float,          # min/max per-tenant throughput
                                          #   over the same wall clock
                                          #   (equal sources -> 1.0 = fair)
              "records": int,
              "bytes": int,
              "bytes_reparsed": int,
              "n_records_per_tenant": int,
              "session_builds": int       # total sessions compiled — pins
            }                             #   warm-wave reuse (tier caching)
          }
        }
      }
    }

``no_slower`` allows a 5% timing-noise margin.  On this interpret-mode
container the windowed-vs-wholecss ratio measures plan+cond overhead only —
the VMEM-capacity win the windows buy exists only on real hardware, where
the whole-CSS variant stops fitting at ~16 MB/core and this ratio becomes
the difference between parsing and not parsing.

Known tuned-config regression note (interpret CPU): past BENCH runs show
the whole-pipeline megakernel *regressing* the clf / jsonl / zone formats
relative to the staged path (csv is the fused win), so the committed seed
cache (``src/repro/tune/default_cache.json``) resolves those formats to
``fuse_pipeline=False`` on this fingerprint.  A ``--tuned`` run whose
``tuned_vs_default.no_slower`` goes false means the cache entry has gone
stale for the current environment — re-run ``python -m repro.tune``.
"""
from __future__ import annotations

import argparse
import csv as pycsv
import dataclasses
import io
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, gbps, taxi_parser, time_fn, yelp_parser
from repro.core.streaming import StreamingParser
from repro.tune import measure as tune_measure

N_YELP = 2000    # ~1.3 MB
N_TAXI = 8000    # ~0.7 MB

#: materialize_sweep variants: label → (backend, partition_impl,
#: fuse_typeconv, window_rows, fuse_pipeline).  ``pallas/fused`` is the
#: backend-default staged materialization path (partition "auto" +
#: *windowed* fused gather+convert kernels — what every driver runs);
#: ``pallas/fused-pipeline`` is the whole-pipeline megakernel
#: (``fuse_pipeline=True`` — one kernel per partition, no HBM round-trips
#: between replay and typed columns); ``pallas/fused-wholecss`` pins the
#: pre-window fused kernels (whole CSS in VMEM — the windowed path's
#: baseline, and on real hardware the VMEM-capped variant);
#: ``pallas/unfused`` is the pre-fusion pallas path (jnp scatter partition
#: + XLA-gather typeconv) the fusion must not regress against; the rest
#: sweep the partition impls, the radix *kernel* included (on this
#: interpret-mode container the kernel is a correctness datapoint — "auto"
#: resolves to it only on real hardware).
VARIANTS = {
    "reference/scatter": ("reference", "scatter", True, 0, False),
    "reference/argsort": ("reference", "argsort", True, 0, False),
    "reference/scatter2": ("reference", "scatter2", True, 0, False),
    "pallas/fused": ("pallas", "auto", True, 0, False),
    "pallas/fused-pipeline": ("pallas", "auto", True, 0, True),
    "pallas/fused-wholecss": ("pallas", "auto", True, -1, False),
    "pallas/unfused": ("pallas", "scatter", False, 0, False),
    "pallas/kernel+fused": ("pallas", "kernel", True, 0, False),
    "pallas/scatter+fused": ("pallas", "scatter", True, 0, False),
    "pallas/argsort+fused": ("pallas", "argsort", True, 0, False),
    "pallas/scatter2+fused": ("pallas", "scatter2", True, 0, False),
}

#: Per backend, the variant whose config is the all-defaults (heuristic)
#: one — what an untuned user gets, and the ``--tuned`` comparison base.
_DEFAULT_LABEL = {"reference": "reference/scatter", "pallas": "pallas/fused"}


def _tuned_vs_default(variants: dict, pairs: dict) -> dict:
    """``{key: {speedup, no_slower}}`` for each ``key: (tuned_label,
    default_label)`` present in ``variants`` — the ``--tuned`` invariant
    rows (``--check-tuned`` fails the run on any ``no_slower=False``)."""
    out = {}
    for key, (tuned_label, default_label) in pairs.items():
        tv, dv = variants.get(tuned_label), variants.get(default_label)
        if tv is None or dv is None:
            continue
        tu, du = tv["us_per_call"], dv["us_per_call"]
        out[key] = {"speedup": du / tu,
                    "no_slower": bool(tu <= du * 1.05)}  # 5% noise margin
    return out


def fig9_chunk_size():
    data = dataset("yelp", N_YELP)
    for chunk in (16, 31, 32, 64, 128, 256):
        p = yelp_parser(chunk_size=chunk)
        chunks = p.prepare(data)
        dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
        emit(f"fig9/yelp/chunk{chunk}", dt * 1e6, f"{gbps(len(data), dt):.3f}GB/s")


def fig10_input_size():
    for kind, base in (("yelp", 250), ("taxi", 1000)):
        for mult in (1, 4, 16):
            data = dataset(kind, base * mult)
            p = yelp_parser() if kind == "yelp" else taxi_parser()
            chunks = p.prepare(data)
            dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
            emit(f"fig10/{kind}/{len(data)//1024}KiB", dt * 1e6,
                 f"{gbps(len(data), dt):.3f}GB/s")


def fig11_tagging_modes():
    data = dataset("yelp", N_YELP)
    for mode in ("tagged", "inline", "vector"):
        p = yelp_parser(tagging=mode)
        chunks = p.prepare(data)
        dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
        emit(f"fig11/yelp/{mode}", dt * 1e6, f"{gbps(len(data), dt):.3f}GB/s")
    skew = dataset("skewed", 400)
    p = yelp_parser(max_records=1 << 12)
    chunks = p.prepare(skew)
    dt, _ = time_fn(p.parse_chunks, jnp.asarray(chunks))
    emit("fig11/skewed/tagged", dt * 1e6, f"{gbps(len(skew), dt):.3f}GB/s")


def _materialize_only(parsers, rounds=8):
    """Best-of interleaved timing of ``stages.materialize`` alone, per
    variant, from shared §3.1/§3.2 outputs (identical across variants).
    The loop itself is the shared measurement core (``tune.measure``)."""
    from repro.core import backends as backends_mod
    from repro.core import stages as stages_mod

    p0, chunks0 = next(iter(parsers.values()))
    be0 = backends_mod.get_backend(p0.cfg.backend)

    @jax.jit
    def upstream(chunks):
        ctx = stages_mod.determine_contexts(chunks, p0.cfg, be0)
        ids = stages_mod.identify_symbols(ctx)
        return ctx.classes, ids.record_id, ids.column_id

    classes, rec_id, col_id = (jnp.asarray(x) for x in upstream(chunks0))

    thunks = {}
    for label, (p, chunks) in parsers.items():
        be = backends_mod.get_backend(p.cfg.backend)
        plan = stages_mod.plan_materialize(p.cfg, be)
        fn = jax.jit(lambda ch, cl, r, c, plan=plan, cfg=p.cfg, be=be:
                     stages_mod.materialize(ch, cl, r, c, plan, cfg, be))
        thunks[label] = (lambda fn=fn, ch=chunks:
                         fn(ch, classes, rec_id, col_id))
    measured = tune_measure.measure_best(thunks, rounds=rounds)
    return {label: m.seconds for label, m in measured.items()}


def materialize_sweep(n_records=250, backends=("reference", "pallas"),
                      workloads=("yelp", "taxi"), json_path="BENCH_parser.json",
                      tuned=False):
    """Backend × partition-impl × fused/unfused sweep through the same
    jitted pipeline, emitting machine-readable ``BENCH_parser.json``.

    Small inputs: interpret-mode kernels are slow on CPU; the sweep is about
    keeping the kernel path honest in the perf log, flagging output
    divergence, and pinning the fused-vs-unfused pallas comparison the
    materialization refactor is accountable for.

    Two workloads: yelp (int/str-heavy — the DFA+partition path dominates)
    and taxi (17 short numeric/temporal columns — float/date conversion
    kernels dominate, the §3.3 kernel-completion datapoint)."""
    from repro.core import backends as backends_mod
    from repro.core import stages as stages_mod

    report = _base_report(n_records)
    for kind, mk, n in (("yelp", yelp_parser, n_records),
                        ("taxi", taxi_parser, 4 * n_records)):
        if kind not in workloads:
            continue
        data = dataset(kind, n)
        entry = {"n_records": n, "bytes": len(data), "variants": {}}
        parsers = {}
        for label, (backend, impl, fuse, window_rows, fuse_pipe) in VARIANTS.items():
            if backend not in backends:
                continue
            p = mk(max_records=1 << 12, backend=backend, partition_impl=impl,
                   fuse_typeconv=fuse, window_rows=window_rows,
                   fuse_pipeline=fuse_pipe)
            parsers[label] = (p, jnp.asarray(p.prepare(data)))
        if tuned:
            # cache-resolved configs (ParserConfig(autotune=True)): every
            # knob the autotuner measured, same machinery otherwise — timed
            # in the same round-robin group as the defaults they compare to
            for backend in backends:
                p = mk(max_records=1 << 12, backend=backend, autotune=True)
                parsers[f"{backend}/tuned"] = (p, jnp.asarray(p.prepare(data)))
        # Round-robin best-of timing (tune.measure — the tuner's own loop):
        # shared-host noise arrives in bursts long enough to swallow whole
        # per-variant runs, so interleave the variants, keep each one's
        # best round.
        measured = tune_measure.measure_best(
            {label: (lambda p=p, ch=ch: p.parse_chunks(ch))
             for label, (p, ch) in parsers.items()})
        best = {label: m.seconds for label, m in measured.items()}
        results = {label: m.output for label, m in measured.items()}
        for label, (p, chunks) in parsers.items():
            dt, out = best[label], results[label]
            plan = stages_mod.plan_materialize(
                p.cfg, backends_mod.get_backend(p.cfg.backend))
            entry["variants"][label] = {
                "us_per_call": dt * 1e6,
                "gbps": gbps(len(data), dt),
                "records": int(out.validation.n_records),
                "partition_impl": plan.partition_impl,
                "fuse_typeconv": p.cfg.fuse_typeconv,
                "typeconv_path": plan.typeconv_path,
                # the resolved staged/fused tier for THIS input size (plan
                # choice + the effective fused_max_bytes cap)
                "execute_path": stages_mod.resolved_execute_path(
                    p.plan, backends_mod.get_backend(p.cfg.backend),
                    int(chunks.size), p.cfg),
            }
            emit(f"materialize/{kind}/{label}", dt * 1e6,
                 f"{gbps(len(data), dt):.3f}GB/s;records={int(out.validation.n_records)}")
        if tuned:
            entry["tuned_vs_default"] = _tuned_vs_default(
                entry["variants"], {b: (f"{b}/tuned", _DEFAULT_LABEL[b])
                                    for b in backends})
            for b, r in entry["tuned_vs_default"].items():
                emit(f"materialize/{kind}/tuned_vs_default/{b}", 0.0,
                     f"{r['speedup']:.3f}x;no_slower={r['no_slower']}")

        # Every variant must be bit-identical (stable partition + shared
        # arithmetic make this exact, not a tolerance check).
        labels = sorted(results)
        if labels:
            base = results[labels[0]]
            same = all(
                np.array_equal(np.asarray(base.css), np.asarray(results[l].css))
                and all(
                    np.array_equal(np.asarray(getattr(base.values[c], f)),
                                   np.asarray(getattr(results[l].values[c], f)))
                    for c in base.values for f in ("value", "valid", "empty"))
                for l in labels[1:])
            entry["outputs_match"] = bool(same)
            emit(f"materialize/{kind}/outputs_match", 0.0, f"all={same}")

        # Materialization-only timing (tagging → partition → field index →
        # typeconv, jitted in isolation): the §3.1/§3.2 DFA stage is
        # identical across variants and dominates the e2e numbers above, so
        # the fused-vs-unfused accountability metric is scoped to the stage
        # this refactor actually owns.  The whole-pipeline megakernel has no
        # standalone materialize stage (that is the point), so it is
        # excluded here and compared end-to-end below instead.
        staged_parsers = {l: pc for l, pc in parsers.items()
                          if pc[0].plan.execute_path != "fused"}
        if staged_parsers:
            mat_best = _materialize_only(staged_parsers)
            for label, dt in mat_best.items():
                entry["variants"][label]["materialize_us"] = dt * 1e6
                emit(f"materialize_only/{kind}/{label}", dt * 1e6, "")

        fused, unfused = "pallas/fused", "pallas/unfused"
        if fused in entry["variants"] and unfused in entry["variants"]:
            tf = entry["variants"][fused]["materialize_us"]
            tu = entry["variants"][unfused]["materialize_us"]
            entry["fused_vs_unfused"] = {
                "speedup": tu / tf,
                "no_slower": bool(tf <= tu * 1.05),  # 5% timing-noise margin
            }
            emit(f"materialize/{kind}/fused_speedup", 0.0, f"{tu / tf:.3f}x")
        # The window-DMA accountability metric: the windowed default vs the
        # pre-window whole-CSS-in-VMEM fused kernels.  On interpret-mode CPU
        # this prices the plan+cond overhead; on real hardware the wholecss
        # variant caps per-parse CSS at VMEM capacity and the windowed path
        # is what keeps scaling.
        wholecss = "pallas/fused-wholecss"
        if fused in entry["variants"] and wholecss in entry["variants"]:
            tf = entry["variants"][fused]["materialize_us"]
            tw = entry["variants"][wholecss]["materialize_us"]
            entry["windowed_vs_wholecss"] = {
                "speedup": tw / tf,
                "no_slower": bool(tf <= tw * 1.05),  # 5% timing-noise margin
            }
            emit(f"materialize/{kind}/windowed_vs_wholecss", 0.0, f"{tw / tf:.3f}x")
        # The whole-pipeline-fusion accountability metric: the megakernel
        # vs the staged backend default, end-to-end (the megakernel has no
        # separable materialize stage).  On interpret-mode CPU this is a
        # correctness-under-load datapoint — the HBM round-trips the fusion
        # removes only cost on real hardware.
        pipeline = "pallas/fused-pipeline"
        if fused in entry["variants"] and pipeline in entry["variants"]:
            tp = entry["variants"][pipeline]["us_per_call"]
            ts = entry["variants"][fused]["us_per_call"]
            entry["fused_vs_staged"] = {
                "speedup": ts / tp,
                "no_slower": bool(tp <= ts * 1.05),  # 5% timing-noise margin
            }
            emit(f"materialize/{kind}/fused_vs_staged", 0.0, f"{ts / tp:.3f}x")
        report["workloads"][kind] = entry

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return report


def _base_report(n_records: int) -> dict:
    """The shared BENCH_parser.json skeleton (single definition so the
    stream-only and materialize paths can never emit diverging meta).

    ``meta`` carries the environment fingerprint: perf numbers are only
    comparable across runs on the same (device_kind, platform, jax, cpus)
    — the same identity the autotuner cache keys on (``tune.cache``), so a
    bench row and the tuned config it exercised name the same machine."""
    dev = jax.devices()[0]
    return {"meta": {"interpret": True, "n_records_base": n_records,
                     "device_kind": str(dev.device_kind),
                     "platform": str(dev.platform),
                     "jax_version": jax.__version__,
                     "cpu_count": os.cpu_count()},
            "workloads": {}}


#: Formats-workload registry names benched per run: jsonl + zone are the
#: format-layer dialects, clf the log format, csv the baseline every other
#: row compares against (same engine, different tables).
FORMATS_BENCH = ("csv", "jsonl", "zone", "clf")


def _format_payload(fmt: str, n: int) -> bytes:
    """Deterministic synthetic corpus per dialect (no RNG — the perf log
    must describe a byte-stable input across runs).  Shared with the
    autotuner CLI so tuned configs and bench rows measure the same bytes."""
    from repro.data import synth

    return synth.format_payload(fmt, n)


def formats_sweep(n_records=250, backends=("reference", "pallas"),
                  tuned=False):
    """GB/s per registered format × backend on the shared engine.

    Parsers come from ``repro.configs.parse_formats.tuned_parser_config``
    (registry DFA + per-format knobs); every variant of a format must be
    bit-identical, so a dialect whose tables break only one backend's
    kernels cannot land a green perf row.

    The non-tuned labels pin their knobs explicitly (``autotune=False``) so
    this sweep keeps feeding the *un*-resolved baselines the tuner cache is
    refreshed from.  ``tuned=True`` adds ``<backend>-tuned`` rows that
    leave every knob to cache resolution — per BENCH history the committed
    interpret-CPU seed cache resolves clf/jsonl/zone to the staged path
    (the megakernel regresses them there; csv is its only win), so the
    tuned rows are the measured-defaults accountability check."""
    from repro.core import Parser
    from repro.core import backends as backends_mod
    from repro.core import stages as stages_mod
    from repro.configs.parse_formats import tuned_parser_config

    out = {}
    for fmt in FORMATS_BENCH:
        data = _format_payload(fmt, n_records)
        entry = {"n_records": n_records, "bytes": len(data), "variants": {}}
        parsers = {}
        labels = ["reference", "pallas", "pallas-fused"]
        if tuned:
            labels += [f"{b}-tuned" for b in backends]
        for label in labels:
            base = label.replace("-tuned", "").replace("-fused", "")
            if base not in backends:
                continue
            if label.endswith("-tuned"):
                # all knobs cache-resolved (tuned_parser_config autotunes
                # by default) — the measured per-device config
                p = Parser(tuned_parser_config(
                    fmt, max_records=1 << 12, backend=base))
            else:
                p = Parser(tuned_parser_config(
                    fmt, max_records=1 << 12, backend=base, autotune=False,
                    fuse_pipeline=label == "pallas-fused",
                    # pin the radix partition kernel on pallas (interpret-
                    # mode "auto" would pick the jnp pass)
                    partition_impl="kernel" if base == "pallas" else "auto"))
            parsers[label] = (p, jnp.asarray(p.prepare(data)))
        # round-robin best-of via the shared measurement core
        measured = tune_measure.measure_best(
            {label: (lambda p=p, ch=ch: p.parse_chunks(ch))
             for label, (p, ch) in parsers.items()})
        best = {label: m.seconds for label, m in measured.items()}
        results = {label: m.output for label, m in measured.items()}
        for label, (p, chunks) in parsers.items():
            dt = best[label]
            n_got = int(results[label].validation.n_records)
            entry["variants"][label] = {
                "us_per_call": dt * 1e6,
                "gbps": gbps(len(data), dt),
                "records": n_got,
                "execute_path": stages_mod.resolved_execute_path(
                    p.plan, backends_mod.get_backend(p.cfg.backend),
                    int(chunks.size), p.cfg),
            }
            emit(f"formats/{fmt}/{label}", dt * 1e6,
                 f"{gbps(len(data), dt):.3f}GB/s;records={n_got}")
        if tuned:
            entry["tuned_vs_default"] = _tuned_vs_default(
                entry["variants"],
                {b: (f"{b}-tuned", b) for b in backends})
            for b, r in entry["tuned_vs_default"].items():
                emit(f"formats/{fmt}/tuned_vs_default/{b}", 0.0,
                     f"{r['speedup']:.3f}x;no_slower={r['no_slower']}")
        labels = sorted(results)
        if labels:
            base_r = results[labels[0]]
            same = all(
                np.array_equal(np.asarray(base_r.css),
                               np.asarray(results[l].css))
                and all(
                    np.array_equal(
                        np.asarray(getattr(base_r.values[c], f)),
                        np.asarray(getattr(results[l].values[c], f)))
                    for c in base_r.values for f in ("value", "valid", "empty"))
                for l in labels[1:])
            entry["outputs_match"] = bool(same)
            emit(f"formats/{fmt}/outputs_match", 0.0, f"all={same}")
        out[fmt] = entry
    return out


#: Stream-workload batch sizes (concurrent tenants per dispatch).
STREAM_S = (1, 4, 16)


def stream_sweep(n_records=250, backends=("reference", "pallas"),
                 partition_bytes=1 << 14, max_carry_bytes=1 << 13,
                 tuned=False):
    """§4.4 streaming-engine workload: S concurrent yelp-like streams through
    ``StreamSession``, batched (one vmapped dispatch per round, per-stream
    device carry) vs sequential (the same streams one at a time through a
    single-stream session — S times the dispatches).

    GB/s uses ``bytes_in`` (each source byte once) as the numerator;
    ``bytes_reparsed`` is reported alongside so the carry-re-parse overhead
    is visible instead of silently inflating throughput.  On this
    interpret-mode container the pallas rows are correctness-under-load
    datapoints (each stream gets ``n_records // 4`` records to keep the
    sweep bounded); on real hardware the batched-vs-sequential speedup is
    the multi-tenant scale-out metric.
    """
    from repro.core.streaming import StreamSession

    entry = {"n_records_per_stream": n_records,
             "partition_bytes": partition_bytes,
             "max_carry_bytes": max_carry_bytes,
             "variants": {}, "stream_batched_vs_sequential": {}}
    # "pallas-fused" = the pallas backend running the whole-pipeline
    # megakernel per partition (fuse_pipeline=True), riding the same
    # StreamSession carry hooks — the fused-streaming accountability row.
    variants = list(backends)
    if "pallas" in variants:
        variants.append("pallas-fused")
    if tuned:
        # cache-resolved configs AND the cache's measured streaming
        # partition size (tune_stream's stream section)
        variants += [f"{b}-tuned" for b in backends]
    for backend in variants:
        base = backend.replace("-tuned", "").replace("-fused", "")
        if backend == "pallas-fused":
            be_kw = dict(backend="pallas", fuse_pipeline=True)
        elif backend.endswith("-tuned"):
            be_kw = dict(backend=base, autotune=True)
        else:
            be_kw = dict(backend=backend)
        n_per_stream = n_records if base == "reference" else max(n_records // 4, 16)
        datas = [dataset("yelp", n_per_stream, seed=s) for s in range(max(STREAM_S))]
        ratios = {}
        for S in STREAM_S:
            streams = datas[:S]
            total_bytes = sum(len(d) for d in streams)
            # ONE session per shape, reused across warm-up and timed runs —
            # the steady-state contract (carry resets per call, the compiled
            # step is cached), so the timed pass holds zero compilation.
            parser = yelp_parser(max_records=1 << 12, **be_kw)
            pb_v = partition_bytes
            if backend.endswith("-tuned"):
                from repro.tune import resolve as tune_resolve

                pb_v = tune_resolve.tuned_stream_partition_bytes(
                    parser.cfg, partition_bytes)
            sess_b = StreamSession(parser, pb_v,
                                   max_carry_bytes=max_carry_bytes, n_streams=S)
            sess_q = StreamSession(parser, pb_v,
                                   max_carry_bytes=max_carry_bytes, n_streams=1)

            def signature(result, n):
                """Whole-partition fingerprint for the bit-identity check:
                every ParseResult field (the tuner's own signature core)."""
                return [np.int64(n)] + tune_measure.parse_signature(result)

            def run_batched(collect=False):
                outs = {s: [] for s in range(S)}
                for s, result, n in sess_b.parse_streams([[d] for d in streams]):
                    if collect:
                        outs[s].append(signature(result, n))
                return outs

            def run_sequential(collect=False):
                outs = {s: [] for s in range(S)}
                for s, d in enumerate(streams):
                    for _s, result, n in sess_q.parse_streams([[d]]):
                        if collect:
                            outs[s].append(signature(result, n))
                return outs

            # warm-up runs compile the steps and pin bit-identity
            out_b = run_batched(collect=True)
            out_q = run_sequential(collect=True)
            match = all(
                len(out_b[s]) == len(out_q[s])
                and all(len(pb) == len(pq)
                        and all(np.array_equal(a, b) for a, b in zip(pb, pq))
                        for pb, pq in zip(out_b[s], out_q[s]))
                for s in range(S))
            one_run = [dataclasses.replace(st) for st in sess_b.stats]

            # the shared round-robin best-of core (the collect runs above
            # already compiled both paths, so warmup=0)
            measured = tune_measure.measure_best(
                {"batched": run_batched, "sequential": run_sequential},
                rounds=2, warmup=0)
            dt_b = measured["batched"].seconds
            dt_q = measured["sequential"].seconds

            entry["variants"][f"{backend}/S{S}"] = {
                "s_total": dt_b,
                "gbps": gbps(total_bytes, dt_b),
                "records": sum(st.records for st in one_run),
                "n_records_per_stream": n_per_stream,
                "bytes": total_bytes,
                "partition_bytes": pb_v,
                "bytes_reparsed": sum(st.bytes_reparsed for st in one_run),
                "partitions": sum(st.partitions for st in one_run),
            }
            ratios[f"S{S}"] = {"speedup": dt_q / dt_b, "outputs_match": bool(match)}
            emit(f"stream/{backend}/S{S}", dt_b * 1e6,
                 f"{gbps(total_bytes, dt_b):.3f}GB/s;batched_vs_seq="
                 f"{dt_q / dt_b:.2f}x;match={match}")
        entry["stream_batched_vs_sequential"][backend] = ratios
    # megakernel-streaming accountability: fused vs staged pallas sessions,
    # same stream counts (both run the same per-stream record budget).
    fused_ratios = {}
    for S in STREAM_S:
        stg = entry["variants"].get(f"pallas/S{S}")
        fus = entry["variants"].get(f"pallas-fused/S{S}")
        if stg and fus:
            fused_ratios[f"S{S}"] = {
                "speedup": stg["s_total"] / fus["s_total"],
                "no_slower": bool(fus["s_total"] <= stg["s_total"] * 1.05),
            }
    if fused_ratios:
        entry["fused_vs_staged"] = fused_ratios
    if tuned:
        # cache-resolved vs heuristic-default sessions, same backend and
        # stream count — the nightly regression gate.  10% margin, not the
        # 5% the single-parse gates use: these are end-to-end multi-round
        # session drains (Python feed loop included), and on a 1-CPU
        # interpret container even identical configs spread ~7% run-to-run.
        tvd = {}
        for b in backends:
            for S in STREAM_S:
                du = entry["variants"].get(f"{b}/S{S}")
                tu = entry["variants"].get(f"{b}-tuned/S{S}")
                if du and tu:
                    tvd.setdefault(b, {})[f"S{S}"] = {
                        "speedup": du["s_total"] / tu["s_total"],
                        "no_slower": bool(
                            tu["s_total"] <= du["s_total"] * 1.10),
                    }
        if tvd:
            entry["tuned_vs_default"] = tvd
    return entry


#: Serve-workload tenant counts (concurrent tenants per service batch).
SERVE_S = (4,)


def serve_sweep(n_records=250, backends=("reference", "pallas"),
                partition_bytes=1 << 14, max_carry_bytes=1 << 13):
    """Multi-tenant serving workload: S tenants with one shared plan key
    through ``ParseService`` in synchronous mode — one admission decision,
    one tier-S batched session.  A warm-up wave on the same service
    compiles the session step, so the timed wave holds zero compilation
    (the steady-state serving contract; pinned by ``session_builds``).

    ``gbps`` is aggregate: the sum of per-tenant ``bytes_in`` over the
    batch wall clock.  ``fairness`` is min/max of per-tenant throughput
    over that same wall clock — the tenants submit equal-record sources,
    so 1.0 means the vmapped lanes served every tenant the same number of
    bytes per second and any spread is source-size variance plus ragged
    lane lifetimes, not scheduler bias.  As in the stream workload, carry
    re-parses are excluded from the numerator.
    """
    from repro.core import ParserConfig, Schema, make_csv_dfa
    from repro.data import synth as synth_mod
    from repro.serve import ParseService

    entry = {"n_records_per_tenant": n_records,
             "partition_bytes": partition_bytes,
             "max_carry_bytes": max_carry_bytes,
             "variants": {}}
    for backend in backends:
        n_per = n_records if backend == "reference" else max(n_records // 4, 16)
        cfg = ParserConfig(
            dfa=make_csv_dfa(), schema=Schema.of(*synth_mod.YELP_SCHEMA),
            max_records=1 << 12, chunk_size=64, backend=backend)
        for S in SERVE_S:
            datas = [dataset("yelp", n_per, seed=s) for s in range(S)]
            svc = ParseService(tiers=(S,), start=False)

            def wave():
                ts = [svc.submit(cfg, [d], partition_bytes=partition_bytes,
                                 max_carry_bytes=max_carry_bytes)
                      for d in datas]
                t0 = time.perf_counter()
                svc.step()
                dt = time.perf_counter() - t0
                for t in ts:          # channels were filled during step()
                    for _ in t.results():
                        pass
                return ts, dt

            wave()                    # warm-up: compiles the tier-S step
            ts, dt = wave()
            builds = svc.registry.session_builds
            svc.close()
            per = [t.stats.bytes_in / dt for t in ts]
            total_bytes = sum(t.stats.bytes_in for t in ts)
            entry["variants"][f"{backend}/S{S}"] = {
                "s_total": dt,
                "gbps": gbps(total_bytes, dt),
                "records": sum(t.stats.records for t in ts),
                "bytes": total_bytes,
                "bytes_reparsed": sum(t.stats.bytes_reparsed for t in ts),
                "n_records_per_tenant": n_per,
                "fairness": min(per) / max(per),
                "session_builds": builds,
            }
            emit(f"serve/{backend}/S{S}", dt * 1e6,
                 f"{gbps(total_bytes, dt):.3f}GB/s;fairness="
                 f"{min(per) / max(per):.3f};session_builds={builds}")
    return entry


#: Distributed-workload device counts (virtual XLA host devices, one
#: subprocess per count so the topology override never leaks).
DIST_DEVICES = (1, 2, 4, 8)


def _dist_variants(backends):
    """reference + pallas staged + pallas megakernel, per the CLI filter."""
    out = []
    if "reference" in backends:
        out.append("reference")
    if "pallas" in backends:
        out += ["pallas", "pallas-fused"]
    return out


def distributed_child(n_records, backends):
    """Runs INSIDE the per-D subprocess (``--_distributed-child``): the
    mesh-sharded end-to-end sweep on this process's device fleet, emitting
    one JSON object on stdout for the parent to aggregate."""
    from jax.sharding import Mesh
    from repro.core.distributed import DistributedParser
    from repro.launch.dryrun import parse_collective_bytes

    mesh = Mesh(np.array(jax.devices()), ("data",))
    out = {"devices": len(jax.devices()), "workloads": {}}
    for kind, mk in (("yelp", yelp_parser), ("taxi", taxi_parser)):
        wl = {"variants": {}}
        for variant in _dist_variants(backends):
            kw = (dict(backend="pallas", fuse_pipeline=True)
                  if variant == "pallas-fused" else dict(backend=variant))
            n = (n_records if variant == "reference"
                 else max(n_records // 4, 16))
            if kind == "taxi":
                n *= 4
            data = dataset(kind, n)
            p = mk(max_records=1 << 12, **kw)
            dp = DistributedParser(p.cfg, mesh)
            chunks = dp.prepare(data)
            for _ in range(2):  # compile + warm
                jax.block_until_ready(dp.parse_chunks(chunks))
            best, sh = float("inf"), None
            for _ in range(3):
                t0 = time.perf_counter()
                sh = dp.parse_chunks(chunks)
                jax.block_until_ready(sh)
                best = min(best, time.perf_counter() - t0)
            # collective accounting on the compiled sharded executable —
            # the cross-device traffic must be summary-sized (O(D*|S|))
            totals, counts = parse_collective_bytes(
                dp.lower(*chunks.shape).compile().as_text())
            # sharded_vs_single bit-identity pin: host-assembled Arrow
            # table vs the single-device Parser export, byte for byte
            ref = p.to_arrow(p.parse_chunks(jnp.asarray(p.prepare(data))))
            got = dp.assemble(sh)
            match = (got.keys() == ref.keys()) and all(
                got[c].keys() == ref[c].keys()
                and all(np.array_equal(np.asarray(got[c][k]),
                                       np.asarray(ref[c][k]))
                        for k in got[c])
                for c in got)
            wl["variants"][variant] = {
                "n_records": n,
                "bytes": len(data),
                "us_per_call": best * 1e6,
                "gbps": gbps(len(data), best),
                "collective_bytes": totals,
                "collective_counts": counts,
                "sharded_vs_single": bool(match),
            }
        out["workloads"][kind] = wl
    print(json.dumps(out))


def distributed_sweep(n_records=250, backends=("reference", "pallas"),
                      devices=DIST_DEVICES):
    """Mesh-sharded end-to-end workload: GB/s over D ∈ {1, 2, 4, 8} virtual
    devices on yelp + taxi, one subprocess per D (the host-platform device
    override must be set before jax initialises, so it can never run in
    this process).  Per variant the child also records the compiled
    executable's collective byte/instruction counts (the O(D·|S|)
    accountability metric) and the ``sharded_vs_single`` bit-identity pin
    (``DistributedParser.assemble`` vs ``Parser.to_arrow``).  On this
    interpret-mode container the GB/s rows are correctness-under-load
    datapoints; the collective counts and the bit-identity pin are the
    real per-PR signal."""
    import os
    import subprocess
    import sys

    backend_arg = ("all" if set(backends) >= {"reference", "pallas"}
                   else backends[0])
    entry = {"n_records_base": n_records, "per_device": {}}
    for d in devices:
        env = dict(os.environ)
        kept = [f for f in env.get("XLA_FLAGS", "").split()
                if not f.startswith("--xla_force_host_platform_device_count=")]
        env["XLA_FLAGS"] = " ".join(
            kept + [f"--xla_force_host_platform_device_count={d}"])
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (env.get("PYTHONPATH"), "src") if p)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_parser",
             "--_distributed-child", str(d), "--records", str(n_records),
             "--backend", backend_arg, "--json", ""],
            capture_output=True, text=True, timeout=1800, env=env)
        if proc.returncode:
            raise RuntimeError(
                f"distributed child D={d} failed:\n{proc.stderr[-4000:]}")
        child = json.loads(proc.stdout.splitlines()[-1])
        if child.get("devices") != d:
            # topology unavailable on this platform: record an explicit
            # skip instead of silently benchmarking the wrong mesh
            entry["per_device"][f"D{d}"] = "skipped"
            emit(f"distributed/D{d}", 0.0, "skipped")
            continue
        entry["per_device"][f"D{d}"] = child
        for kind, wl in child["workloads"].items():
            for variant, v in wl["variants"].items():
                emit(f"distributed/D{d}/{kind}/{variant}",
                     v["us_per_call"],
                     f"{v['gbps']:.3f}GB/s;collective_bytes="
                     f"{sum(v['collective_bytes'].values())};match="
                     f"{v['sharded_vs_single']}")
    return entry


def fig12_partition_size():
    data = dataset("yelp", N_YELP * 2)
    for part_kib in (64, 256, 1024):
        p = yelp_parser(max_records=1 << 13)
        sp = StreamingParser(p, part_kib * 1024, max_carry_bytes=1 << 16)
        for _ in sp.parse_stream([data]):  # warm-up: compile the partition shape
            pass
        t0 = time.perf_counter()
        n = 0
        for _, nrec in sp.parse_stream([data]):
            n += nrec
        dt = time.perf_counter() - t0
        emit(f"fig12/yelp/part{part_kib}KiB", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s;records={n}")


def _baseline_python_csv(data: bytes, kind: str):
    rows = list(pycsv.reader(io.StringIO(data.decode())))
    # include type conversion like ParPaRaw does
    if kind == "yelp":
        for r in rows:
            int(r[0]); int(r[1]); int(r[2]); r[3]; r[4]
    else:  # taxi: ints/floats/dates per TAXI_SCHEMA
        for r in rows:
            int(r[0]); r[1]; r[2]; int(r[3]); float(r[4])
            int(r[5]); int(r[6]); int(r[7])
            for x in r[8:15]:
                float(x)
            int(r[15]); float(r[16])
    return len(rows)


def _baseline_numpy_split(data: bytes):
    """Constrained splitter (no quote support — the format-specific trick the
    paper's §2 baselines use; WRONG on quoted yelp data, shown for rate only)."""
    arr = np.frombuffer(data, np.uint8)
    newlines = np.flatnonzero(arr == ord("\n"))
    commas = np.flatnonzero(arr == ord(","))
    return len(newlines) + 0 * len(commas)


def _baseline_chunked_newline(data: bytes, n_threads=8):
    """Mühlbauer-style chunking: split at newlines after chunk boundaries,
    then sequential-parse each chunk (here: single-core loop standing in for
    the thread pool; counts records only)."""
    n = len(data)
    bounds = [0]
    for i in range(1, n_threads):
        pos = data.find(b"\n", i * n // n_threads)
        bounds.append(pos + 1 if pos >= 0 else n)
    bounds.append(n)
    total = 0
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        total += data.count(b"\n", lo, hi)
    return total


def fig13_end_to_end():
    for kind in ("yelp", "taxi"):
        data = dataset(kind, N_YELP if kind == "yelp" else N_TAXI)
        p = yelp_parser() if kind == "yelp" else taxi_parser(max_records=1 << 13)
        sp = StreamingParser(p, 1 << 18, max_carry_bytes=1 << 16)
        sp.parse_all([data])  # warm-up: compile the partition shape
        t0 = time.perf_counter()
        out = sp.parse_all([data])
        dt_par = time.perf_counter() - t0
        emit(f"fig13/{kind}/parparaw", dt_par * 1e6, f"{gbps(len(data), dt_par):.3f}GB/s")

        t0 = time.perf_counter()
        _baseline_python_csv(data, kind)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/python_csv", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s;speedup={dt/dt_par:.2f}x")

        t0 = time.perf_counter()
        _baseline_numpy_split(data)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/numpy_split_constrained", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s")

        t0 = time.perf_counter()
        _baseline_chunked_newline(data)
        dt = time.perf_counter() - t0
        emit(f"fig13/{kind}/chunked_newline_constrained", dt * 1e6,
             f"{gbps(len(data), dt):.3f}GB/s")


def run():
    fig9_chunk_size()
    fig10_input_size()
    fig11_tagging_modes()
    materialize_sweep()
    fig12_partition_size()
    fig13_end_to_end()


def tuned_regressions(report):
    """All ``tuned_vs_default`` entries in ``report`` whose ``no_slower``
    gate failed, as ``(path, ratio_dict)`` pairs — the ``--check-tuned``
    walk (recursive: covers the flat per-backend blocks and the stream
    sweep's nested per-S blocks alike)."""
    bad = []

    def walk(node, path):
        if not isinstance(node, dict):
            return
        for key, val in node.items():
            here = f"{path}/{key}" if path else key
            if key == "tuned_vs_default":
                for leaf_path, leaf in _ratio_leaves(val, here):
                    if not leaf.get("no_slower", True):
                        bad.append((leaf_path, leaf))
            else:
                walk(val, here)

    def _ratio_leaves(node, path):
        if isinstance(node, dict) and "no_slower" in node:
            yield path, node
        elif isinstance(node, dict):
            for key, val in node.items():
                yield from _ratio_leaves(val, f"{path}/{key}")

    walk(report, "")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="all",
                    choices=["all", "reference", "pallas"])
    ap.add_argument("--workload", default="all",
                    choices=["all", "yelp", "taxi", "formats", "stream",
                             "serve", "distributed"])
    ap.add_argument("--json", default="BENCH_parser.json", metavar="PATH",
                    help="machine-readable sweep output ('' to skip)")
    ap.add_argument("--records", type=int, default=250,
                    help="yelp record count (taxi runs 4x this; the stream "
                         "workload runs this many records per stream)")
    ap.add_argument("--figs", action="store_true",
                    help="also run the paper-figure suites (9-13)")
    ap.add_argument("--tuned", action="store_true",
                    help="add autotuned (cache-resolved) variants and "
                         "tuned_vs_default ratios to yelp/taxi, formats and "
                         "stream workloads")
    ap.add_argument("--check-tuned", action="store_true",
                    help="with --tuned: exit non-zero if any tuned config "
                         "is >5%% slower than its default")
    ap.add_argument("--_distributed-child", type=int, default=None,
                    dest="distributed_child", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    backends = ("reference", "pallas") if args.backend == "all" else (args.backend,)
    if args.distributed_child is not None:
        # subprocess mode: the per-D mesh sweep body (see distributed_sweep)
        distributed_child(args.records, backends)
        return
    workloads = (("yelp", "taxi", "formats", "stream", "serve", "distributed")
                 if args.workload == "all" else (args.workload,))
    print("name,us_per_call,derived")
    mat = tuple(w for w in workloads if w in ("yelp", "taxi"))
    if mat:
        report = materialize_sweep(n_records=args.records, backends=backends,
                                   workloads=mat, json_path="",
                                   tuned=args.tuned)
    else:
        report = _base_report(args.records)
    if "formats" in workloads:
        report["workloads"]["formats"] = formats_sweep(
            n_records=args.records, backends=backends, tuned=args.tuned)
    if "stream" in workloads:
        report["workloads"]["stream"] = stream_sweep(
            n_records=args.records, backends=backends, tuned=args.tuned)
    if "serve" in workloads:
        report["workloads"]["serve"] = serve_sweep(
            n_records=args.records, backends=backends)
    if "distributed" in workloads:
        report["workloads"]["distributed"] = distributed_sweep(
            n_records=args.records, backends=backends)
    if args.json:
        if args.workload != "all" and os.path.exists(args.json):
            # partial runs merge into the existing log instead of dropping
            # the other workloads' rows; meta keeps full-run provenance
            with open(args.json) as f:
                old = json.load(f)
            old.setdefault("workloads", {}).update(report["workloads"])
            report = old
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
    if args.figs:
        fig9_chunk_size()
        fig10_input_size()
        fig11_tagging_modes()
        fig12_partition_size()
        fig13_end_to_end()
    if args.check_tuned:
        bad = tuned_regressions(report)
        for path, leaf in bad:
            print(f"# TUNED REGRESSION {path}: "
                  f"{leaf.get('speedup', float('nan')):.2f}x vs default")
        if bad:
            raise SystemExit(1)
        print("# check-tuned: all tuned configs within the 5% gate")


if __name__ == "__main__":
    main()
