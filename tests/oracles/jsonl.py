"""Sequential oracle for ``make_jsonl_dfa`` (JSON Lines).

One top-level object per line.  Depth-1 ``,`` and ``:`` delimit fields
(alternating key/value columns); depth-1 string quotes and spaces are
dropped; escapes are kept raw (``\\"`` does not close a string but no
unescaping happens).  A nested container is one field holding its raw
JSON subtext, brackets included, up to ``max_depth``.  Blank lines
produce no records.  Raises ``ValueError`` exactly where the DFA falls
into its INV sink: newline inside a string or nested value, stray ``\\``
or ``]`` at depth 1, nesting beyond ``max_depth``, junk after the
record's closing ``}``, a record not opening with ``{``.
"""
from __future__ import annotations

from typing import List

LF, SP = 0x0A, 0x20
QUOTE, BSLASH = ord('"'), ord("\\")
COMMA, COLON = ord(","), ord(":")
LBRACE, RBRACE, LBRACK, RBRACK = ord("{"), ord("}"), ord("["), ord("]")


def parse(data: bytes, max_depth: int = 4) -> List[List[bytes]]:
    if not data or data[-1] != LF:
        data += b"\n"

    records: List[List[bytes]] = []
    fields: List[bytes] = []
    cur = bytearray()
    state = "EOR"
    depth = 0

    def end_field():
        fields.append(bytes(cur))
        cur.clear()

    def end_record():
        nonlocal fields
        end_field()
        records.append(fields)
        fields = []

    for b in data:
        if state == "EOR":
            if b in (LF, SP):
                pass  # blank lines / leading spaces: nothing
            elif b == LBRACE:
                state = "OBJ"
            else:
                raise ValueError("record must open with '{'")
        elif state == "OBJ":  # depth 1, outside strings: the tagging level
            if b == QUOTE:
                state = "STR"
            elif b in (COMMA, COLON):
                end_field()
            elif b == SP:
                pass
            elif b in (LBRACE, LBRACK):
                depth = 2
                cur.append(b)
                state = "NEST"
            elif b == RBRACE:
                state = "DONE"
            elif b in (LF, BSLASH, RBRACK):
                raise ValueError("invalid byte at depth 1")
            else:
                cur.append(b)  # unquoted token: numbers, true/false/null
        elif state == "STR":  # depth-1 string: quotes dropped, escapes raw
            if b == QUOTE:
                state = "OBJ"
            elif b == BSLASH:
                cur.append(b)
                state = "ESC"
            elif b == LF:
                raise ValueError("newline inside string")
            else:
                cur.append(b)
        elif state == "ESC":
            if b == LF:
                raise ValueError("newline inside escape")
            cur.append(b)
            state = "STR"
        elif state == "DONE":  # record object closed; spaces then newline
            if b == LF:
                end_record()
                state = "EOR"
            elif b == SP:
                pass
            else:
                raise ValueError("junk after closing '}'")
        elif state == "NEST":  # nested container: raw subtext, brackets kept
            if b in (LBRACE, LBRACK):
                if depth >= max_depth:
                    raise ValueError("nesting beyond max_depth")
                depth += 1
                cur.append(b)
            elif b in (RBRACE, RBRACK):  # closers not matched by type
                cur.append(b)
                depth -= 1
                if depth == 1:
                    state = "OBJ"
            elif b == QUOTE:
                cur.append(b)
                state = "NSTR"
            elif b in (LF, BSLASH):
                raise ValueError("invalid byte in nested value")
            else:
                cur.append(b)
        elif state == "NSTR":  # nested string: quotes are raw subtext
            if b == QUOTE:
                cur.append(b)
                state = "NEST"
            elif b == BSLASH:
                cur.append(b)
                state = "NESC"
            elif b == LF:
                raise ValueError("newline inside nested string")
            else:
                cur.append(b)
        else:  # NESC
            if b == LF:
                raise ValueError("newline inside nested escape")
            cur.append(b)
            state = "NSTR"
    return records
