"""Sequential oracle for ``make_log_dfa`` (Common-Log-Format-style).

Space-delimited fields with two independent enclosing scopes: ``[...]``
and ``"..."``.  Inside either scope spaces and newlines are field data;
the open/close bytes themselves are dropped (CONTROL).  Quirks mirrored
from the DFA tables: a stray ``]`` outside brackets is plain data, a ``"``
inside ``[...]`` is dropped without leaving the bracket scope, closing a
scope returns to the *same* field (``a[b]c`` is one field ``abc``), every
space delimits (runs mint empty fields) and a blank line is a record with
one empty field.
"""
from __future__ import annotations

from typing import List

LF, SP = 0x0A, 0x20
QUOTE, LB, RB = ord('"'), ord("["), ord("]")


def parse(data: bytes) -> List[List[bytes]]:
    if not data or data[-1] != LF:
        data += b"\n"

    records: List[List[bytes]] = []
    fields: List[bytes] = []
    cur = bytearray()
    state = "TOP"  # EOR/FLD/EOF share one behaviour in this dialect

    for b in data:
        if state == "TOP":
            if b == LF:
                fields.append(bytes(cur)); cur.clear()
                records.append(fields); fields = []
            elif b == SP:
                fields.append(bytes(cur)); cur.clear()
            elif b == QUOTE:
                state = "QUO"
            elif b == LB:
                state = "BRK"
            else:
                cur.append(b)  # stray ']' included: plain data
        elif state == "QUO":
            if b == QUOTE:
                state = "TOP"
            else:
                cur.append(b)  # newlines, spaces, brackets: data
        else:  # BRK
            if b == RB:
                state = "TOP"
            elif b == QUOTE:
                pass  # '"' inside [...]: dropped, scope continues
            else:
                cur.append(b)
    return records
