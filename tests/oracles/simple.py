"""Sequential oracle for ``make_simple_dfa`` (quote-free delimited).

Every newline is a record delimiter and every delimiter byte a field
delimiter — no quoting, no comments, so the oracle is a plain two-level
split after mirroring the parser's trailing-newline append.  A blank line
is a record with one empty field.
"""
from __future__ import annotations

from typing import List

LF = 0x0A


def parse(data: bytes, delimiter: bytes = b",") -> List[List[bytes]]:
    if not data or data[-1] != LF:
        data += b"\n"
    return [line.split(delimiter) for line in data.split(b"\n")[:-1]]
