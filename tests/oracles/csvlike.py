"""Sequential oracle for the RFC 4180 CSV dialect of ``make_csv_dfa``.

Covers the plain, comment-enabled and alternate-delimiter (TSV) variants.
Semantics mirrored: quote enclosure (delimiters/newlines inside quotes are
data), doubled-quote unescaping, CR as structural outside quotes and data
inside, ``#`` opening a comment only at start-of-record (comment lines
produce no records), and the parser's trailing-newline append.
"""
from __future__ import annotations

from typing import List, Optional

LF, CR = 0x0A, 0x0D


def parse(data: bytes, delimiter: bytes = b",", quote: bytes = b'"',
          comment: Optional[bytes] = None,
          handle_cr: bool = True) -> List[List[bytes]]:
    d, q = delimiter[0], quote[0]
    c = comment[0] if comment is not None else None
    if not data or data[-1] != LF:
        data += b"\n"

    records: List[List[bytes]] = []
    fields: List[bytes] = []
    cur = bytearray()
    state = "EOR"

    def end_field():
        fields.append(bytes(cur))
        cur.clear()

    def end_record():
        nonlocal fields
        fields.append(bytes(cur))
        cur.clear()
        records.append(fields)
        fields = []

    for b in data:
        if state == "EOR":
            if b == LF:
                end_record()
            elif b == q:
                state = "ENC"
            elif b == d:
                end_field(); state = "EOF"
            elif c is not None and b == c:
                state = "CMT"
            elif handle_cr and b == CR:
                pass
            else:
                cur.append(b); state = "FLD"
        elif state == "ENC":
            if b == q:
                state = "ESC"
            else:
                cur.append(b)  # delimiters, newlines, CR: data inside quotes
        elif state == "ESC":
            if b == q:
                cur.append(q); state = "ENC"  # doubled quote -> one literal
            elif b == LF:
                end_record(); state = "EOR"
            elif b == d:
                end_field(); state = "EOF"
            elif handle_cr and b == CR:
                pass
            else:
                raise ValueError(f"junk byte {b:#x} after closing quote")
        elif state == "FLD":
            if b == LF:
                end_record(); state = "EOR"
            elif b == d:
                end_field(); state = "EOF"
            elif b == q:
                raise ValueError("quote inside unquoted field")
            elif handle_cr and b == CR:
                pass
            else:
                cur.append(b)  # '#' mid-record is plain data
        elif state == "EOF":
            if b == LF:
                end_record(); state = "EOR"
            elif b == q:
                state = "ENC"
            elif b == d:
                end_field()
            elif handle_cr and b == CR:
                pass
            else:
                cur.append(b); state = "FLD"  # '#' after a delim is data too
        else:  # CMT: swallow to newline; comment lines emit no record
            if b == LF:
                state = "EOR"
    return records
