"""Pure-Python sequential oracles, one per registered format.

Each oracle is an independent character-level parser of the format's
*documented dialect* (the semantics written on the ``make_*_dfa``
docstrings in ``src/repro/core/dfa.py``) — a sequential mirror of what the
massively parallel engine must produce, written without the DFA tables so
the comparison is not circular.

Contract: ``parse(data: bytes) -> list[list[bytes]]`` — the complete
records (each a list of field byte-strings) that ``Parser.parse(data)``
reports, including the parser's trailing-record-delimiter append and the
format's unquoting/field-collapsing rules.  Oracles raise ``ValueError``
on input that would hit a DFA's invalid sink — test generators only ever
produce well-formed input.

Importing this package attaches every oracle to the core format registry
(``repro.core.formats.attach_oracle``), filling the registry's oracle slot
so ``tests/test_format_conformance.py`` can sweep every registered format
generically.
"""
from repro.core import formats as formats_mod

from tests.oracles import clf, csvlike, jsonl, simple, zone

ORACLES = {
    "csv": csvlike.parse,
    "csv+comment": lambda data: csvlike.parse(data, comment=b"#"),
    "tsv": lambda data: csvlike.parse(data, delimiter=b"\t"),
    "simple": simple.parse,
    "clf": clf.parse,
    "jsonl": jsonl.parse,
    "zone": zone.parse,
}

for _name, _fn in ORACLES.items():
    formats_mod.attach_oracle(_name, _fn)
