"""Sequential oracle for ``make_zone_dfa`` (DNS zone files).

Whitespace-delimited resource records: only the first space/tab after
field content delimits (runs collapse, leading whitespace is skipped, no
empty fields are minted).  ``;`` opens a comment to end of line — on a
contentless line the record is suppressed entirely; after content the
comment's newline ends the record.  ``(`` turns newlines into whitespace
until ``)`` so one record spans lines; a comment inside parens resumes
the record on the next line, and a ``;`` or ``)`` directly after in-paren
field content delimits that field.  Stray ``)`` at top level and nested
``(`` are plain data.  A record ending in ``)`` carries one trailing
empty field (the whitespace before ``)`` already delimited) — the
schema's n_cols clamp drops it.
"""
from __future__ import annotations

from typing import List

LF, SP, TAB = 0x0A, 0x20, 0x09
SEMI, LP, RP = ord(";"), ord("("), ord(")")


def parse(data: bytes) -> List[List[bytes]]:
    if not data or data[-1] != LF:
        data += b"\n"

    records: List[List[bytes]] = []
    fields: List[bytes] = []
    cur = bytearray()
    state = "EOR"

    def end_field():
        fields.append(bytes(cur))
        cur.clear()

    def end_record():
        nonlocal fields
        end_field()
        records.append(fields)
        fields = []

    for b in data:
        if state == "EOR":  # start of line, no record content yet
            if b in (LF, SP, TAB):
                pass
            elif b == SEMI:
                state = "CM0"
            elif b == LP:
                state = "POF"
            else:
                cur.append(b)  # stray ')' included: plain data
                state = "FLD"
        elif state == "FLD":  # inside a top-level field
            if b == LF:
                end_record()
                state = "EOR"
            elif b in (SP, TAB):
                end_field()
                state = "EOF"
            elif b == SEMI:
                state = "CMT"  # field closed by the record delim to come
            elif b == LP:
                end_field()
                state = "POF"
            else:
                cur.append(b)
        elif state == "EOF":  # in a whitespace run after a delimiter
            if b == LF:
                end_record()
                state = "EOR"
            elif b in (SP, TAB):
                pass  # run collapses: no empty fields
            elif b == SEMI:
                state = "CMT"
            elif b == LP:
                state = "POF"
            else:
                cur.append(b)
                state = "FLD"
        elif state == "CMT":  # comment after content: newline ends record
            if b == LF:
                end_record()
                state = "EOR"
        elif state == "CM0":  # comment on contentless line: no record
            if b == LF:
                state = "EOR"
        elif state == "POF":  # inside parens, whitespace context
            if b in (LF, SP, TAB):
                pass
            elif b == SEMI:
                state = "PCM"
            elif b == RP:
                state = "EOF"
            else:
                cur.append(b)  # nested '(' included: plain data
                state = "PFD"
        elif state == "PFD":  # inside parens, inside a field
            if b in (LF, SP, TAB):
                end_field()
                state = "POF"
            elif b == SEMI:
                end_field()
                state = "PCM"
            elif b == RP:
                end_field()
                state = "EOF"
            else:
                cur.append(b)
        else:  # PCM: comment inside parens — record resumes next line
            if b == LF:
                state = "POF"
    return records
