"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only launch/dryrun.py
fakes 512 devices (in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_csv_table(rng, n_rows, dtypes, quote_prob=0.5, newline_prob=0.2,
                     empty_prob=0.1):
    """Generate a random table + its RFC4180 CSV encoding via Python's csv
    module (the gold-standard oracle)."""
    import csv as pycsv
    import io

    rows = []
    for _ in range(n_rows):
        row = []
        for dt in dtypes:
            if rng.random() < empty_prob:
                row.append("")
            elif dt == "int32":
                row.append(str(int(rng.integers(-(10**8), 10**8))))
            elif dt == "float32":
                v = float(rng.normal()) * 10 ** int(rng.integers(-3, 6))
                row.append(f"{v:.6g}")
            elif dt == "date":
                y, m, d = int(rng.integers(1970, 2037)), int(rng.integers(1, 13)), int(rng.integers(1, 29))
                if rng.random() < 0.5:
                    row.append(f"{y:04d}-{m:02d}-{d:02d}")
                else:
                    hh, mm, ss = (int(rng.integers(0, x)) for x in (24, 60, 60))
                    row.append(f"{y:04d}-{m:02d}-{d:02d} {hh:02d}:{mm:02d}:{ss:02d}")
            else:
                n = int(rng.integers(0, 30))
                alphabet = list("abcXYZ 09_-+.;")
                if rng.random() < quote_prob:
                    alphabet += ['"', ","]
                if rng.random() < newline_prob:
                    alphabet += ["\n"]
                row.append("".join(rng.choice(alphabet) for _ in range(n)))
        rows.append(row)
    buf = io.StringIO()
    w = pycsv.writer(buf, quoting=pycsv.QUOTE_MINIMAL, lineterminator="\n")
    w.writerows(rows)
    return rows, buf.getvalue().encode()
