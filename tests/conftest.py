"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; multi-device tests
go through :func:`run_with_devices`, which isolates the
``--xla_force_host_platform_device_count`` override in a subprocess."""
import os
import subprocess
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

#: Subprocess exit code meaning "requested device count unavailable" —
#: mapped to pytest.skip (an explicit skip, never a false pass).
DEVICE_SKIP_RC = 77


def run_with_devices(code: str, n_devices: int, timeout: float = 900,
                     env: dict = None) -> str:
    """Run ``code`` in a subprocess under ``n_devices`` virtual XLA host
    devices and return its stdout.

    The override goes through the child's environment (set *before* any
    jax import, the only reliable ordering) so it never leaks into this
    process.  The child double-checks ``len(jax.devices())`` and exits
    ``DEVICE_SKIP_RC`` on a mismatch (e.g. a platform where the host
    override is ignored), which surfaces here as ``pytest.skip`` — an
    explicit skip instead of silently testing the wrong topology.
    ``N_DEVICES`` is predefined in the child's namespace.
    """
    child_env = dict(os.environ, **(env or {}))
    kept = [f for f in child_env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count=")]
    kept.append(f"--xla_force_host_platform_device_count={int(n_devices)}")
    child_env["XLA_FLAGS"] = " ".join(kept)
    prelude = textwrap.dedent(f"""
        import sys
        sys.path.insert(0, {_SRC!r})
        import jax
        if len(jax.devices()) != {int(n_devices)}:
            sys.exit({DEVICE_SKIP_RC})
        N_DEVICES = {int(n_devices)}
    """)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=child_env)
    if proc.returncode == DEVICE_SKIP_RC:
        pytest.skip(f"{n_devices} XLA host devices unavailable")
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_csv_table(rng, n_rows, dtypes, quote_prob=0.5, newline_prob=0.2,
                     empty_prob=0.1):
    """Generate a random table + its RFC4180 CSV encoding via Python's csv
    module (the gold-standard oracle)."""
    import csv as pycsv
    import io

    rows = []
    for _ in range(n_rows):
        row = []
        for dt in dtypes:
            if rng.random() < empty_prob:
                row.append("")
            elif dt == "int32":
                row.append(str(int(rng.integers(-(10**8), 10**8))))
            elif dt == "float32":
                v = float(rng.normal()) * 10 ** int(rng.integers(-3, 6))
                row.append(f"{v:.6g}")
            elif dt == "date":
                y, m, d = int(rng.integers(1970, 2037)), int(rng.integers(1, 13)), int(rng.integers(1, 29))
                if rng.random() < 0.5:
                    row.append(f"{y:04d}-{m:02d}-{d:02d}")
                else:
                    hh, mm, ss = (int(rng.integers(0, x)) for x in (24, 60, 60))
                    row.append(f"{y:04d}-{m:02d}-{d:02d} {hh:02d}:{mm:02d}:{ss:02d}")
            else:
                n = int(rng.integers(0, 30))
                alphabet = list("abcXYZ 09_-+.;")
                if rng.random() < quote_prob:
                    alphabet += ['"', ","]
                if rng.random() < newline_prob:
                    alphabet += ["\n"]
                row.append("".join(rng.choice(alphabet) for _ in range(n)))
        rows.append(row)
    buf = io.StringIO()
    w = pycsv.writer(buf, quoting=pycsv.QUOTE_MINIMAL, lineterminator="\n")
    w.writerows(rows)
    return rows, buf.getvalue().encode()
