"""Differential fuzzing: both parser backends vs the Python csv/int/float
oracle (the harness §3.3's kernel completion is hardened by).

Random CSV tables — quoted fields, escaped quotes, embedded newlines, empty
and missing fields, signed/overflowing ints, exponent floats, valid and
invalid datetimes, unterminated tails — are parsed end-to-end on
``backend="reference"``, ``backend="pallas"``, and the pallas
whole-pipeline megakernel (``fuse_pipeline=True``), and cross-checked
field-by-field against Python's ``csv`` module + ``int()`` / ``float()`` /
``datetime`` oracles.  All backends must agree *bit-for-bit* (values,
``valid``, ``empty``, CSS, field index); the reference backend must agree
with the oracle up to documented semantics:

  * int32   — valid ⇔ ``[+-]?digits``, field ≤ ``int_width`` bytes, and
              |value| ≤ 2**31-1 (overflow clears ``valid``).
  * float32 — valid is structural (mantissa/dot/exponent shape, ≤
              ``float_width`` bytes); magnitude may round, overflow to ±inf,
              or flush to zero in the subnormal range.
  * date    — ``YYYY-MM-DD[ HH:MM:SS]`` (``T`` separator allowed) with real
              civil-calendar validation; epoch seconds within int32.
  * str     — bytes round-trip exactly (RFC 4180 unquoting/unescaping).

Two profiles: the deterministic seed sweep below runs in CI; the deep sweep
(more seeds, bigger tables) is ``-m slow``.  The hypothesis section runs
only where hypothesis is installed (CI); its CI profile is derandomized so
failures reproduce.
"""
import csv as pycsv
import datetime as dt
import io
import os
import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core import typeconv
from repro.kernels.numparse import ops as k_ops
from tests.test_backend_parity import _assert_results_equal

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without dev extras: numpy sweeps still run
    HAVE_HYPOTHESIS = False

INT32_MAX = 2**31 - 1
DTYPES = ("int32", "str", "float32", "date")
SCHEMA = Schema.of(("i", "int32"), ("s", "str"), ("f", "float32"), ("d", "date"))
MAX_RECORDS = 64
PAD_BYTES = 4096          # fixed byte capacity → one compiled shape per backend
CI_SEEDS = range(5)
DEEP_SEEDS = range(5, 25)

INT_RE = re.compile(r"\A[+-]?[0-9]+\Z")
FLOAT_RE = re.compile(
    r"\A[+-]?(?=[0-9]|\.[0-9])[0-9]*(\.[0-9]*)?([eE][+-]?[0-9]+)?\Z")
DATE_RE = re.compile(r"\A\d{4}-\d{2}-\d{2}([ T]\d{2}:\d{2}:\d{2})?\Z")


# ---------------------------------------------------------------------------
# oracles (documented parser semantics, in plain Python)
# ---------------------------------------------------------------------------

def oracle_int(s, width=11):
    """Returns (valid, value or None)."""
    if not INT_RE.match(s) or len(s) > width:
        return False, None
    v = int(s)
    if abs(v) > INT32_MAX:
        return False, None
    return True, v


def oracle_float_valid(s, width=24):
    return bool(FLOAT_RE.match(s)) and len(s) <= width


def oracle_date(s):
    """Returns (valid, epoch_seconds or None)."""
    if not DATE_RE.match(s):
        return False, None
    fmt = "%Y-%m-%d" if len(s) == 10 else f"%Y-%m-%d{s[10]}%H:%M:%S"
    try:
        d = dt.datetime.strptime(s, fmt).replace(tzinfo=dt.timezone.utc)
    except ValueError:  # day/month/time out of civil range
        return False, None
    return True, int(d.timestamp())


def check_float_value(s, got):
    """Value check for oracle-valid float fields, skipping the documented
    magnitude edges (overflow→inf asserted, subnormal flush skipped)."""
    want = float(s)
    if want == 0.0:
        if "e" not in s.lower():
            assert got == 0.0, (s, got)
        return
    if abs(want) > 3.5e38:
        assert np.isinf(got) and (got > 0) == (want > 0), (s, got)
        return
    if abs(want) < 1e-30:  # pow-flush zone
        return
    np.testing.assert_allclose(got, np.float32(want), rtol=2e-5, err_msg=s)


# ---------------------------------------------------------------------------
# table generator
# ---------------------------------------------------------------------------

_STR_ALPHABET = list("abcXYZ 09_-+.;")
_STR_SPICE = list('",\n')


def _gen_field(rng, dtype):
    r = rng.random()
    if r < 0.12:
        return ""  # empty / missing field
    if dtype == "int32":
        if r < 0.55:
            return str(int(rng.integers(-10**9, 10**9)))
        if r < 0.70:  # overflow boundary straddle
            return str(int(rng.integers(2**31 - 3, 2**31 + 3)) *
                       int(rng.choice([-1, 1])))
        if r < 0.85:
            return str(rng.choice(["9999999999", "12345678901", "0000000001",
                                   "+42", "-0", "007", "2147483647"]))
        return str(rng.choice(["x", "1x2", "--4", "+", "4 2", "1.5"]))
    if dtype == "float32":
        if r < 0.5:
            return f"{float(rng.normal()) * 10 ** int(rng.integers(-6, 7)):.6g}"
        if r < 0.7:
            return f"{int(rng.integers(-9999, 9999))}e{int(rng.integers(-30, 31))}"
        if r < 0.85:
            return str(rng.choice(["+.5", "-.5", "3.", "1e39", "-1e39",
                                   "1.5e+06", "0.25", "1E-3"]))
        return str(rng.choice([".", "1e", "1e+", "1.2.3", "nan", "inf", "x.5"]))
    if dtype == "date":
        y, m, d = (int(rng.integers(1902, 2038)), int(rng.integers(1, 13)),
                   int(rng.integers(1, 32)))
        if r < 0.5:
            return f"{y:04d}-{m:02d}-{d:02d}"
        if r < 0.8:
            hh, mm, ss = (int(rng.integers(0, 25)), int(rng.integers(0, 61)),
                          int(rng.integers(0, 61)))
            sep = " " if rng.random() < 0.7 else "T"
            return f"{y:04d}-{m:02d}-{d:02d}{sep}{hh:02d}:{mm:02d}:{ss:02d}"
        return str(rng.choice(["2024-02-30", "2023-02-29", "2024-04-31",
                               "2024-1-01", "junk", "2024-01-01 00:00"]))
    # str
    n = int(rng.integers(0, 13))
    alphabet = _STR_ALPHABET + (_STR_SPICE if rng.random() < 0.5 else [])
    return "".join(str(c) for c in rng.choice(alphabet, size=n))


def make_table(seed, n_rows):
    rng = np.random.default_rng(seed)
    rows = [[_gen_field(rng, d) for d in DTYPES] for _ in range(n_rows)]
    buf = io.StringIO()
    pycsv.writer(buf, quoting=pycsv.QUOTE_MINIMAL, lineterminator="\n").writerows(rows)
    text = buf.getvalue()
    if rng.random() < 0.4:
        text = text[:-1]  # unterminated tail record
    # generator/oracle self-check: csv must round-trip the exact fields
    assert [r for r in pycsv.reader(io.StringIO(text))] == rows
    return rows, text.encode()


# ---------------------------------------------------------------------------
# end-to-end differential harness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def parsers():
    parsers = {
        be: Parser(ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA,
                                max_records=MAX_RECORDS, chunk_size=64,
                                backend=be,
                                # pin the radix partition kernel on pallas so
                                # the fuzz sweep covers the kernel path
                                # (interpret-mode "auto" picks the jnp pass)
                                partition_impl="kernel" if be == "pallas" else "auto"))
        for be in ("reference", "pallas")
    }
    # third axis: the whole-pipeline megakernel (fuse_pipeline=True) joins
    # every bit-for-bit comparison
    parsers["pallas-fused"] = Parser(ParserConfig(
        dfa=make_csv_dfa(), schema=SCHEMA, max_records=MAX_RECORDS,
        chunk_size=64, backend="pallas", partition_impl="kernel",
        fuse_pipeline=True))
    assert parsers["pallas-fused"].plan.execute_path == "fused"
    return parsers


def _check_against_oracle(rows, res, parser):
    assert int(res.validation.n_records) == len(rows)
    assert bool(res.validation.ok)
    arrow = parser.to_arrow(res)
    for c, (name, dtype) in enumerate(zip("isfd", DTYPES)):
        parsed = res.values[name]
        valid = np.asarray(parsed.valid)
        empty = np.asarray(parsed.empty)
        values = np.asarray(parsed.value)
        for r, row in enumerate(rows):
            s = row[c]
            assert bool(empty[r]) == (s == ""), (r, name, s)
            if dtype == "int32":
                want_ok, want = oracle_int(s)
                assert bool(valid[r]) == want_ok, (r, s)
                if want_ok:
                    assert int(values[r]) == want, (r, s)
            elif dtype == "float32":
                want_ok = oracle_float_valid(s)
                assert bool(valid[r]) == want_ok, (r, s)
                if want_ok:
                    check_float_value(s, values[r])
            elif dtype == "date":
                want_ok, want = oracle_date(s)
                assert bool(valid[r]) == want_ok, (r, s)
                if want_ok:
                    assert int(values[r]) == want, (r, s)
            else:  # str round-trips exactly through the CSS
                a = arrow[name]
                got = bytes(a["data"][a["offsets"][r]: a["offsets"][r + 1]])
                assert got == s.encode(), (r, s, got)


def _run_differential(parsers, seed, n_rows):
    rows, data = make_table(seed, n_rows)
    assert len(data) + 1 <= PAD_BYTES
    chunks = jnp.asarray(parsers["reference"].prepare(data, pad_to=PAD_BYTES))
    ref = parsers["reference"].parse_chunks(chunks)
    pal = parsers["pallas"].parse_chunks(chunks)
    fus = parsers["pallas-fused"].parse_chunks(chunks)
    _assert_results_equal(ref, pal, label=f"seed={seed}: ")  # bit-for-bit
    _assert_results_equal(ref, fus, label=f"seed={seed} fused: ")
    _check_against_oracle(rows, ref, parsers["reference"])


@pytest.mark.parametrize("seed", CI_SEEDS)
def test_differential_fuzz_ci(parsers, seed):
    """Deterministic CI profile: fixed seeds, fixed shapes (one compile)."""
    _run_differential(parsers, seed, n_rows=24)


@pytest.mark.slow
@pytest.mark.parametrize("seed", DEEP_SEEDS)
def test_differential_fuzz_deep(parsers, seed):
    _run_differential(parsers, seed, n_rows=40)


# ---------------------------------------------------------------------------
# hypothesis column-level differential (runs where hypothesis is installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "fuzz_ci", max_examples=25, derandomize=True, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "fuzz_deep", max_examples=200, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("FUZZ_PROFILE", "fuzz_ci"))

    N_FIELDS = 24      # fixed field count → fixed shapes → one compile
    CSS_BYTES = 512

    def _pack_fixed(strs):
        """Pad to N_FIELDS fields / CSS_BYTES bytes so shapes stay constant."""
        strs = (list(strs) + [""] * N_FIELDS)[:N_FIELDS]
        blob = "".join(strs).encode()
        lens = np.asarray([len(s) for s in strs], np.int32)
        offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
        css = np.zeros(CSS_BYTES, np.uint8)
        css[: len(blob)] = np.frombuffer(blob, np.uint8)
        return jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens), strs

    int_text = st.one_of(
        st.integers(-10**12, 10**12).map(str),
        st.from_regex(r"\A[+-]?[0-9]{1,12}\Z"),
        st.sampled_from(["", "+", "x1", "1 2", "007", "2147483648"]),
    )
    float_text = st.one_of(
        st.floats(allow_nan=False, allow_infinity=False, width=32,
                  min_value=-1e30, max_value=1e30).map(lambda v: f"{v:.6g}"),
        st.from_regex(r"\A[+-]?[0-9]{1,7}(\.[0-9]{0,6})?(e[+-]?[12]?[0-9])?\Z"),
        st.sampled_from(["", ".", "+.5", "1e", "1e+", "3.", "1e39"]),
    )
    date_text = st.one_of(
        st.tuples(st.integers(1902, 2037), st.integers(1, 13),
                  st.integers(1, 31)).map(lambda t: "%04d-%02d-%02d" % t),
        st.tuples(st.integers(1902, 2037), st.integers(1, 12),
                  st.integers(1, 28), st.integers(0, 24), st.integers(0, 60),
                  st.integers(0, 60)).map(
                      lambda t: "%04d-%02d-%02d %02d:%02d:%02d" % t),
        st.sampled_from(["", "junk", "2024-02-30", "2024-01-01T00:00:00"]),
    )

    @given(st.lists(int_text, min_size=1, max_size=N_FIELDS))
    def test_hypothesis_int_differential(strs):
        css, offs, lens, strs = _pack_fixed(strs)
        ref = typeconv.parse_int(css, offs, lens, width=11)
        pal = k_ops.parse_int_column(css, offs, lens, width=11)
        np.testing.assert_array_equal(np.asarray(ref.valid), np.asarray(pal.valid))
        ok = np.asarray(ref.valid)
        np.testing.assert_array_equal(np.asarray(ref.value)[ok],
                                      np.asarray(pal.value)[ok])
        for s, v, got in zip(strs, ok, np.asarray(ref.value)):
            want_ok, want = oracle_int(s)
            assert bool(v) == want_ok, s
            if want_ok:
                assert int(got) == want, s

    @given(st.lists(float_text, min_size=1, max_size=N_FIELDS))
    def test_hypothesis_float_differential(strs):
        css, offs, lens, strs = _pack_fixed(strs)
        ref = typeconv.parse_float(css, offs, lens, width=24)
        pal = k_ops.parse_float_column(css, offs, lens, width=24)
        np.testing.assert_array_equal(np.asarray(ref.valid), np.asarray(pal.valid))
        ok = np.asarray(ref.valid)
        np.testing.assert_array_equal(np.asarray(ref.value)[ok],
                                      np.asarray(pal.value)[ok])
        for s, v, got in zip(strs, ok, np.asarray(ref.value)):
            assert bool(v) == oracle_float_valid(s), s
            if v:
                check_float_value(s, got)

    @given(st.lists(date_text, min_size=1, max_size=N_FIELDS))
    def test_hypothesis_date_differential(strs):
        css, offs, lens, strs = _pack_fixed(strs)
        ref = typeconv.parse_date(css, offs, lens)
        pal = k_ops.parse_date_column(css, offs, lens)
        np.testing.assert_array_equal(np.asarray(ref.valid), np.asarray(pal.valid))
        np.testing.assert_array_equal(np.asarray(ref.value), np.asarray(pal.value))
        for s, v, got in zip(strs, np.asarray(ref.valid), np.asarray(ref.value)):
            want_ok, want = oracle_date(s)
            assert bool(v) == want_ok, s
            if want_ok:
                assert int(got) == want, s


# ---------------------------------------------------------------------------
# per-format differential fuzz (format registry × tests/oracles)
# ---------------------------------------------------------------------------
# Random *well-formed* text per dialect; expected output comes from the
# format's sequential oracle (tests/oracles/), so the generators only have
# to stay inside the dialect — they never track rows themselves.  All three
# backends must agree bit-for-bit; reference must match the oracle.

from repro.core import formats as formats_mod  # noqa: E402
from tests import oracles  # noqa: E402,F401 — attaches oracles to the registry

FORMAT_FUZZ = ("jsonl", "zone", "clf")
FORMAT_CI_SEEDS = range(3)
FORMAT_DEEP_SEEDS = range(3, 13)


def _join_tok(rng, alphabet, lo=1, hi=9):
    return "".join(str(c) for c in rng.choice(alphabet, size=int(rng.integers(lo, hi))))


def _j_string(rng):
    """A depth-1 JSONL string: structural bytes and raw escapes inside."""
    out = []
    for _ in range(int(rng.integers(0, 9))):
        r = rng.random()
        if r < 0.15:
            out.append("\\" + str(rng.choice(['"', "n", "\\", "t"])))
        elif r < 0.5:
            out.append(str(rng.choice([",", ":", "{", "}", "[", "]", " "])))
        else:
            out.append(str(rng.choice(list("abcXYZ09_-+."))))
    return '"' + "".join(out) + '"'


def _j_nested(rng, levels):
    """Raw nested subtext; bounded depth, closers not matched by type."""
    items = []
    for _ in range(int(rng.integers(0, 3))):
        r = rng.random()
        if r < 0.25 and levels > 1:
            items.append(_j_nested(rng, levels - 1))
        elif r < 0.55:
            items.append(_j_string(rng))
        else:
            items.append(str(int(rng.integers(-99, 100))))
    o, c = [("{", "}"), ("[", "]")][int(rng.integers(0, 2))]
    return o + str(rng.choice([", ", ",", " , "])).join(items) + c


def make_jsonl_text(seed, n_rows):
    rng = np.random.default_rng([seed, 1])
    sp = lambda: " " * int(rng.integers(0, 2))  # noqa: E731
    lines = []
    for _ in range(n_rows):
        idv = str(rng.choice([str(int(rng.integers(-10**10, 10**10))), "007",
                              "2147483648", "true", "null", "0"]))
        r = rng.random()
        if r < 0.5:
            name = _j_string(rng)
        elif r < 0.8:
            name = _j_nested(rng, levels=3)  # value opens depth 2 of max 4
        else:
            name = str(rng.choice(["null", "true", "12"]))
        score = str(rng.choice([f"{float(rng.normal()):.4g}", ".5", "2e3",
                                "1e39", "3.", "x", "-0.25"]))
        lines.append("{" + sp() + f'"id"{sp()}:{sp()}{idv}{sp()},{sp()}'
                     f'"name"{sp()}:{sp()}{name}{sp()},{sp()}'
                     f'"score"{sp()}:{sp()}{score}' + sp() + "}" + sp())
        if rng.random() < 0.15:
            lines.append(str(rng.choice(["", " ", "  "])))  # blank: no record
    text = "\n".join(lines) + "\n"
    if rng.random() < 0.3:
        text = text.rstrip("\n ")  # unterminated tail record
    return text.encode()


def make_zone_text(seed, n_rows):
    rng = np.random.default_rng([seed, 2])
    ws = lambda: "".join(  # noqa: E731
        str(rng.choice([" ", "\t"])) for _ in range(int(rng.integers(1, 3))))
    tok = lambda: _join_tok(rng, list("abcdXZ0189._-"))  # noqa: E731
    lines = []
    for _ in range(n_rows):
        if rng.random() < 0.2:
            lines.append(str(rng.choice(["", " ", ";full-line comment"])))
        ttl = str(rng.choice([str(int(rng.integers(0, 10**10))), "0042",
                              "2147483647", tok()]))
        toks = [tok(), ttl, str(rng.choice(["IN", "CH", "HS"])),
                str(rng.choice(["A", "TXT", "MX", "CNAME"])), tok()]
        lo = hi = None
        if rng.random() < 0.4:  # parenthesize a span: record spans lines
            i = int(rng.integers(1, 5))
            j = int(rng.integers(i, 5))
            toks = toks[:i] + ["("] + toks[i:j + 1] + [")"] + toks[j + 1:]
            lo, hi = i, j + 2
        out = []
        for k, t in enumerate(toks):
            out.append(t)
            if k == len(toks) - 1:
                break
            in_paren = lo is not None and lo <= k < hi
            near_paren = t in "()" or toks[k + 1] in "()"
            r = rng.random()
            if in_paren and r < 0.2:
                out.append(ws() + f";c{k}\n" + ws())  # in-paren comment
            elif in_paren and r < 0.5:
                out.append("\n" + ws())  # newline-as-whitespace
            elif near_paren and r < 0.65:
                out.append("")  # parens may abut field content
            else:
                out.append(ws())
        line = "".join(out)
        if rng.random() < 0.2:
            line += str(rng.choice(["", " "])) + ";trailing"
        lines.append(line)
    text = "\n".join(lines) + "\n"
    if rng.random() < 0.3:
        text = text.rstrip("\n\t ;gnilart")  # unterminated tail
    return text.encode()


def make_clf_text(seed, n_rows):
    rng = np.random.default_rng([seed, 3])
    lines = []
    for _ in range(n_rows):
        if rng.random() < 0.08:
            lines.append("")  # blank line: a record with one empty field
            continue
        host = _join_tok(rng, list("abcXYZ019.-_"))
        if rng.random() < 0.1:
            host += "]" + host  # stray ']' outside scopes is data
        ts_body = _join_tok(rng, list("abc019/: "), 1, 14)
        if rng.random() < 0.1:
            ts_body += '"ignored'  # '"' inside [...] is dropped
        if rng.random() < 0.08:
            ts_body += "\n "  # newline inside [...] is data
        req_body = _join_tok(rng, list("GETPOST /abc?=_."), 1, 14)
        if rng.random() < 0.1:
            req_body += str(rng.choice(["[", "]"]))  # brackets in quotes: data
        if rng.random() < 0.08:
            req_body += "\nx"  # newline inside quotes is data
        code = str(rng.choice([str(int(rng.integers(-999, 1000))), "200",
                               "40x", ""]))
        sep = "  " if rng.random() < 0.1 else " "  # runs mint empty fields
        lines.append(sep.join([host, f"[{ts_body}]", f'"{req_body}"', code]))
    text = "\n".join(lines) + "\n"
    if rng.random() < 0.3:
        text = text[:-1]
    return text.encode()


FORMAT_GENERATORS = {
    "jsonl": make_jsonl_text,
    "zone": make_zone_text,
    "clf": make_clf_text,
}


def _run_format_differential(fmt, seed, n_rows):
    # Late import: test_format_conformance imports this module for the typed
    # oracles, so the shared checker/parser cache loads at call time.
    from tests.test_format_conformance import (
        BACKENDS, _check_against_oracle, parser_for)
    spec = formats_mod.get_format(fmt)
    data = FORMAT_GENERATORS[fmt](seed, n_rows)
    records = spec.oracle(data)
    assert len(records) <= MAX_RECORDS and len(data) + 1 <= PAD_BYTES
    ps = {be: parser_for(fmt, be, spec.tagging) for be in BACKENDS}
    chunks = jnp.asarray(ps["reference"].prepare(data, pad_to=PAD_BYTES))
    ref = ps["reference"].parse_chunks(chunks)
    pal = ps["pallas"].parse_chunks(chunks)
    fus = ps["pallas-fused"].parse_chunks(chunks)
    _assert_results_equal(ref, pal, label=f"{fmt} seed={seed}: ")
    _assert_results_equal(ref, fus, label=f"{fmt} seed={seed} fused: ")
    _check_against_oracle(ref, ps["reference"], records)


@pytest.mark.parametrize("fmt", FORMAT_FUZZ)
@pytest.mark.parametrize("seed", FORMAT_CI_SEEDS)
def test_format_fuzz_ci(fmt, seed):
    """Deterministic CI profile: fixed seeds, fixed shapes (one compile per
    format × backend, shared with the conformance suite's parser cache)."""
    _run_format_differential(fmt, seed, n_rows=16)


@pytest.mark.slow
@pytest.mark.parametrize("fmt", FORMAT_FUZZ)
@pytest.mark.parametrize("seed", FORMAT_DEEP_SEEDS)
def test_format_fuzz_deep(fmt, seed):
    _run_format_differential(fmt, seed, n_rows=24)
