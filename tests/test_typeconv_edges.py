"""§3.3 semantic edge cases, pinned on every conversion path.

Three families of regressions:

  * int32 magnitude overflow — ``parse_int`` (jnp gather), the numparse
    Pallas kernel, and ``parse_int_segmented`` must all clear ``valid`` for
    values like ``9999999999`` instead of silently Horner-wrapping, and must
    agree with each other (the old ≤9- vs ≤10-digit cap inconsistency).
  * ``parse_date`` semantics — day-in-month/leap-year validation, the
    ``length==19`` time path, separator and time-of-day ranges.
  * ``parse_float`` boundaries — overflow-to-inf, lone ``.``, ``+.5``-style
    dotted signs, exponent edge shapes.

Every case asserts the reference and Pallas backends agree bit-for-bit on
values and verdicts.
"""
import datetime as dt

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import typeconv
from repro.kernels.numparse import ops as k_ops

INT32_MAX = 2**31 - 1


def _column(strs):
    """Pack python strings into (css, offset, length) back to back."""
    lens = np.asarray([len(s) for s in strs], np.int32)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    css = np.frombuffer("".join(strs).encode() or b"\x00", np.uint8)
    return jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens)


def _segmented_inputs(strs):
    total = sum(len(s) for s in strs)
    fid = np.concatenate([[i] * len(s) for i, s in enumerate(strs)] or [[0]])
    fstart = np.zeros(max(total, 1), bool)
    pos = 0
    for s in strs:
        if s:
            fstart[pos] = True
        pos += len(s)
    return jnp.asarray(fstart), jnp.asarray(fid.astype(np.int32))


# ---------------------------------------------------------------------------
# int32 overflow
# ---------------------------------------------------------------------------

INT_CASES = [
    # (text, expected_valid) — expected_value is int(text) where valid
    ("2147483647", True),
    ("-2147483647", True),
    ("+2147483647", True),
    ("2147483648", False),          # old behaviour: wrapped to -2147483648
    ("-2147483648", False),         # symmetric magnitude cap (documented)
    ("9999999999", False),          # old behaviour: wrapped silently
    ("99999999999999", False),
    ("0000000001", True),           # 10 digits, small value
    ("00000000000042", True),       # >10 digits of leading zeros still fine
    ("1410065407", True),           # what 9999999999 used to wrap to
    ("42", True),
    ("-0", True),
]


def test_parse_int_overflow_gather_and_kernel():
    strs = [s for s, _ in INT_CASES]
    css, offs, lens = _column(strs)
    width = int(lens.max())
    ref = typeconv.parse_int(css, offs, lens, width=width)
    pal = k_ops.parse_int_column(css, offs, lens, width=width)
    want_valid = np.asarray([v for _, v in INT_CASES])
    np.testing.assert_array_equal(np.asarray(ref.valid), want_valid)
    np.testing.assert_array_equal(np.asarray(pal.valid), want_valid)
    want_vals = np.asarray([int(s) for s, v in INT_CASES if v], np.int64)
    np.testing.assert_array_equal(np.asarray(ref.value)[want_valid], want_vals)
    np.testing.assert_array_equal(np.asarray(pal.value)[want_valid], want_vals)


def test_parse_int_overflow_segmented():
    strs = [s for s, _ in INT_CASES]
    css, offs, lens = _column(strs)
    fstart, fid = _segmented_inputs(strs)
    seg = typeconv.parse_int_segmented(css, fstart, fid, len(strs))
    want_valid = np.asarray([v for _, v in INT_CASES])
    np.testing.assert_array_equal(np.asarray(seg.valid), want_valid)
    want_vals = np.asarray([int(s) for s, v in INT_CASES if v], np.int64)
    np.testing.assert_array_equal(np.asarray(seg.value)[want_valid], want_vals)


def test_int_paths_reconciled_on_long_digit_runs():
    """The old caps disagreed: gather accepted ≤10 digits, segmented ≤9.
    Both now accept any digit count whose *value* fits int32."""
    strs = ["0" * 9 + "7", "0" * 12 + "3", "1" * 10, "2000000000", "2147483640"]
    css, offs, lens = _column(strs)
    width = int(lens.max())
    fstart, fid = _segmented_inputs(strs)
    gat = typeconv.parse_int(css, offs, lens, width=width)
    seg = typeconv.parse_int_segmented(css, fstart, fid, len(strs))
    pal = k_ops.parse_int_column(css, offs, lens, width=width)
    np.testing.assert_array_equal(np.asarray(gat.valid), np.asarray(seg.valid))
    np.testing.assert_array_equal(np.asarray(gat.valid), np.asarray(pal.valid))
    want_valid = np.asarray([True, True, True, True, True])
    np.testing.assert_array_equal(np.asarray(gat.valid), want_valid)
    np.testing.assert_array_equal(np.asarray(gat.value),
                                  [7, 3, 1111111111, 2000000000, 2147483640])
    np.testing.assert_array_equal(np.asarray(seg.value), np.asarray(gat.value))
    np.testing.assert_array_equal(np.asarray(pal.value), np.asarray(gat.value))


# ---------------------------------------------------------------------------
# parse_date semantics
# ---------------------------------------------------------------------------

DATE_CASES = [
    ("2024-02-29", True),            # leap year
    ("2023-02-29", False),           # not a leap year
    ("1900-02-29", False),           # century non-leap
    ("2000-02-29", True),            # 400-year leap
    ("2024-02-30", False),
    ("2024-04-31", False),           # 30-day month
    ("2024-06-31", False),
    ("2024-09-31", False),
    ("2024-11-31", False),
    ("2024-01-31", True),
    ("2024-12-31", True),
    ("2024-00-10", False),
    ("2024-13-10", False),
    ("2024-01-00", False),
    # length==19 time path
    ("2024-12-31 23:59:59", True),
    ("2024-12-31T23:59:59", True),   # ISO 8601 separator
    ("2024-12-31x23:59:59", False),
    ("2024-01-01 24:00:00", False),
    ("2024-01-01 23:60:00", False),
    ("2024-01-01 23:00:60", False),
    ("2024-01-01 00:00:00", True),
    ("2023-02-29 12:00:00", False),  # civil check applies on the time path too
    # structural
    ("2024-1-01", False),
    ("2024/01/01", False),
    ("2024-01-01 00:00", False),     # length 16: neither 10 nor 19
    ("", False),
]


def test_parse_date_semantics_both_backends():
    strs = [s for s, _ in DATE_CASES]
    css, offs, lens = _column(strs)
    ref = typeconv.parse_date(css, offs, lens)
    pal = k_ops.parse_date_column(css, offs, lens)
    want_valid = np.asarray([v for _, v in DATE_CASES])
    np.testing.assert_array_equal(np.asarray(ref.valid), want_valid,
                                  err_msg=str(strs))
    np.testing.assert_array_equal(np.asarray(pal.valid), np.asarray(ref.valid))
    np.testing.assert_array_equal(np.asarray(pal.value), np.asarray(ref.value))
    np.testing.assert_array_equal(np.asarray(pal.empty), np.asarray(ref.empty))
    # values: cross-check the valid ones against Python datetime
    for s, v, got in zip(strs, want_valid, np.asarray(ref.value)):
        if not v:
            continue
        fmt = "%Y-%m-%d" if len(s) == 10 else f"%Y-%m-%d{s[10]}%H:%M:%S"
        ts = dt.datetime.strptime(s, fmt).replace(tzinfo=dt.timezone.utc).timestamp()
        assert int(got) == int(ts), s


# ---------------------------------------------------------------------------
# parse_float boundaries
# ---------------------------------------------------------------------------

FLOAT_CASES = [
    # (text, expected_valid, expected_value or None for "don't check")
    ("1e38", True, np.float32(1e38)),
    ("1e39", True, np.float32(np.inf)),     # overflow-to-inf, still valid
    ("-1e39", True, np.float32(-np.inf)),
    ("3402823466e29", True, None),          # ~float32 max neighbourhood
    # near/below the float32 subnormal range the 10^exp pow flushes to zero
    # (XLA FTZ); both backends share the behaviour, so value is unchecked.
    ("1e-38", True, None),
    ("1e-39", True, None),
    (".", False, None),
    ("+.", False, None),
    ("+.5", True, np.float32(0.5)),
    ("-.5", True, np.float32(-0.5)),
    ("3.", True, np.float32(3.0)),
    ("1e", False, None),
    ("1e+", False, None),
    ("1E-3", True, np.float32(1e-3)),
    ("1.2.3", False, None),
    ("1e2e3", False, None),
    ("1.5e+06", True, np.float32(1.5e6)),
    ("", False, None),
    ("-", False, None),
]


def test_parse_float_boundaries_both_backends():
    strs = [s for s, _, _ in FLOAT_CASES]
    css, offs, lens = _column(strs)
    ref = typeconv.parse_float(css, offs, lens, width=24)
    pal = k_ops.parse_float_column(css, offs, lens, width=24)
    want_valid = np.asarray([v for _, v, _ in FLOAT_CASES])
    np.testing.assert_array_equal(np.asarray(ref.valid), want_valid,
                                  err_msg=str(strs))
    # bit-for-bit backend agreement on the verdicts AND the values
    np.testing.assert_array_equal(np.asarray(pal.valid), np.asarray(ref.valid))
    np.testing.assert_array_equal(np.asarray(pal.value)[want_valid],
                                  np.asarray(ref.value)[want_valid])
    for (s, v, want), got in zip(FLOAT_CASES, np.asarray(ref.value)):
        if want is None or not v:
            continue
        if np.isinf(want):
            assert got == want, (s, got)
        else:
            np.testing.assert_allclose(got, want, rtol=3e-6, err_msg=s)


def test_parse_float_inf_overflow_matches_python():
    """float32 overflow mirrors what numpy's float32 cast of python floats
    does: finite doubles beyond 3.4028235e38 land on inf."""
    strs = ["3e38", "4e38", "1e40", "-4e38"]
    css, offs, lens = _column(strs)
    ref = typeconv.parse_float(css, offs, lens, width=24)
    pal = k_ops.parse_float_column(css, offs, lens, width=24)
    np.testing.assert_array_equal(np.asarray(ref.value), np.asarray(pal.value))
    with np.errstate(over="ignore"):  # the float32 cast overflows by design
        want = [np.float32(float(s)) for s in strs]
    for s, got, w in zip(strs, np.asarray(ref.value), want):
        assert got == w, (s, got)
    assert np.isinf(np.asarray(ref.value)[1:]).all()
