"""DFA table invariants + parallel-vs-sequential equivalence (paper §3.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dfa as dfa_mod
from repro.core.transition import sequential_reference, transition_pipeline

ALL_DFAS = {
    "csv": dfa_mod.make_csv_dfa(),
    "csv+comment": dfa_mod.make_csv_dfa(comment=b"#"),
    "tsv": dfa_mod.make_csv_dfa(delimiter=b"\t"),
    "simple": dfa_mod.make_simple_dfa(),
    "clf": dfa_mod.make_log_dfa(),
}


@pytest.mark.parametrize("name", list(ALL_DFAS))
def test_table_invariants(name):
    d = ALL_DFAS[name]
    d.validate_tables()
    # every state reachable row maps into range
    assert d.transition.max() < d.n_states
    assert d.emission.max() <= dfa_mod.CONTROL
    # group LUT covers all 256 bytes
    assert d.group_of.shape == (256,)
    # distinguished bytes map to their own groups
    for g, b in enumerate(d.group_bytes):
        assert d.group_of[b] == g


def _pad(raw: bytes, k: int, rd: int) -> np.ndarray:
    arr = np.frombuffer(raw, np.uint8)
    n = arr.size + (0 if arr.size and arr[-1] == rd else 1)
    total = ((n + k - 1) // k) * k
    buf = np.full(total, dfa_mod.PAD_BYTE, np.uint8)
    buf[: arr.size] = arr
    if n != arr.size:
        buf[arr.size] = rd
    return buf.reshape(-1, k)


@pytest.mark.parametrize("name", list(ALL_DFAS))
@pytest.mark.parametrize("chunk", [3, 16, 64])
def test_parallel_matches_sequential(name, chunk):
    d = ALL_DFAS[name]
    raw = (
        b'aa,"b,\nb",cc\n# not, a, comment?\n"x""y",,"z"\n'
        b"1,2,3\n[10/Oct/2000] \"GET /x\" 200\n"
    )
    chunks = _pad(raw, chunk, d.group_bytes[0])
    cls_ref, _, end_ref = sequential_reference(chunks.reshape(-1), d)
    classes, ends, _ = transition_pipeline(jnp.asarray(chunks), d)
    np.testing.assert_array_equal(np.asarray(classes).reshape(-1), cls_ref)
    assert int(ends[-1]) == end_ref


@settings(max_examples=30, deadline=None)
@given(
    data=st.binary(min_size=0, max_size=600),
    chunk=st.sampled_from([5, 32, 64]),
    name=st.sampled_from(list(ALL_DFAS)),
)
def test_property_parallel_matches_sequential(data, chunk, name):
    """The parallel FSM simulation must equal the sequential one for ANY
    byte string — including pathological quote/delimiter soup."""
    d = ALL_DFAS[name]
    # bias the alphabet towards structural characters
    trans = bytes((b % 16) + ord("0") if b > 127 else b for b in data)
    structural = b',"\n#x '
    biased = bytes(
        structural[b % len(structural)] if b % 3 == 0 else b for b in trans
    )
    chunks = _pad(biased, chunk, d.group_bytes[0])
    cls_ref, _, end_ref = sequential_reference(chunks.reshape(-1), d)
    classes, ends, _ = transition_pipeline(jnp.asarray(chunks), d)
    np.testing.assert_array_equal(np.asarray(classes).reshape(-1), cls_ref)
    assert int(ends[-1]) == end_ref


def test_comment_lines_produce_no_records():
    d = ALL_DFAS["csv+comment"]
    raw = b"# header comment\n1,2\n# interior\n3,4\n"
    chunks = _pad(raw, 16, d.group_bytes[0])
    classes, _, _ = transition_pipeline(jnp.asarray(chunks), d)
    n_rec = int((np.asarray(classes).reshape(-1) == dfa_mod.RECORD_DELIM).sum())
    assert n_rec == 2  # only the two data lines delimit records


def test_quoted_delimiters_are_data():
    d = ALL_DFAS["csv"]
    raw = b'"a,b\nc",2\n'
    chunks = _pad(raw, 8, d.group_bytes[0])
    classes, _, _ = transition_pipeline(jnp.asarray(chunks), d)
    flat = np.asarray(classes).reshape(-1)
    # the comma and newline inside quotes are DATA
    assert flat[2] == dfa_mod.DATA  # ','
    assert flat[4] == dfa_mod.DATA  # '\n'
    # the structural comma after the closing quote is a FIELD_DELIM
    assert flat[7] == dfa_mod.FIELD_DELIM
