"""DFA table invariants + parallel-vs-sequential equivalence (paper §3.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without dev extras: table pins still run
    HAVE_HYPOTHESIS = False

from repro.core import dfa as dfa_mod
from repro.core import formats as formats_mod
from repro.core.transition import sequential_reference, transition_pipeline

# Registry-driven: every registered format's tables are covered here, so a
# newly registered format inherits the invariant + equivalence sweeps.
ALL_DFAS = {name: formats_mod.get_format(name).dfa()
            for name in formats_mod.available_formats()}

# One well-formed sample per format that must land back in an accept state
# (the quote/bracket/paren/nesting scopes all round-trip closed).
WELL_FORMED = {
    "csv": b'1,"a,\nb",3\n',
    "csv+comment": b"# c\n1,2\n",
    "tsv": b'1\t"x\ty"\t2\n',
    "simple": b"1,2\n",
    "clf": b'h [10/Oct "x] "GET /a\nb" 200\n',
    "jsonl": b'{"a": 1, "b": {"c": ["d\\"e", 2]}}\n',
    "zone": b"a 3600 ( IN ;c\n A ) d\n",
}


@pytest.mark.parametrize("name", list(ALL_DFAS))
def test_table_invariants(name):
    d = ALL_DFAS[name]
    d.validate_tables()
    # every state reachable row maps into range
    assert d.transition.max() < d.n_states
    assert d.emission.max() <= dfa_mod.CONTROL
    # group LUT covers all 256 bytes
    assert d.group_of.shape == (256,)
    # distinguished bytes map to their own groups
    for g, b in enumerate(d.group_bytes):
        assert d.group_of[b] == g


def _pad(raw: bytes, k: int, rd: int) -> np.ndarray:
    arr = np.frombuffer(raw, np.uint8)
    n = arr.size + (0 if arr.size and arr[-1] == rd else 1)
    total = ((n + k - 1) // k) * k
    buf = np.full(total, dfa_mod.PAD_BYTE, np.uint8)
    buf[: arr.size] = arr
    if n != arr.size:
        buf[arr.size] = rd
    return buf.reshape(-1, k)


@pytest.mark.parametrize("name", list(ALL_DFAS))
@pytest.mark.parametrize("chunk", [3, 16, 64])
def test_parallel_matches_sequential(name, chunk):
    d = ALL_DFAS[name]
    raw = (
        b'aa,"b,\nb",cc\n# not, a, comment?\n"x""y",,"z"\n'
        b"1,2,3\n[10/Oct/2000] \"GET /x\" 200\n"
    )
    chunks = _pad(raw, chunk, d.group_bytes[0])
    cls_ref, _, end_ref = sequential_reference(chunks.reshape(-1), d)
    classes, ends, _ = transition_pipeline(jnp.asarray(chunks), d)
    np.testing.assert_array_equal(np.asarray(classes).reshape(-1), cls_ref)
    assert int(ends[-1]) == end_ref


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        data=st.binary(min_size=0, max_size=600),
        chunk=st.sampled_from([5, 32, 64]),
        name=st.sampled_from(list(ALL_DFAS)),
    )
    def test_property_parallel_matches_sequential(data, chunk, name):
        """The parallel FSM simulation must equal the sequential one for ANY
        byte string — including pathological quote/delimiter soup."""
        d = ALL_DFAS[name]
        # bias the alphabet towards structural characters (every format's:
        # CSV quotes/comments, JSONL braces/colons/escapes, zone
        # parens/semis, CLF brackets — soup for one dialect is soup for all)
        trans = bytes((b % 16) + ord("0") if b > 127 else b for b in data)
        structural = b',"\n#x {}[]:;()\\\t'
        biased = bytes(
            structural[b % len(structural)] if b % 3 == 0 else b for b in trans
        )
        chunks = _pad(biased, chunk, d.group_bytes[0])
        cls_ref, _, end_ref = sequential_reference(chunks.reshape(-1), d)
        classes, ends, _ = transition_pipeline(jnp.asarray(chunks), d)
        np.testing.assert_array_equal(np.asarray(classes).reshape(-1), cls_ref)
        assert int(ends[-1]) == end_ref


def test_comment_lines_produce_no_records():
    d = ALL_DFAS["csv+comment"]
    raw = b"# header comment\n1,2\n# interior\n3,4\n"
    chunks = _pad(raw, 16, d.group_bytes[0])
    classes, _, _ = transition_pipeline(jnp.asarray(chunks), d)
    n_rec = int((np.asarray(classes).reshape(-1) == dfa_mod.RECORD_DELIM).sum())
    assert n_rec == 2  # only the two data lines delimit records


def test_quoted_delimiters_are_data():
    d = ALL_DFAS["csv"]
    raw = b'"a,b\nc",2\n'
    chunks = _pad(raw, 8, d.group_bytes[0])
    classes, _, _ = transition_pipeline(jnp.asarray(chunks), d)
    flat = np.asarray(classes).reshape(-1)
    # the comma and newline inside quotes are DATA
    assert flat[2] == dfa_mod.DATA  # ','
    assert flat[4] == dfa_mod.DATA  # '\n'
    # the structural comma after the closing quote is a FIELD_DELIM
    assert flat[7] == dfa_mod.FIELD_DELIM


@pytest.mark.parametrize("name", sorted(WELL_FORMED))
def test_well_formed_sample_round_trips(name):
    """A closed-scope sample must end in an accept state and delimit at
    least one record (the streaming-carry precondition for every format)."""
    d = ALL_DFAS[name]
    raw = WELL_FORMED[name]
    cls, states, end = sequential_reference(np.frombuffer(raw, np.uint8), d)
    assert bool(d.accept[end]), d.state_names[end]
    assert (cls == dfa_mod.RECORD_DELIM).sum() >= 1
    if d.invalid_state is not None:  # well-formed input never hits the sink
        assert (states != d.invalid_state).all()


def _classes(name, raw):
    d = ALL_DFAS[name]
    cls, _, _ = sequential_reference(np.frombuffer(raw, np.uint8), d)
    return cls


def test_log_dfa_emission_semantics():
    """First direct pin of make_log_dfa's dialect (it previously rode along
    unregistered and untested): bracket/quote scopes, stray closers."""
    C, D, F, R = (dfa_mod.CONTROL, dfa_mod.DATA, dfa_mod.FIELD_DELIM,
                  dfa_mod.RECORD_DELIM)
    #      a  [  b  "  c  SP ]  d  SP "  e  SP f  "  SP ]  \n
    raw = b'a[b"c ]d "e f" ]\n'
    want = [D, C, D, C, D, D, C, D, F, C, D, D, D, C, F, D, R]
    assert list(_classes("clf", raw)) == want


def test_jsonl_dfa_emission_semantics():
    """Depth-1 ','/':' delimit; everything nested is raw DATA subtext."""
    C, D, F, R = (dfa_mod.CONTROL, dfa_mod.DATA, dfa_mod.FIELD_DELIM,
                  dfa_mod.RECORD_DELIM)
    raw = b'{"a": {"b": [1, 2]}, "c": 3}\n'
    cls = _classes("jsonl", raw)
    assert cls[4] == F            # depth-1 ':'
    assert cls[6] == D            # nested '{' begins raw subtext
    assert cls[10] == D           # ':' inside nested container
    assert cls[14] == D           # ',' inside nested container
    assert cls[17] == D and cls[18] == D  # nested closers
    assert cls[19] == F           # depth-1 ',' after the nested value
    assert cls[24] == F           # depth-1 ':' before scalar value
    assert cls[27] == C           # record's closing '}'
    assert cls[28] == R           # newline between records
    # blank lines produce no records
    assert list(_classes("jsonl", b"\n\n")) == [C, C]


def test_zone_dfa_emission_semantics():
    """Whitespace-run collapse, paren newline-as-whitespace, comments."""
    C, D, F, R = (dfa_mod.CONTROL, dfa_mod.DATA, dfa_mod.FIELD_DELIM,
                  dfa_mod.RECORD_DELIM)
    #      a  SP b  SP (  SP c  \n SP d  SP )  SP e  ;  f  \n
    raw = b'a b ( c\n d ) e;f\n'
    want = [D, F, D, F, C, C, D, F, C, D, F, C, C, D, C, C, R]
    assert list(_classes("zone", raw)) == want
    # a whitespace run emits exactly one FIELD_DELIM (no empty fields)
    assert list(_classes("zone", b"a \t b\n")) == [D, F, C, C, D, R]
    # full-line comments and blank lines emit no record delimiter
    assert list(_classes("zone", b";x\n\n")) == [C, C, C, C]
