"""End-to-end system test: raw CSV bytes → ParPaRaw on-device parse →
token pipeline → sharded training step → loss decreases; plus the
dry-run machinery itself on a subprocess-local multi-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices


def test_parse_train_end_to_end():
    """The paper's technique as a first-class data pipeline: train on text
    parsed on-device out of quoted CSV, and verify learning happens."""
    from repro.configs.base import ModelConfig
    from repro.core import Schema
    from repro.data import synth
    from repro.data.pipeline import CSVTokenPipeline, PipelineConfig
    from repro.models.model import build_model
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import TrainConfig, init_train_state, make_train_step

    data = synth.yelp_like(np.random.default_rng(0), 2000)
    pipe = CSVTokenPipeline(
        Schema.of(*synth.YELP_SCHEMA),
        PipelineConfig(seq_len=64, batch_size=4, partition_bytes=1 << 16,
                       max_carry_bytes=1 << 14),
    )
    cfg = ModelConfig(name="bytelm-test", family="dense", vocab=512,
                      n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, tie_embeddings=True, remat=False,
                      param_dtype=jnp.float32)
    model = build_model(cfg)
    ocfg = opt_mod.OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    opt = opt_mod.make_optimizer(ocfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt, TrainConfig(optimizer=ocfg)))

    losses = []
    it = pipe.batches([data])
    for i in range(40):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::8]
    # byte-LM on English reviews should beat uniform-over-byte-alphabet fast
    assert losses[-1] < 4.5, losses[-5:]


@pytest.mark.slow
def test_dryrun_machinery_512_mesh():
    """Exercise launch/dryrun's build_cell path end to end in a subprocess
    (the full sweep runs the same code)."""
    out = run_with_devices("""
        from repro.launch.dryrun import build_cell
        out = build_cell("qwen2-1.5b", "decode_32k", multi_pod=True)
        assert out["status"] == "ok", out
        assert out["devices"] == 512
        assert out["memory"]["temp_bytes"] > 0
        print("DRYRUN_OK", sum(out["collective_counts"].values()))
    """, 512)
    assert "DRYRUN_OK" in out


def test_roofline_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
      %ag = bf16[8,1024,128]{2,1,0} all-gather(%x), replica_groups=...
      %ar = f32[256]{0} all-reduce(%y), to_apply=%sum
      %a2a = bf16[16,64,64]{2,1,0} all-to-all(%z)
      %other = f32[2,2]{1,0} add(%a, %b)
    """
    totals, counts = parse_collective_bytes(hlo)
    assert totals["all-gather"] == 8 * 1024 * 128 * 2
    assert totals["all-reduce"] == 256 * 4
    assert totals["all-to-all"] == 16 * 64 * 64 * 2
    assert counts == {"all-gather": 1, "all-reduce": 1, "all-to-all": 1}
