"""Windowed-DMA fused numparse: window planning + kernel coverage.

The fused gather+convert kernels DMA one contiguous CSS window per row
block instead of holding the whole CSS in VMEM (``ops.plan_css_windows`` +
``parse_*_fields_windowed``).  These tests pin the plan geometry (aligned
starts, tight windows, monotone/fits detection), the degenerate shapes
(block-boundary fields, straddling fields, empty and all-empty columns,
multi-tile mega-field fallback, non-monotone offsets), and the acceptance
bar that the windowed path still issues no XLA gather outside pallas_call.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jaxpr_utils import gathers_outside_pallas as _gathers_outside_pallas
from repro.core import typeconv
from repro.kernels.numparse import numparse
from repro.kernels.numparse import ops as k_ops

ALIGN = numparse.WINDOW_ALIGN


def _pack_css(strs):
    """Concatenate field strings into a CSS + (offset, length) index."""
    lens = np.asarray([len(s) for s in strs], np.int32)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    css = np.frombuffer("".join(strs).encode(), np.uint8)
    if css.size == 0:
        css = np.zeros(1, np.uint8)
    return jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens)


def _plan(offs, lens, br, width, wt, css_len):
    pad = (-offs.shape[0]) % br
    offs = np.pad(np.asarray(offs), (0, pad))
    lens = np.pad(np.asarray(lens), (0, pad))
    return k_ops.plan_css_windows(
        jnp.asarray(offs, jnp.int32), jnp.asarray(lens, jnp.int32),
        rows_per_block=br, width=width, window_bytes=wt, css_len=css_len,
    )


def _assert_parsed_equal(got, want, msg=""):
    for f in ("value", "valid", "empty"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f"{msg}: {f}")


# ---------------------------------------------------------------------------
# plan geometry
# ---------------------------------------------------------------------------

def test_plan_window_starts_aligned_and_tight():
    strs = [str(1000 + i) for i in range(64)]  # 4 bytes each, offsets 0,4,8…
    css, offs, lens = _pack_css(strs)
    ws, rel, fits = _plan(offs, lens, br=16, width=11, wt=512,
                          css_len=int(css.shape[0]))
    ws = np.asarray(ws)
    assert bool(fits)
    assert (ws % ALIGN == 0).all()
    # block b starts at field 16b → offset 64b, aligned down to 128-multiples
    np.testing.assert_array_equal(ws, (np.arange(4) * 64) // ALIGN * ALIGN)
    # relative offsets must reproduce the absolute ones
    np.testing.assert_array_equal(
        np.asarray(rel) + np.repeat(ws, 16), np.asarray(offs))


def test_plan_block_boundary_windows():
    """Fields exactly at row-block boundaries stay inside their block's
    window — including the last field of block b and first of block b+1
    sharing a CSS byte neighbourhood."""
    strs = ["%06d" % i for i in range(256)]  # 6-byte fields, br divides evenly
    css, offs, lens = _pack_css(strs)
    ws, rel, fits = _plan(offs, lens, br=64, width=11, wt=1024,
                          css_len=int(css.shape[0]))
    assert bool(fits)
    ws, rel = np.asarray(ws), np.asarray(rel)
    offs, lens = np.asarray(offs), np.asarray(lens)
    for b in range(4):
        for r in range(64):
            i = b * 64 + r
            assert ws[b] <= offs[i], (b, i)
            assert offs[i] + 11 <= ws[b] + 1024, (b, i)
            assert rel[i] == offs[i] - ws[b]


def test_plan_detects_mega_field_overflow():
    strs = [str(i) for i in range(32)] + ["9" * 4000] + [str(i) for i in range(31)]
    css, offs, lens = _pack_css(strs)
    _, _, fits = _plan(offs, lens, br=16, width=11, wt=256,
                       css_len=int(css.shape[0]))
    assert not bool(fits)
    # a tile large enough for the straddle fits again
    _, _, fits2 = _plan(offs, lens, br=16, width=11, wt=8192,
                        css_len=int(css.shape[0]))
    assert bool(fits2)


def test_plan_detects_non_monotone_offsets():
    css = jnp.zeros(1024, jnp.uint8)
    offs = np.arange(64, dtype=np.int32) * 4
    offs[10] = 900  # jumps forward…
    offs[11] = 40   # …then back: violates sortedness
    lens = np.full(64, 3, np.int32)
    _, _, fits = _plan(jnp.asarray(offs), jnp.asarray(lens), br=64, width=11,
                       wt=2048, css_len=1024)
    assert not bool(fits)


def test_plan_empty_fields_inherit_running_offset():
    """Empty fields carry offset 0 from the field index; the plan must not
    let them drag a late block's window back to the CSS start."""
    strs = []
    for i in range(128):
        strs.append("" if i % 3 == 0 else str(10000 + i))
    css, offs, lens = _pack_css(strs)
    offs = np.asarray(offs).copy()
    offs[np.asarray(lens) == 0] = 0  # what field_index emits for absent/empty
    ws, rel, fits = _plan(jnp.asarray(offs), lens, br=32, width=11, wt=512,
                          css_len=int(css.shape[0]))
    assert bool(fits)
    ws = np.asarray(ws)
    assert (np.diff(ws) >= 0).all()
    assert ws[-1] > 0  # late windows moved forward despite the zero offsets


def test_plan_leading_empty_does_not_drag_window_to_css_start():
    """An empty field in a column's FIRST record must not seed block 0's
    window at CSS offset 0 when the column's bytes live far into the CSS —
    that would overflow the tile and silently disable windowing."""
    col_base = 100_000  # the column's segment starts deep in the CSS
    offs = np.zeros(32, np.int32)
    lens = np.zeros(32, np.int32)
    pos = col_base
    for i in range(32):
        if i % 7 == 0:
            continue  # empty field: offset stays 0 (what field_index emits)
        offs[i] = pos
        lens[i] = 5
        pos += 5
    ws, rel, fits = _plan(jnp.asarray(offs), jnp.asarray(lens), br=32,
                          width=11, wt=512, css_len=col_base + 200)
    assert bool(fits)  # the window seeds from the first non-empty offset…
    assert int(np.asarray(ws)[0]) == col_base // ALIGN * ALIGN  # …not from 0


def test_per_row_window_fallback_handles_arbitrary_offsets():
    """The large-CSS fallback (per-row windows) parses correctly with
    non-monotone offsets and mega-fields — the shapes the block-window
    invariant cannot cover."""
    import functools

    from repro.kernels.numparse import numparse

    strs = [str(i * 31) for i in range(64)] + ["8" * 900]
    css, offs, lens = _pack_css(strs)
    # shuffle the index: rows no longer sorted by offset
    perm = np.random.default_rng(3).permutation(len(strs))
    offs = jnp.asarray(np.asarray(offs)[perm])
    lens = jnp.asarray(np.asarray(lens)[perm])
    got = k_ops._fused_column(
        functools.partial(numparse.parse_int_fields_fused, width=11),
        functools.partial(numparse.parse_int_fields_windowed, width=11),
        css, offs, lens, 11, numparse.DEFAULT_BLOCK_ROWS, 0, 0, True,
        wholecss_max=0,  # force the per-row tier even for this small CSS
    )
    ref = typeconv.parse_int(css, offs, lens, width=11)
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(ref.valid))
    ok = np.asarray(got.valid)
    np.testing.assert_array_equal(np.asarray(got.value)[ok],
                                  np.asarray(ref.value)[ok])


def test_auto_window_bytes_geometry():
    wt = k_ops.auto_window_bytes(512, 11)
    assert wt % ALIGN == 0
    assert wt >= 512 * 12 + 11  # every ≤11-byte field + terminator fits
    # explicit sizes are rounded up to alignment and floored sanely
    br, wt2 = k_ops._resolve_window(16, 100, 512, 11, 1000)
    assert br == 16 and wt2 % ALIGN == 0 and wt2 >= 11 + ALIGN


# ---------------------------------------------------------------------------
# windowed kernels vs whole-CSS fused vs typeconv
# ---------------------------------------------------------------------------

def _mixed_cases(rng, rows):
    ints, floats, dates = [], [], []
    for _ in range(rows):
        u = rng.random()
        if u < 0.15:
            junk = rng.choice(["", "x1y", "+", ".", "1e", "9" * 12, "2024-13-01"])
            ints.append(junk); floats.append(junk); dates.append(junk)
            continue
        ints.append(str(int(rng.integers(-(2**33), 2**33))))
        floats.append(f"{rng.normal() * 10.0 ** int(rng.integers(-6, 7)):.6g}")
        y, m, d = rng.integers(1970, 2038), rng.integers(1, 13), rng.integers(1, 29)
        dates.append(f"{y:04d}-{m:02d}-{d:02d}" if rng.random() < 0.5 else
                     f"{y:04d}-{m:02d}-{d:02d} {rng.integers(0, 24):02d}:"
                     f"{rng.integers(0, 60):02d}:{rng.integers(0, 60):02d}")
    return ints, floats, dates


@pytest.mark.parametrize("rows,window_rows", [(500, 32), (512, 512), (33, 8)])
def test_windowed_matches_wholecss_and_typeconv(rows, window_rows):
    ints, floats, dates = _mixed_cases(np.random.default_rng(rows), rows)
    cases = [
        (ints, k_ops.parse_int_column_fused,
         lambda c, o, l: typeconv.parse_int(c, o, l, width=11)),
        (floats, k_ops.parse_float_column_fused,
         lambda c, o, l: typeconv.parse_float(c, o, l, width=24)),
        (dates, k_ops.parse_date_column_fused, typeconv.parse_date),
    ]
    for strs, fused, oracle in cases:
        css, offs, lens = _pack_css(strs)
        got = fused(css, offs, lens, window_rows=window_rows)
        whole = fused(css, offs, lens, window_rows=k_ops.WHOLE_CSS)
        _assert_parsed_equal(got, whole, f"{fused.__name__} windowed vs whole")
        ref = oracle(css, offs, lens)
        np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(ref.valid))
        np.testing.assert_array_equal(np.asarray(got.empty), np.asarray(ref.empty))
        ok = np.asarray(got.valid)
        np.testing.assert_array_equal(np.asarray(got.value)[ok],
                                      np.asarray(ref.value)[ok])


def test_windowed_field_straddles_two_row_blocks():
    """The last field of one row block extends past the next block's first
    offset: both blocks' windows must cover their own reads."""
    strs = (["%02d" % i for i in range(15)] + ["88887777"]  # long field at
            + ["%02d" % i for i in range(16)])              # a block boundary
    css, offs, lens = _pack_css(strs)
    got = k_ops.parse_int_column_fused(css, offs, lens, window_rows=16)
    ref = typeconv.parse_int(css, offs, lens, width=11)
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(ref.valid))
    np.testing.assert_array_equal(np.asarray(got.value)[np.asarray(got.valid)],
                                  np.asarray(ref.value)[np.asarray(got.valid)])


def test_windowed_empty_and_all_empty_columns():
    # interleaved empties
    strs = ["" if i % 2 else str(i * 7) for i in range(128)]
    css, offs, lens = _pack_css(strs)
    got = k_ops.parse_int_column_fused(css, offs, lens, window_rows=16)
    whole = k_ops.parse_int_column_fused(css, offs, lens,
                                         window_rows=k_ops.WHOLE_CSS)
    _assert_parsed_equal(got, whole, "interleaved empties")
    # all-empty column (every offset 0, every length 0)
    css0 = jnp.zeros(1, jnp.uint8)
    z = jnp.zeros(64, jnp.int32)
    for fused in (k_ops.parse_int_column_fused, k_ops.parse_float_column_fused,
                  k_ops.parse_date_column_fused):
        got = fused(css0, z, z, window_rows=16)
        assert not np.asarray(got.valid).any()
        assert np.asarray(got.empty).all()


def test_windowed_mega_field_falls_back_per_column():
    """A single multi-tile mega-field flips the column to the whole-CSS
    kernel at run time — results stay bit-identical to the oracle."""
    strs = ([str(i) for i in range(100)] + ["7" * 5000]
            + [str(-i) for i in range(100)])
    css, offs, lens = _pack_css(strs)
    got = k_ops.parse_int_column_fused(css, offs, lens, window_rows=16,
                                       window_bytes=256)
    whole = k_ops.parse_int_column_fused(css, offs, lens,
                                         window_rows=k_ops.WHOLE_CSS)
    _assert_parsed_equal(got, whole, "mega-field fallback")
    ref = typeconv.parse_int(css, offs, lens, width=11)
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(ref.valid))
    assert not bool(np.asarray(got.valid)[100])  # the mega-field itself: too wide


def test_windowed_field_at_css_end():
    """Windows touching the last CSS byte rely on the tile padding, not on
    reading past the buffer."""
    strs = ["123", "-45", "678"]
    css, offs, lens = _pack_css(strs)
    got = k_ops.parse_int_column_fused(css, offs, lens, window_rows=2)
    want = k_ops.parse_int_column(css, offs, lens)
    _assert_parsed_equal(got, want, "css end")


# ---------------------------------------------------------------------------
# end-to-end through the parser + jaxpr acceptance bar
# ---------------------------------------------------------------------------

def _taxi_like_rows(n):
    return b"".join(
        b"%d,a%d,%d.%02d,2026-0%d-1%d\n"
        % (i, i, i % 1000, i % 100, i % 9 + 1, i % 9) for i in range(n))


@pytest.mark.parametrize("kw", [
    {},                                  # default: windowed, auto tile
    {"window_rows": 8},                  # many tiny windows
    {"max_window_bytes": 384},           # explicit tile
    {"window_rows": -1},                 # whole-CSS baseline
])
def test_parser_window_knobs_match_reference(kw):
    from repro.core import Parser, ParserConfig, Schema, make_csv_dfa

    schema = Schema.of(("id", "int32"), ("name", "str"),
                       ("price", "float32"), ("updated", "date"))
    data = _taxi_like_rows(200)
    ref = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema,
                              max_records=256)).parse(data)
    got = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema,
                              max_records=256, backend="pallas",
                              **kw)).parse(data)
    assert int(got.validation.n_records) == 200
    np.testing.assert_array_equal(np.asarray(got.css), np.asarray(ref.css))
    for c in ref.values:
        _assert_parsed_equal(got.values[c], ref.values[c], f"{kw} {c}")


def test_parser_config_window_knob_validation():
    from repro.core import ParserConfig, Schema, make_csv_dfa

    schema = Schema.of(("i", "int32"))
    with pytest.raises(ValueError, match="window_rows"):
        ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8,
                     backend="pallas", window_rows=-2)
    with pytest.raises(ValueError, match="max_window_bytes"):
        ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8,
                     backend="pallas", max_window_bytes=-1)


def test_plan_records_typeconv_path():
    from repro.core import ParserConfig, Schema, get_backend, make_csv_dfa
    from repro.core import stages as stages_mod

    schema = Schema.of(("i", "int32"))
    mk = lambda **kw: stages_mod.plan_materialize(
        ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8, **kw),
        get_backend(kw.get("backend", "reference")))
    assert mk().typeconv_path == "reference"
    assert mk(backend="pallas").typeconv_path == "fused-windowed"
    assert mk(backend="pallas", window_rows=-1).typeconv_path == "fused-wholecss"
    assert mk(backend="pallas", fuse_typeconv=False).typeconv_path == "unfused"


def test_windowed_kernels_issue_no_xla_gather():
    """Acceptance bar: the windowed fused path — window planning, the
    lax.cond fallback, and the kernels themselves — issues no XLA-level
    take/gather.  Covers the default config and explicit window knobs."""
    from repro.core import ParserConfig, Schema, get_backend, make_csv_dfa

    be = get_backend("pallas")
    css = jnp.zeros(100001, jnp.uint8)
    off = jnp.zeros(4096, jnp.int32)
    ln = jnp.zeros(4096, jnp.int32)
    schema = Schema.of(("i", "int32"), ("f", "float32"), ("d", "date"))
    for kw in ({}, {"window_rows": 64}, {"max_window_bytes": 512}):
        cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=64,
                           backend="pallas", **kw)
        for dtype in ("int32", "float32", "date"):
            jx = jax.make_jaxpr(
                lambda c, o, l: be.parse_field[dtype](c, o, l, cfg)
            )(css, off, ln)
            assert not _gathers_outside_pallas(jx.jaxpr), (kw, dtype)
