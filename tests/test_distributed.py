"""Distributed parser: multi-device shard_map pipeline equals single-device
parse — end to end, *including* materialization/typeconv on every shard.

Every test runs through ``conftest.run_with_devices`` (subprocess-isolated
``--xla_force_host_platform_device_count``, explicit skip if the topology
is unavailable), so the overrides never leak into other tests.

Coverage:
  * index-only sharded parse reassembles the oracle strings (legacy pin);
  * converted sharded parse + host ``assemble`` is bit-identical to
    ``Parser.to_arrow`` for D∈{1,2,4,8} across backends × tagging modes ×
    ``fuse_pipeline`` (the tentpole guarantee);
  * the compiled sharded executable's collective traffic is O(D·|S|) —
    byte-for-byte identical across a 4× input-size change;
  * lane-sharded ``StreamSession`` is bit-identical to the single-device
    batched engine and its step compiles with ZERO collectives (the
    carry-locality invariant);
  * the mesh-aware ``ParseService`` serves identical tenant results.
"""
import pytest

from conftest import run_with_devices

_COMMON = """
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core.distributed import DistributedParser

def csv_data(n_rows, seed=7):
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n_rows):
        body = "".join(rng.choice(list('ab,\\n"x'))
                       for _ in range(int(rng.integers(0, 12))))
        rows.append((str(i), body.replace('"', '""'), f"{i}.25"))
    return rows, "".join('%s,"%s",%s\\n' % r for r in rows).encode()

SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"))
"""


@pytest.mark.slow
def test_distributed_matches_single():
    """Legacy pin: per-shard field index over an 8-device (4, 2) mesh
    reassembles every oracle string, including mid-record shard cuts."""
    out = run_with_devices(_COMMON + """
mesh = jax.make_mesh((4, 2), ("data", "model"))
rows, data = csv_data(200)
cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=256,
                   chunk_size=32)

dp = DistributedParser(cfg, mesh, axis_names=("data", "model"))
chunks = dp.prepare(data)
got = dp.parse_chunks(chunks)

from repro.core.transition import transition_pipeline
cls_ref, _, _ = transition_pipeline(chunks, cfg.dfa)
np.testing.assert_array_equal(
    np.asarray(got.classes).reshape(-1), np.asarray(cls_ref).reshape(-1))
assert int(np.asarray(got.n_records).reshape(-1)[0]) == len(rows)

arrow = dp.assemble(got)
off, dat = arrow["b"]["offsets"], arrow["b"]["data"]
for i, row in enumerate(rows):
    want = row[1].replace('""', '"')
    assert bytes(dat[off[i]:off[i + 1]]).decode() == want, (i, want)
print("DISTRIBUTED_OK", len(rows))
""", 8)
    assert "DISTRIBUTED_OK" in out


# (backend, fuse_pipeline, tagging) — three combos per device count cover
# both backends, both execute paths, and all three tagging layouts across
# the sweep without a full 9-way matrix per D.
_COMBOS = (("reference", False, "tagged"),
           ("pallas", False, "vector"),
           ("pallas", True, "inline"))


@pytest.mark.slow
@pytest.mark.parametrize("n_dev", (1, 2, 4, 8))
def test_sharded_converted_bit_identity(n_dev):
    """The tentpole guarantee: sharded end-to-end parse *with conversion*
    (``assemble``) is bit-identical to ``Parser.to_arrow`` — validation
    scalars included — for every backend/path/tagging combo."""
    out = run_with_devices(_COMMON + f"COMBOS = {_COMBOS!r}\n" + """
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
rows, data = csv_data(60)
for be, fuse, tagging in COMBOS:
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=128,
                       chunk_size=16, backend=be, tagging=tagging,
                       fuse_pipeline=fuse)
    p = Parser(cfg)
    res = p.parse_chunks(p.prepare(data))
    ref = p.to_arrow(res)

    dp = DistributedParser(cfg, mesh)
    sh = dp.parse_chunks(dp.prepare(data))
    got = dp.assemble(sh)

    key = (be, fuse, tagging)
    assert int(sh.n_records) == int(res.validation.n_records), key
    for f in ("ok", "end_state_ok", "no_invalid", "min_columns",
              "max_columns"):
        a = np.asarray(getattr(sh.validation, f)).reshape(-1)[0]
        b = np.asarray(getattr(res.validation, f))
        assert np.array_equal(a, b), (key, f, a, b)
    for col in got:
        for k in got[col]:
            a, b = np.asarray(got[col][k]), np.asarray(ref[col][k])
            assert a.dtype == b.dtype and np.array_equal(a, b), (key, col, k)
    print("OK", key)
print("CONVERTED_OK")
""", n_dev)
    assert "CONVERTED_OK" in out


@pytest.mark.slow
def test_collectives_are_input_size_independent():
    """The O(D·|S|) pin: the compiled sharded executable's collective
    traffic is summary-sized — byte-for-byte identical across a 4× change
    in input size (no collective ever moves input-sized data)."""
    out = run_with_devices(_COMMON + """
from repro.launch.dryrun import parse_collective_bytes

mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=128,
                   chunk_size=32)
dp = DistributedParser(cfg, mesh)

stats = []
for n_chunks in (16, 64):  # 4x apart, both divisible by 8 devices
    hlo = dp.lower(n_chunks, 32).compile().as_text()
    stats.append(parse_collective_bytes(hlo))
(small_b, small_c), (big_b, big_c) = stats
assert sum(small_c.values()) > 0, small_c     # the stitch does gather
assert small_b == big_b, (small_b, big_b)     # ...but O(D*|S|) only
assert small_c == big_c, (small_c, big_c)
print("COLLECTIVES_OK", small_b)
""", 8)
    assert "COLLECTIVES_OK" in out


@pytest.mark.slow
def test_lane_sharded_streaming_bit_identity():
    """Lane-sharded StreamSession: identical yields + stats vs the
    unmeshed batched engine, and ZERO collectives in the compiled step
    (each device owns its lanes' carry — the carry-locality invariant)."""
    out = run_with_devices(_COMMON + """
from repro.core.streaming import StreamSession, StreamOverflow

devs = jax.devices()
mesh = jax.sharding.Mesh(np.array(devs), ("streams",))
S = 2 * len(devs)
cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=64,
                   chunk_size=16, backend="pallas")
p = Parser(cfg)
sources = [[("".join("%d,lane%d,%d.5\\n" % (s * 100 + i, s, i)
                     for i in range(7 + s))).encode()]
           for s in range(S)]

def run(mesh_arg):
    sess = StreamSession(p, partition_bytes=48, max_carry_bytes=48,
                         n_streams=S, mesh=mesh_arg)
    rounds = []
    for s, r, n in sess.parse_streams(sources):
        assert not isinstance(r, StreamOverflow)
        rounds.append((s, n, jax.tree_util.tree_map(np.asarray, r)))
    return sess, rounds

base_sess, base = run(None)
shd_sess, shd = run(mesh)
assert len(base) == len(shd)
for (s0, n0, r0), (s1, n1, r1) in zip(base, shd):
    assert (s0, n0) == (s1, n1)
    for a, b in zip(jax.tree_util.tree_leaves(r0),
                    jax.tree_util.tree_leaves(r1)):
        assert np.array_equal(a, b), s0
assert ([vars(a) for a in base_sess.stats]
        == [vars(b) for b in shd_sess.stats])

# zero-collectives pin on the compiled lane-sharded step
cb, cl = shd_sess._init_carry()
txt = shd_sess._step.lower(
    cb, cl, jnp.zeros((S, 48), jnp.uint8), jnp.zeros((S,), jnp.int32),
    jnp.zeros((S,), bool)).compile().as_text()
bad = [l for l in txt.splitlines()
       if any(c in l for c in ("all-gather", "all-reduce",
                               "collective-permute", "all-to-all"))]
assert not bad, bad[:5]
print("STREAMING_OK", S)
""", 4)
    assert "STREAMING_OK" in out


@pytest.mark.slow
def test_mesh_aware_service():
    """ParseService(mesh=...): tiers filter to multiples of the axis size
    and tenants get results identical to the unmeshed service."""
    out = run_with_devices(_COMMON + """
from repro.serve.service import ParseService, TenantResult

cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=64,
                   chunk_size=16, backend="pallas")
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("streams",))

def serve(svc):
    data = [("%d,x%d,%d.5\\n" % (i, i, i)).encode() * 4 for i in range(5)]
    ts = [svc.submit(cfg, d, partition_bytes=64, name=f"t{i}")
          for i, d in enumerate(data)]
    while svc.step() is not None:
        pass
    out = {}
    for t in ts:
        st = t.wait(timeout=60)
        assert not t.failed
        chunks = []
        for item in t.results():
            assert isinstance(item, TenantResult), item
            arrow = svc.registry.parser(cfg)[1].to_arrow(item.result)
            chunks.append(np.asarray(arrow["a"]["values"])[:item.n_records])
        out[t.name] = (st.records, [c.tolist() for c in chunks])
    return out

base = serve(ParseService(tiers=(1, 4, 16), start=False))
svc = ParseService(tiers=(1, 4, 16), mesh=mesh, start=False)
assert svc.tiers == (4, 16), svc.tiers
assert serve(svc) == base
print("SERVICE_OK")
""", 4)
    assert "SERVICE_OK" in out
