"""Distributed parser: multi-device shard_map pipeline equals single-device
parse.  Runs in a subprocess so the 8-device host-platform override never
leaks into other tests."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %(src)r)
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
    from repro.core.distributed import DistributedParser

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    rng = np.random.default_rng(7)
    rows = []
    for i in range(200):
        body = "".join(rng.choice(list('ab,\\n"x')) for _ in range(int(rng.integers(0, 12))))
        rows.append((str(i), body.replace('"', '""'), f"{i}.5"))
    data = "".join('%%s,"%%s",%%s\\n' %% r for r in rows).encode()

    schema = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"))
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=256, chunk_size=32)

    single = Parser(cfg)
    chunks = single.prepare(data)
    # pad chunk count to a multiple of the device count
    n_dev = 8
    c = chunks.shape[0]
    pad = (-c) %% n_dev
    if pad:
        from repro.core.dfa import PAD_BYTE
        chunks = np.concatenate([chunks, np.full((pad, chunks.shape[1]), PAD_BYTE, np.uint8)])

    ref = single.parse_chunks(jnp.asarray(chunks))

    dp = DistributedParser(cfg, mesh, axis_names=("data", "model"))
    got = dp.parse_chunks(jnp.asarray(chunks))

    # 1) identical symbol classification across the device boundary cuts
    from repro.core.transition import transition_pipeline
    cls_ref, _, _ = transition_pipeline(jnp.asarray(chunks), cfg.dfa)
    np.testing.assert_array_equal(
        np.asarray(got.classes).reshape(-1), np.asarray(cls_ref).reshape(-1)
    )

    # 2) global record count matches
    assert int(np.asarray(got.n_records).reshape(-1)[0]) == len(rows)

    # 3) per-shard columnar output reassembles into the oracle values
    n_dev_shards = 8
    field_off = np.asarray(got.field_offset).reshape(n_dev_shards, len(schema.columns), -1)
    field_len = np.asarray(got.field_length).reshape(n_dev_shards, len(schema.columns), -1)
    css = np.asarray(got.css).reshape(n_dev_shards, -1)
    rec_base = np.asarray(got.rec_base).reshape(-1)

    texts = {}
    for d in range(n_dev_shards):
        base = int(rec_base[d])
        # records fully inside shard d (shards split mid-record; a record's
        # value bytes can span shards only via the tail/head records)
        for r in range(field_len.shape[2]):
            ln = int(field_len[d, 1, r])
            off = int(field_off[d, 1, r])
            if ln or r + base < len(rows):
                texts.setdefault(base + r, []).append(bytes(css[d, off:off+ln]))
    ok = 0
    for i, row in enumerate(rows):
        want = row[1].replace('""', '"')
        got_txt = b"".join(texts.get(i, [])).decode()
        assert got_txt == want, (i, got_txt, want)
        ok += 1
    print("DISTRIBUTED_OK", ok)
    """
)


@pytest.mark.slow
def test_distributed_matches_single():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SCRIPT % {"src": os.path.abspath(src)}
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DISTRIBUTED_OK" in proc.stdout
