"""Paper §4.2/§4.3 capabilities: column selection, type inference, UTF-8
content, row/record skipping via tagging."""
import jax.numpy as jnp
import numpy as np

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core.parser import Column


def test_column_selection_projects_out():
    """Deselected columns' symbols are dropped at tagging (paper: 'skipping
    records and selecting columns')."""
    schema = Schema((Column("a", "int32"), Column("junk", "str", selected=False),
                     Column("c", "float32")))
    p = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8))
    res = p.parse(b'1,"lots of text here",2.5\n2,"more text",3.5\n')
    assert "junk" not in res.values
    arrow = p.to_arrow(res)
    assert set(arrow) == {"a", "c"}
    np.testing.assert_array_equal(arrow["a"]["values"][:2], [1, 2])
    np.testing.assert_allclose(arrow["c"]["values"][:2], [2.5, 3.5])
    # projected symbols land in the sentinel partition, not column storage
    kept = int(res.col_count[:3].sum())
    assert kept < len(b'1,lots of text here,2.5\n2,more text,3.5\n')


def test_type_inference():
    schema = Schema.of(("x", "str"), ("y", "str"), ("z", "str"))
    p = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8))
    res = p.parse(b"1,1.5,abc\n-42,2e3,def\n7,0.25,\n")
    inferred = p.infer_types(res)
    assert inferred == {"x": "int32", "y": "float32", "z": "str"}


def test_utf8_content_survives():
    """Paper §4.2: multi-byte code points crossing chunk boundaries.  The
    byte-level DFA treats UTF-8 continuation bytes as catch-all data, so
    values round-trip regardless of where chunks cut them."""
    text = "héllo wörld — ünïcode ✓ 日本語テキスト"
    data = f'1,"{text}",2\n'.encode()
    schema = Schema.of(("a", "int32"), ("t", "str"), ("b", "int32"))
    for chunk in (3, 5, 16):  # force cuts inside multi-byte sequences
        p = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema,
                                max_records=4, chunk_size=chunk))
        res = p.parse(data)
        assert bool(res.validation.ok)
        arrow = p.to_arrow(res)
        t = arrow["t"]
        got = bytes(t["data"][t["offsets"][0]: t["offsets"][1]])
        assert got.decode() == text, chunk


def test_record_skipping_via_tagging():
    from repro.core import offsets as offs_mod
    from repro.core import tagging as tag_mod
    from repro.core.transition import transition_pipeline

    data = b"1,a\n2,b\n3,c\n"
    p = Parser(ParserConfig(dfa=make_csv_dfa(),
                            schema=Schema.of(("x", "str"), ("y", "str")),
                            max_records=8, chunk_size=4))
    chunks = p.prepare(data)
    classes, _, _ = transition_pipeline(jnp.asarray(chunks), p.cfg.dfa)
    ids = offs_mod.symbol_ids(classes.reshape(-1))
    skip = np.zeros(8, bool)
    skip[1] = True  # drop record "2,b"
    tagged = tag_mod.tag_symbols(
        jnp.asarray(chunks), classes.reshape(-1), ids.record_id,
        ids.column_id, 2, skip_records=jnp.asarray(skip),
    )
    kept_syms = np.asarray(tagged.col_tag) < 2
    kept_bytes = bytes(np.asarray(jnp.asarray(chunks).reshape(-1))[kept_syms])
    assert kept_bytes == b"1a3c"  # record 2 fully projected out
