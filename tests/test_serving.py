"""Serving engine: continuous batching correctness vs unbatched greedy
oracle, slot reuse, and the active-mask invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-1.5b", reduced=True),
                              param_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, n_new):
    state = model.init_decode_state(1, max_seq=64)
    step = jax.jit(model.decode_step)
    logits = None
    for t in prompt:
        logits, state = step(params, jnp.asarray([t], jnp.int32), state)
    out = []
    tok = int(jnp.argmax(logits[0]))
    for _ in range(n_new):
        out.append(tok)
        logits, state = step(params, jnp.asarray([tok], jnp.int32), state)
        tok = int(jnp.argmax(logits[0]))
    return out


def test_continuous_batching_matches_oracle(small_model, rng):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=3, max_seq=64)
    prompts = [rng.integers(3, cfg.vocab, size=int(rng.integers(2, 7))).astype(np.int32)
               for _ in range(7)]  # 7 requests > 3 slots → slot reuse
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    finished = engine.run_until_done()
    assert len(finished) == 7
    for rid, toks in finished.items():
        want = _oracle(model, params, prompts[rid].tolist(), len(toks) - 1)
        assert list(toks[1:]) == want[: len(toks) - 1], rid


def test_mixed_depth_slots(small_model, rng):
    """Admitting a new request while others are mid-generation must not
    disturb them (per-slot positions + active masks)."""
    cfg, model, params = small_model
    eng_ref = ServeEngine(model, params, slots=1, max_seq=64)
    p0 = rng.integers(3, cfg.vocab, size=4).astype(np.int32)
    eng_ref.submit(Request(rid=0, prompt=p0, max_new_tokens=6))
    ref = eng_ref.run_until_done()[0]

    eng = ServeEngine(model, params, slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=p0, max_new_tokens=6))
    eng.tick()  # request 0 starts alone
    eng.tick()
    p1 = rng.integers(3, cfg.vocab, size=3).astype(np.int32)
    eng.submit(Request(rid=1, prompt=p1, max_new_tokens=4))  # joins mid-flight
    out = eng.run_until_done()
    np.testing.assert_array_equal(out[0], ref)
