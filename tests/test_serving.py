"""Multi-tenant parse service (serve/): registry sharing, tier scheduling
+ recompile pinning, backpressure, per-tenant stats under ragged
lifetimes, and the ISSUE-7 acceptance run — one tenant's overflow leaves
the other tenants of the batch bit-identical to their solo runs, and the
failed tenant's lane serves a newly admitted tenant in the same service
lifetime.

Scheduling-sensitive tests run the service synchronously
(``start=False`` + ``step()``) so admission decisions are deterministic;
the threaded front end is exercised where the behaviour under test *is*
the overlap (backpressure, ByteQueue ingest).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core.streaming import StreamOverflow, StreamSession, StreamingParser
from repro.serve import (
    ByteQueue,
    ParseService,
    TenantOverflow,
    TenantResult,
)
from tests.conftest import random_csv_table

SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"))
DTYPES = ("int32", "str", "float32")
ALT_SCHEMA = Schema.of(("x", "str"), ("y", "int32"))


def _cfg(schema=SCHEMA, **kw):
    kw.setdefault("max_records", 32)
    kw.setdefault("chunk_size", 32)
    return ParserConfig(dfa=make_csv_dfa(), schema=schema, **kw)


def _drain(tenant):
    """Consume a tenant's channel; return (results, overflows, errors)."""
    res, ovf, err = [], [], []
    for item in tenant.results():
        (res if isinstance(item, TenantResult)
         else ovf if isinstance(item, TenantOverflow) else err).append(item)
    return res, ovf, err


def test_registry_shares_one_executable_per_plan_key(rng):
    """Two tenants with equal plan keys (independently built but identical
    configs/DFAs) share ONE compiled parser and session; a differing
    schema compiles a second executable."""
    _, d = random_csv_table(rng, 10, DTYPES)
    svc = ParseService(max_queued_partitions=128, start=False)
    t0 = svc.submit(_cfg(), d, partition_bytes=256)
    t1 = svc.submit(_cfg(), d, partition_bytes=256)   # fresh cfg + fresh Dfa
    svc.step()
    assert svc.registry.parser_builds == 1
    assert svc.registry.session_builds == 1
    assert t0.session_key == t1.session_key

    _, alt = random_csv_table(rng, 10, ("str", "int32"))
    t2 = svc.submit(_cfg(ALT_SCHEMA), alt, partition_bytes=256)
    svc.step()
    assert svc.registry.parser_builds == 2
    assert t2.session_key != t0.session_key
    for t in (t0, t1, t2):
        res, ovf, err = _drain(t)
        assert res and not ovf and not err
        assert t.wait(5).records == 10


def test_tier_selection_and_recompile_count(rng):
    """Batch width is the smallest tier ≥ group size, and the jitted step
    compiles once per (plan key, tier) — pinned via the session step's own
    jit cache, not wall-clock heuristics."""
    _, d = random_csv_table(rng, 6, DTYPES)
    svc = ParseService(tiers=(1, 4, 16), max_queued_partitions=128,
                       start=False)
    assert [svc.tier_for(n) for n in (1, 2, 4, 5, 16, 40)] == [1, 4, 4, 16, 16, 16]

    # 3 tenants → tier 4: one session, spare lane inert
    ts = [svc.submit(_cfg(), d, partition_bytes=128) for _ in range(3)]
    svc.step()
    assert svc.registry.session_builds == 1
    (sk, sess), = svc.registry._sessions.items()
    assert sk[3] == 4 and sess.n_streams == 4  # (…, n_streams, mesh_key)
    assert sess._step._cache_size() == 1
    for t in ts:
        assert t.wait(5).records == 6 and not t.failed

    # a second wave at the same tier: same session, no recompile
    ts2 = [svc.submit(_cfg(), d, partition_bytes=128) for _ in range(4)]
    svc.step()
    assert svc.registry.session_builds == 1
    assert sess._step._cache_size() == 1
    for t in ts2:
        assert t.wait(5).records == 6

    # a single tenant → tier 1: a second session (new width), one compile
    t1 = svc.submit(_cfg(), d, partition_bytes=128)
    svc.step()
    assert svc.registry.session_builds == 2
    assert t1.session_key[3] == 1
    assert t1.wait(5).records == 6


def test_oversized_group_splits_across_batches(rng):
    """More compatible tenants than the top tier: served across several
    batches on the same top-tier session, nothing dropped."""
    _, d = random_csv_table(rng, 3, DTYPES)
    svc = ParseService(tiers=(1, 2), max_queued_partitions=128, start=False)
    ts = [svc.submit(_cfg(), d, partition_bytes=128) for _ in range(5)]
    steps = 0
    while svc.step() is not None:
        steps += 1
    assert steps == 3                       # 2 + 2 + 1
    assert svc.registry.session_builds <= 2  # tier-2 + tier-1 at most
    for t in ts:
        assert t.wait(5).records == 3


def test_backpressure_bounded_queue_blocks_never_drops(rng):
    """A consumer that stops reading stalls the worker at the queue bound;
    once it resumes, every partition arrives in order — nothing dropped."""
    _, d = random_csv_table(rng, 40, DTYPES)
    svc = ParseService(max_queued_partitions=2, admission_wait=0.0, start=True)
    try:
        t = svc.submit(_cfg(), d, partition_bytes=64)
        deadline = time.monotonic() + 60
        while t._q.qsize() < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        # channel full, many partitions still unparsed → worker is blocked
        assert t._q.qsize() == 2
        time.sleep(0.2)
        assert not t.done                   # stalled, not dropping
        assert t._q.qsize() == 2            # bound held while we slept
        res, ovf, err = _drain(t)           # resume consuming
        assert not ovf and not err
        st = t.wait(30)
        assert st.records == 40
        assert st.partitions == len(res) > 2
        assert st.bytes_in == len(d)
    finally:
        svc.close()


def test_bytequeue_ingest_backpressure():
    """Push-model ingest: ByteQueue.write blocks at max_chunks (producer
    backpressure), everything written is parsed after close()."""
    rows = b"".join(b"%d,abc,1.5\n" % i for i in range(60))
    chunks = [rows[i:i + 32] for i in range(0, len(rows), 32)]
    q = ByteQueue(max_chunks=2)
    progress = []

    def produce():
        for c in chunks:
            q.write(c)
            progress.append(len(c))
        q.close()

    svc = ParseService(admission_wait=0.0, start=True)
    try:
        producer = threading.Thread(target=produce, daemon=True)
        producer.start()
        time.sleep(0.1)
        # before the service consumes, the bounded queue pins the producer
        # at max_chunks in-flight writes (+1 possibly blocked in put)
        assert len(progress) <= 3 < len(chunks)
        t = svc.submit(_cfg(), q, partition_bytes=128)
        producer.join(timeout=60)
        assert not producer.is_alive()
        res, ovf, err = _drain(t)
        assert not ovf and not err
        st = t.wait(30)
        assert st.records == 60
        assert st.bytes_in == len(rows) == sum(progress)
    finally:
        svc.close()


def test_per_tenant_stats_ragged_lifetimes(rng):
    """Tenants of one batch with very different stream lengths (including
    an empty one) each get exactly their solo-run stats."""
    datas = []
    for n in (25, 3, 0):
        if n:
            _, d = random_csv_table(rng, n, DTYPES, quote_prob=0.5)
        else:
            d = b""
        datas.append(d)
    svc = ParseService(max_queued_partitions=128, start=False)
    ts = [svc.submit(_cfg(), d, partition_bytes=96, max_carry_bytes=512)
          for d in datas]
    svc.step()
    for t, d in zip(ts, datas):
        solo = StreamingParser(Parser(_cfg()), 96, max_carry_bytes=512)
        list(solo.parse_stream([d]))
        st = t.wait(5)
        for f in ("partitions", "bytes_in", "bytes_reparsed", "records",
                  "max_carry", "flush_delims", "failed"):
            assert getattr(st, f) == getattr(solo.stats, f), (t.name, f)


def test_acceptance_overflow_isolated_lane_reclaimed(rng):
    """ISSUE 7 acceptance: a 4-tenant batch where one tenant's record
    exceeds carry capacity — the other 3 complete bit-identical to solo
    StreamSession runs, the failed tenant gets a typed overflow result,
    and its lane serves a newly admitted tenant in the same service
    lifetime (same session object, no new compile)."""
    datas = []
    for n in (12, 6, 9):
        _, d = random_csv_table(rng, n, DTYPES, quote_prob=0.5)
        datas.append(d)
    bad = b'7,"' + b"y" * 4000 + b'",1.5\n'
    sources = [datas[0], bad, datas[1], datas[2]]

    svc = ParseService(tiers=(1, 4), max_queued_partitions=128, start=False)
    ts = [svc.submit(_cfg(), src, partition_bytes=128, max_carry_bytes=256,
                     name=f"tenant{i}") for i, src in enumerate(sources)]
    svc.step()

    res1, ovf1, err1 = _drain(ts[1])
    # rounds before the overflow may deliver (0-record) partitions; the
    # typed overflow is the LAST thing on the failed tenant's channel
    assert not err1 and len(ovf1) == 1
    assert all(r.n_records == 0 for r in res1)
    assert isinstance(ovf1[0].error, StreamOverflow)
    assert ts[1].wait(5).failed and ts[1].failed

    for i in (0, 2, 3):
        # the solo oracle: a fresh single-stream session over the same bytes
        solo_sess = StreamSession(Parser(_cfg()), 128, max_carry_bytes=256)
        solo = [(r, n) for _s, r, n in solo_sess.parse_streams([[sources[i]]])]
        res, ovf, err = _drain(ts[i])
        assert not ovf and not err, i
        assert len(res) == len(solo), i
        for p, (item, (rq, nq)) in enumerate(zip(res, solo)):
            assert item.n_records == nq, (i, p)
            for f in ("css", "col_start", "col_count", "field_offset",
                      "field_length", "end_state", "last_record_end"):
                a = np.asarray(getattr(item.result, f))
                b = np.asarray(getattr(rq, f))
                assert np.array_equal(a, b), (i, p, f)
        st = ts[i].wait(5)
        for f in ("partitions", "bytes_in", "records", "max_carry"):
            assert getattr(st, f) == getattr(solo_sess.stats[0], f), (i, f)

    # lane reclaim: a fresh 4-tenant wave reuses the SAME tier-4 session —
    # including the failed tenant's lane — with no new compile.
    builds = svc.registry.session_builds
    failed_lane = ts[1].lane
    wave = [svc.submit(_cfg(), datas[2], partition_bytes=128,
                       max_carry_bytes=256) for _ in range(4)]
    svc.step()
    assert svc.registry.session_builds == builds
    reclaimed = [t for t in wave if t.lane == failed_lane]
    assert len(reclaimed) == 1
    for t in wave:
        st = t.wait(5)
        assert not t.failed and st.records == 9, t.name
        assert t.session_key == ts[1].session_key


def test_threaded_service_end_to_end(rng):
    """The threaded front end: concurrent tenants over two schemas, one
    induced overflow, consumed from separate threads — correct records
    everywhere, no cross-tenant contamination."""
    _, d_main = random_csv_table(rng, 20, DTYPES, quote_prob=0.5)
    _, d_alt = random_csv_table(rng, 14, ("str", "int32"))
    bad = b'1,"' + b"z" * 4000 + b'",2.5\n'
    svc = ParseService(admission_wait=0.05, start=True)
    got = {}

    def consume(t):
        res, ovf, err = _drain(t)
        got[t.name] = (sum(r.n_records for r in res), len(ovf), len(err))

    try:
        tenants = [
            svc.submit(_cfg(), d_main, partition_bytes=128,
                       max_carry_bytes=256, name="m0"),
            svc.submit(_cfg(), d_main, partition_bytes=128,
                       max_carry_bytes=256, name="m1"),
            svc.submit(_cfg(ALT_SCHEMA), d_alt, partition_bytes=128,
                       max_carry_bytes=256, name="alt"),
            svc.submit(_cfg(), bad, partition_bytes=128,
                       max_carry_bytes=256, name="bad"),
        ]
        threads = [threading.Thread(target=consume, args=(t,), daemon=True)
                   for t in tenants]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=300)
            assert not th.is_alive()
        assert got["m0"] == (20, 0, 0)
        assert got["m1"] == (20, 0, 0)
        assert got["alt"] == (14, 0, 0)
        assert got["bad"][1:] == (1, 0) and got["bad"][0] == 0
        assert svc.registry.parser_builds == 2   # SCHEMA + ALT_SCHEMA
    finally:
        svc.close()


def test_submit_after_close_raises():
    svc = ParseService(start=False)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(_cfg(), b"1,a,2.0\n", partition_bytes=64)
