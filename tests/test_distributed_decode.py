"""Distributed flash-decoding combine: sequence-sharded attention shards
merged with (max, sumexp, pv) triples must equal full softmax attention."""
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import distributed_decode_combine


def test_combine_equals_full_softmax(rng):
    b, h, s, d, shards = 2, 4, 64, 16, 8
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    scale = 1.0 / math.sqrt(d)

    # oracle: full softmax over the whole sequence
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    p = jax.nn.softmax(scores, axis=-1)
    want = jnp.einsum("bhs,bshd->bhd", p, v)

    # shard the sequence; each shard computes its local (m, l, pv)
    ks = k.reshape(b, shards, s // shards, h, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, shards, s // shards, h, d).transpose(1, 0, 2, 3, 4)

    def local(k_l, v_l):
        s_l = jnp.einsum("bhd,bshd->bhs", q, k_l) * scale
        m = jnp.max(s_l, axis=-1)
        e = jnp.exp(s_l - m[..., None])
        l = jnp.sum(e, axis=-1)
        pv = jnp.einsum("bhs,bshd->bhd", e, v_l)
        return distributed_decode_combine(m, l, pv, "shard")

    got = jax.vmap(local, axis_name="shard")(ks, vs)
    # every shard returns the same combined result
    for i in range(shards):
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
