"""Backend parity: ``backend="pallas"`` must be bit-identical to
``backend="reference"`` for every driver (the acceptance bar for the
pluggable stage-backend layer in core/backends.py / core/stages.py).

Every comparison is exact (``np.array_equal``, no tolerance): both backends
run the same integer/byte pipelines, so any drift is a logic bug, not
rounding.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa, make_log_dfa, make_simple_dfa
from repro.core import backends as backends_mod
from repro.core.streaming import StreamingParser

DFAS = {
    "csv": make_csv_dfa,
    "simple": make_simple_dfa,
    "log": make_log_dfa,
}

# Inputs exercise quotes/brackets where the DFA supports them, plus empty
# fields, signed ints, exponent floats, valid/invalid dates (leap years,
# day-in-month, time-of-day), overflowing ints, and a trailing unterminated
# record.  Every dtype the schema layer knows appears in at least one schema
# so no dtype can silently fall back to a non-backend path.
INPUTS = {
    "csv": (b'1,"a,b",3.5,2024-02-29\n'
            b'-42,"he""llo",0.25,2023-02-29\n'
            b',world,1e3,2024-04-31\n'
            b'7,x,,2024-12-31 23:59:59\n'
            b'2147483648,y,+.5,\n'
            b'8,z,1e-3,2024-06-30\n'),
    "simple": b"1,2.5\n-22,1e3\n333,junk\n,+.25\n9999999999,.\n",
    "log": b'h1 [01/Jan/2024] "GET /a b" 200\nh2 [02/Feb] "POST /c" -404\n',
}

SCHEMAS = {
    "csv": Schema.of(("i", "int32"), ("s", "str"), ("f", "float32"), ("d", "date")),
    "simple": Schema.of(("a", "int32"), ("b", "float32")),
    "log": Schema.of(("host", "str"), ("ts", "str"), ("req", "str"), ("code", "int32")),
}


def _assert_results_equal(r, q, label=""):
    for f in ("css", "col_start", "col_count", "field_offset", "field_length",
              "end_state", "last_record_end"):
        a, b = np.asarray(getattr(r, f)), np.asarray(getattr(q, f))
        assert np.array_equal(a, b), f"{label}{f}: {a} != {b}"
    assert r.values.keys() == q.values.keys()
    for name in r.values:
        for f in ("value", "valid", "empty"):
            a = np.asarray(getattr(r.values[name], f))
            b = np.asarray(getattr(q.values[name], f))
            assert np.array_equal(a, b), f"{label}values[{name}].{f}: {a} != {b}"
    for f in r.validation._fields:
        a, b = np.asarray(getattr(r.validation, f)), np.asarray(getattr(q.validation, f))
        assert np.array_equal(a, b), f"{label}validation.{f}: {a} != {b}"


def _pair(dfa_name, **kw):
    kw.setdefault("max_records", 16)
    kw.setdefault("chunk_size", 16)
    cfgs = {
        be: ParserConfig(dfa=DFAS[dfa_name](), schema=SCHEMAS[dfa_name],
                         backend=be,
                         # pin the radix partition *kernel* on the pallas
                         # side (under interpret=True "auto" would pick the
                         # jnp pass) so parity covers the whole kernel path
                         partition_impl="kernel" if be == "pallas" else "auto",
                         **kw)
        for be in ("reference", "pallas")
    }
    return Parser(cfgs["reference"]), Parser(cfgs["pallas"])


@pytest.mark.parametrize("dfa_name", sorted(DFAS))
@pytest.mark.parametrize("tagging", ("tagged", "inline", "vector"))
def test_parser_parity(dfa_name, tagging):
    ref, pal = _pair(dfa_name, tagging=tagging)
    data = INPUTS[dfa_name]
    _assert_results_equal(ref.parse(data), pal.parse(data),
                          label=f"{dfa_name}/{tagging}: ")


@pytest.mark.parametrize("dfa_name", sorted(DFAS))
def test_parser_parity_fused(dfa_name):
    """Third backend axis: the whole-pipeline megakernel
    (``fuse_pipeline=True``) must match reference bit-for-bit too.  (The
    per-tagging-mode sweep + streaming/carry variants live in
    test_fused_pipeline.py.)"""
    ref, _ = _pair(dfa_name)
    fus = Parser(ParserConfig(dfa=DFAS[dfa_name](), schema=SCHEMAS[dfa_name],
                              backend="pallas", partition_impl="kernel",
                              fuse_pipeline=True, max_records=16,
                              chunk_size=16))
    assert fus.plan.execute_path == "fused"
    data = INPUTS[dfa_name]
    _assert_results_equal(ref.parse(data), fus.parse(data),
                          label=f"{dfa_name} fused: ")


def test_parser_parity_nondefault_block_chunks():
    """Chunk counts that do not divide block_chunks exercise the pallas
    backend's pad-to-block path."""
    ref, pal = _pair("csv", chunk_size=16, block_chunks=2)
    data = INPUTS["csv"]
    assert ref.prepare(data).shape[0] % 2 == 1  # odd chunk count → padding
    _assert_results_equal(ref.parse(data), pal.parse(data))


def test_parser_parity_carry_initial_state():
    """The streaming hook: a non-default initial state (mid-quote) must give
    identical contexts on both backends."""
    ref, pal = _pair("csv")
    chunks = ref.prepare(b'b",2,3\n4,"x",5\n')
    enc = ref.cfg.dfa.state_names.index("ENC")
    r = ref.parse_chunks(jnp.asarray(chunks), initial_state=jnp.int32(enc))
    q = pal.parse_chunks(jnp.asarray(chunks), initial_state=jnp.int32(enc))
    _assert_results_equal(r, q)


def test_streaming_parity_multi_partition():
    ref, pal = _pair("csv", max_records=32)
    data = INPUTS["csv"] * 6  # several partitions with mid-record splits
    outs = []
    for p in (ref, pal):
        sp = StreamingParser(p, partition_bytes=64, max_carry_bytes=64)
        parts = [(r, n) for r, n in sp.parse_stream([data])]
        assert sp.stats.partitions > 1
        outs.append(parts)
    assert len(outs[0]) == len(outs[1])
    for (r, n_r), (q, n_q) in zip(*outs):
        assert n_r == n_q
        _assert_results_equal(r, q, label="stream: ")


def test_distributed_parity():
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed import DistributedParser

    data = INPUTS["csv"] * 4
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shards = {}
    for be in ("reference", "pallas"):
        cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMAS["csv"],
                           max_records=64, chunk_size=16, backend=be,
                           partition_impl="kernel" if be == "pallas" else "auto")
        dp = DistributedParser(cfg, mesh)
        shards[be] = dp.parse_chunks(dp.prepare(data))
    r, q = shards["reference"], shards["pallas"]
    ra, qa = jax.tree_util.tree_leaves(r), jax.tree_util.tree_leaves(q)
    assert len(ra) == len(qa)
    for a, b in zip(ra, qa):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (a, b)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown parser backend"):
        ParserConfig(dfa=make_simple_dfa(), schema=SCHEMAS["simple"],
                     max_records=4, backend="nope")


def test_registry_lists_both_backends():
    assert {"reference", "pallas"} <= set(backends_mod.available_backends())
