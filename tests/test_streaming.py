"""Streaming parser: partition boundaries inside quoted fields, carry-over
stitching, and oracle equality for the full stream (paper §4.4)."""
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core.streaming import StreamingParser
from tests.conftest import random_csv_table

SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"), ("d", "date"))
DTYPES = ("int32", "str", "float32", "date")


def _source(data: bytes, step: int):
    for i in range(0, len(data), step):
        yield data[i : i + step]


@pytest.mark.parametrize("partition_bytes", [97, 256, 1024])
def test_stream_equals_oracle(rng, partition_bytes):
    rows, data = random_csv_table(rng, 60, DTYPES, quote_prob=0.8, newline_prob=0.5)
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=64, chunk_size=32)
    sp = StreamingParser(Parser(cfg), partition_bytes, max_carry_bytes=2048)
    out = sp.parse_all(_source(data, 53))
    assert sp.stats.records == len(rows)
    for r, row in enumerate(rows):
        if row[0] != "":
            assert out["a"]["validity"][r]
            assert int(out["a"]["values"][r]) == int(row[0])
        got = bytes(out["b"]["data"][out["b"]["offsets"][r]: out["b"]["offsets"][r + 1]])
        assert got.decode() == row[1], (r, got, row[1])
        if row[2] != "":
            np.testing.assert_allclose(out["c"]["values"][r], np.float32(float(row[2])), rtol=2e-6)


def test_partition_cut_inside_quotes():
    """A partition boundary in the middle of a quoted field containing
    record delimiters — the adversarial case for context-free chunking."""
    row_b = "A" * 40 + "\n,\n,\n" + "B" * 40  # newlines+commas inside quotes
    data = f'1,"{row_b}",2.5\n2,"tail",3.5\n'.encode()
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=16, chunk_size=16,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=48, max_carry_bytes=256)
    out = sp.parse_all(_source(data, 17))
    assert sp.stats.records == 2
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == row_b
    np.testing.assert_allclose(out["c"]["values"], [2.5, 3.5])


def test_record_larger_than_partition():
    big = "x" * 700
    data = f'1,"{big}",1.0\n2,b,2.0\n'.encode()
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=8, chunk_size=32,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=128, max_carry_bytes=1024)
    out = sp.parse_all(_source(data, 64))
    assert sp.stats.records == 2
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == big
    assert sp.stats.max_carry >= 128  # the carry really did grow past a partition


def test_capacity_overflow_raises():
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),),
                       max_records=4, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=32, max_carry_bytes=32)
    data = b'"' + b"y" * 500 + b'"\n'
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def test_capacity_exact_fill_needs_flush_delimiter_raises():
    """Regression: an unterminated record that exactly fills the buffer
    leaves no room for the flush delimiter — must raise the graceful
    capacity error, not an out-of-bounds write."""
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),),
                       max_records=4, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=32, max_carry_bytes=32)
    data = b"y" * sp.capacity  # one delimiter-free record, exactly capacity
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def _small_parser(**kw):
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=16, chunk_size=16, **kw)
    return Parser(cfg)


def test_stream_pad_only_tail():
    """Regression: a stream ending in a PAD-only tail (trailing 0x00 bytes
    after the last record delimiter) must not mint a spurious empty record,
    must drop the stale carry, and must terminate."""
    data = b"1,aa\n2,bb\n" + b"\x00" * 8
    sp = StreamingParser(_small_parser(), partition_bytes=256, max_carry_bytes=64)
    parts = list(sp.parse_stream([data]))
    assert len(parts) == 1
    _, n_complete = parts[0]
    assert n_complete == 2          # no empty third record from the PAD tail
    assert sp.stats.records == 2
    assert sp.stats.max_carry == 0  # stale PAD carry was dropped, not kept


def test_stream_pad_only_final_partition():
    """Same, but the PAD tail lands in its own final partition: the carry
    from the previous partition is empty, the final partition is all PADs,
    and the stream must end with zero extra records."""
    sp = StreamingParser(_small_parser(), partition_bytes=10, max_carry_bytes=64)
    parts = list(sp.parse_stream([b"1,aa\n2,bb\n", b"\x00" * 6]))
    assert [n for _, n in parts] == [2, 0]
    assert sp.stats.records == 2
    assert sp.stats.max_carry == 0


def test_stream_final_unterminated_quote_drops_stale_carry():
    """A final partition whose tail is an unclosed quoted field: the last
    raw byte is a record delimiter (inside quotes → DATA), so no delimiter
    is appended and the tail record cannot be completed.  The stream must
    still terminate with the stale carry dropped and validation flagging the
    partition."""
    data = b'1,aa\n2,"bb\n'
    sp = StreamingParser(_small_parser(), partition_bytes=256, max_carry_bytes=64)
    parts = list(sp.parse_stream([data]))
    assert len(parts) == 1
    result, n_complete = parts[0]
    assert n_complete == 1  # only "1,aa"; the quoted tail never closes
    assert not bool(result.validation.ok)  # ends mid-quote: not accepted
    assert sp.stats.max_carry == 0


def test_no_trailing_newline(rng):
    rows, data = random_csv_table(rng, 10, ("int32", "str"))
    data = data.rstrip(b"\n")
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=16, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=64, max_carry_bytes=256)
    out = sp.parse_all(_source(data, 31))
    assert sp.stats.records == len(rows)
    r = len(rows) - 1
    got = bytes(out["b"]["data"][out["b"]["offsets"][r]: out["b"]["offsets"][r + 1]])
    assert got.decode() == rows[r][1]
