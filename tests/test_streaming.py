"""Streaming parser: partition boundaries inside quoted fields, carry-over
stitching, and oracle equality for the full stream (paper §4.4).

Covers both engines of ``StreamingParser`` — ``device`` (the
``StreamSession`` plan/executor step with on-device carry) and ``host``
(the legacy host-carry loop, kept as the bit-identity oracle) — plus the
multi-stream batched session and the no-per-partition-host-sync contract.
"""
import jax
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core import streaming as streaming_mod
from repro.core.streaming import StreamOverflow, StreamSession, StreamingParser
from tests.conftest import random_csv_table

SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"), ("d", "date"))
DTYPES = ("int32", "str", "float32", "date")


def _source(data: bytes, step: int):
    for i in range(0, len(data), step):
        yield data[i : i + step]


@pytest.mark.parametrize("partition_bytes", [97, 256, 1024])
def test_stream_equals_oracle(rng, partition_bytes):
    rows, data = random_csv_table(rng, 60, DTYPES, quote_prob=0.8, newline_prob=0.5)
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=64, chunk_size=32)
    sp = StreamingParser(Parser(cfg), partition_bytes, max_carry_bytes=2048)
    out = sp.parse_all(_source(data, 53))
    assert sp.stats.records == len(rows)
    for r, row in enumerate(rows):
        if row[0] != "":
            assert out["a"]["validity"][r]
            assert int(out["a"]["values"][r]) == int(row[0])
        got = bytes(out["b"]["data"][out["b"]["offsets"][r]: out["b"]["offsets"][r + 1]])
        assert got.decode() == row[1], (r, got, row[1])
        if row[2] != "":
            np.testing.assert_allclose(out["c"]["values"][r], np.float32(float(row[2])), rtol=2e-6)


def test_partition_cut_inside_quotes():
    """A partition boundary in the middle of a quoted field containing
    record delimiters — the adversarial case for context-free chunking."""
    row_b = "A" * 40 + "\n,\n,\n" + "B" * 40  # newlines+commas inside quotes
    data = f'1,"{row_b}",2.5\n2,"tail",3.5\n'.encode()
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=16, chunk_size=16,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=48, max_carry_bytes=256)
    out = sp.parse_all(_source(data, 17))
    assert sp.stats.records == 2
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == row_b
    np.testing.assert_allclose(out["c"]["values"], [2.5, 3.5])


def test_record_larger_than_partition():
    big = "x" * 700
    data = f'1,"{big}",1.0\n2,b,2.0\n'.encode()
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=8, chunk_size=32,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=128, max_carry_bytes=1024)
    out = sp.parse_all(_source(data, 64))
    assert sp.stats.records == 2
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == big
    assert sp.stats.max_carry >= 128  # the carry really did grow past a partition


def test_capacity_overflow_raises():
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),),
                       max_records=4, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=32, max_carry_bytes=32)
    data = b'"' + b"y" * 500 + b'"\n'
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def test_capacity_exact_fill_needs_flush_delimiter_raises():
    """Regression: an unterminated record that exactly fills the buffer
    leaves no room for the flush delimiter — must raise the graceful
    capacity error, not an out-of-bounds write."""
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),),
                       max_records=4, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=32, max_carry_bytes=32)
    data = b"y" * sp.capacity  # one delimiter-free record, exactly capacity
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def _small_parser(**kw):
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=16, chunk_size=16, **kw)
    return Parser(cfg)


def test_stream_pad_only_tail():
    """Regression: a stream ending in a PAD-only tail (trailing 0x00 bytes
    after the last record delimiter) must not mint a spurious empty record,
    must drop the stale carry, and must terminate."""
    data = b"1,aa\n2,bb\n" + b"\x00" * 8
    sp = StreamingParser(_small_parser(), partition_bytes=256, max_carry_bytes=64)
    parts = list(sp.parse_stream([data]))
    assert len(parts) == 1
    _, n_complete = parts[0]
    assert n_complete == 2          # no empty third record from the PAD tail
    assert sp.stats.records == 2
    assert sp.stats.max_carry == 0  # stale PAD carry was dropped, not kept


def test_stream_pad_only_final_partition():
    """Same, but the PAD tail lands in its own final partition: the carry
    from the previous partition is empty, the final partition is all PADs,
    and the stream must end with zero extra records."""
    sp = StreamingParser(_small_parser(), partition_bytes=10, max_carry_bytes=64)
    parts = list(sp.parse_stream([b"1,aa\n2,bb\n", b"\x00" * 6]))
    assert [n for _, n in parts] == [2, 0]
    assert sp.stats.records == 2
    assert sp.stats.max_carry == 0


def test_stream_final_unterminated_quote_drops_stale_carry():
    """A final partition whose tail is an unclosed quoted field: the last
    raw byte is a record delimiter (inside quotes → DATA), so no delimiter
    is appended and the tail record cannot be completed.  The stream must
    still terminate with the stale carry dropped and validation flagging the
    partition."""
    data = b'1,aa\n2,"bb\n'
    sp = StreamingParser(_small_parser(), partition_bytes=256, max_carry_bytes=64)
    parts = list(sp.parse_stream([data]))
    assert len(parts) == 1
    result, n_complete = parts[0]
    assert n_complete == 1  # only "1,aa"; the quoted tail never closes
    assert not bool(result.validation.ok)  # ends mid-quote: not accepted
    assert sp.stats.max_carry == 0


def test_no_trailing_newline(rng):
    rows, data = random_csv_table(rng, 10, ("int32", "str"))
    data = data.rstrip(b"\n")
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=16, chunk_size=16)
    sp = StreamingParser(Parser(cfg), partition_bytes=64, max_carry_bytes=256)
    out = sp.parse_all(_source(data, 31))
    assert sp.stats.records == len(rows)
    r = len(rows) - 1
    got = bytes(out["b"]["data"][out["b"]["offsets"][r]: out["b"]["offsets"][r + 1]])
    assert got.decode() == rows[r][1]

# ---------------------------------------------------------------------------
# StreamSession engine: device-resident carry, dispatch-ahead, multi-stream
# ---------------------------------------------------------------------------

def _backend_kw(backend):
    # pin the radix partition *kernel* on the pallas side (under
    # interpret=True "auto" would pick the jnp pass) so the streaming suite
    # exercises the whole kernel path end to end; "pallas-fused" is the
    # whole-pipeline megakernel riding the same carry hooks
    if backend == "pallas":
        return dict(backend="pallas", partition_impl="kernel")
    if backend == "pallas-fused":
        return dict(backend="pallas", partition_impl="kernel",
                    fuse_pipeline=True)
    return dict(backend="reference")


def _assert_results_equal(r, q, label=""):
    for f in ("css", "col_start", "col_count", "field_offset", "field_length",
              "end_state", "last_record_end"):
        a, b = np.asarray(getattr(r, f)), np.asarray(getattr(q, f))
        assert np.array_equal(a, b), f"{label}{f}: {a} != {b}"
    assert r.values.keys() == q.values.keys()
    for name in r.values:
        for f in ("value", "valid", "empty"):
            a = np.asarray(getattr(r.values[name], f))
            b = np.asarray(getattr(q.values[name], f))
            assert np.array_equal(a, b), f"{label}values[{name}].{f}: {a} != {b}"
    for f in r.validation._fields:
        a, b = np.asarray(getattr(r.validation, f)), np.asarray(getattr(q.validation, f))
        assert np.array_equal(a, b), f"{label}validation.{f}: {a} != {b}"


def _assert_stats_equal(a, b, label=""):
    for f in ("partitions", "bytes_in", "bytes_reparsed", "records",
              "max_carry", "flush_delims", "failed"):
        assert getattr(a, f) == getattr(b, f), \
            f"{label}stats.{f}: {getattr(a, f)} != {getattr(b, f)}"


@pytest.mark.parametrize("backend", ["reference", "pallas", "pallas-fused"])
@pytest.mark.parametrize("tagging", ["tagged", "inline", "vector"])
def test_device_engine_matches_host_and_oneshot(rng, backend, tagging):
    """The acceptance bar: the device-carry engine is bit-identical to the
    legacy host-carry iterator per partition, and its concatenated output
    equals a one-shot ``Parser.parse_chunks`` of the whole input — across
    all tagging modes and both backends."""
    rows, data = random_csv_table(rng, 24, DTYPES, quote_prob=0.7, newline_prob=0.4)
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=40,
                       chunk_size=32, tagging=tagging, **_backend_kw(backend))

    sp_dev = StreamingParser(Parser(cfg), 160, max_carry_bytes=1024)
    sp_host = StreamingParser(Parser(cfg), 160, max_carry_bytes=1024, engine="host")
    dev = list(sp_dev.parse_stream(_source(data, 71)))
    host = list(sp_host.parse_stream(_source(data, 71)))
    assert len(dev) == len(host) and len(dev) > 1
    for i, ((rd, nd), (rh, nh)) in enumerate(zip(dev, host)):
        assert nd == nh
        _assert_results_equal(rd, rh, label=f"{backend}/{tagging}/part{i}: ")
    _assert_stats_equal(sp_dev.stats, sp_host.stats, label=f"{backend}/{tagging}: ")

    # concatenated stream output == one-shot parse of the whole input
    one_cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=40,
                           chunk_size=32, tagging=tagging, **_backend_kw(backend))
    one = Parser(one_cfg)
    result = one.parse(data)
    n = int(result.validation.n_records)
    assert n == len(rows)
    arrow = one.to_arrow(result)
    streamed = StreamingParser(Parser(cfg), 160, max_carry_bytes=1024).parse_all(
        _source(data, 71))
    for c, col in enumerate(SCHEMA.columns):
        got, want = streamed[col.name], arrow[col.name]
        if "values" in got:
            assert np.array_equal(got["values"], want["values"][:n]), col.name
            want_validity = np.unpackbits(want["validity"], bitorder="little")[:n]
            assert np.array_equal(got["validity"], want_validity.astype(bool)), col.name
        else:
            assert np.array_equal(np.asarray(got["offsets"], np.int64),
                                  np.asarray(want["offsets"][: n + 1], np.int64)), col.name
            assert np.array_equal(got["data"], want["data"][: want["offsets"][n]]), col.name


@pytest.mark.parametrize("engine", ["device", "host"])
def test_ragged_source_chunks(rng, engine):
    """Sources that yield wildly uneven pieces (including empty ones) must
    produce the same stream as any other chunking of the same bytes."""
    rows, data = random_csv_table(rng, 50, DTYPES, quote_prob=0.6, newline_prob=0.3)
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=64, chunk_size=32)

    def ragged():
        sizes = rng.integers(0, 97, size=10_000)
        i = 0
        for sz in sizes:
            if i >= len(data):
                return
            yield data[i : i + int(sz)]
            i += int(sz)

    sp = StreamingParser(Parser(cfg), 256, max_carry_bytes=2048, engine=engine)
    out = sp.parse_all(ragged())
    assert sp.stats.records == len(rows)
    assert sp.stats.bytes_in == len(data)
    ref = StreamingParser(Parser(cfg), 256, max_carry_bytes=2048, engine=engine)
    out_ref = ref.parse_all(_source(data, 999))
    for name in out:
        for k in out[name]:
            assert np.array_equal(out[name][k], out_ref[name][k]), (name, k)


@pytest.mark.parametrize("engine", ["device", "host"])
def test_carry_spans_multiple_partitions(engine):
    """A quoted record much longer than a partition: its bytes are carried
    (and re-parsed) across several partitions before completing."""
    big = "B" * 300
    data = f'1,"{big}",2.5\n2,tail,3.5\n'.encode()
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=8, chunk_size=32,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=64, max_carry_bytes=512,
                         engine=engine)
    out = sp.parse_all(_source(data, 37))
    assert sp.stats.records == 2
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == big
    # the carry grew past several partitions and its bytes were re-parsed
    assert sp.stats.max_carry >= 2 * 64
    assert sp.stats.bytes_reparsed > len(big)
    assert sp.stats.bytes_in == len(data)


@pytest.mark.parametrize("engine", ["device", "host"])
def test_empty_source_stream(engine):
    """An empty source (no bytes at all, or only empty yields) produces no
    partitions, no records, and terminates."""
    for source in ([], [b""], [b"", b""]):
        sp = StreamingParser(_small_parser(), 32, max_carry_bytes=32, engine=engine)
        assert list(sp.parse_stream(source)) == []
        assert sp.stats.partitions == 0
        assert sp.stats.records == 0
        assert sp.stats.bytes_in == 0


@pytest.mark.parametrize("engine", ["device", "host"])
def test_single_giant_record_stream(engine):
    """The whole stream is ONE unterminated record spanning many partitions;
    the flush delimiter closes it at end-of-stream."""
    big = "g" * 500
    data = f'7,"{big}",1.25'.encode()  # no trailing newline
    cfg = ParserConfig(
        dfa=make_csv_dfa(),
        schema=Schema.of(("a", "int32"), ("b", "str"), ("c", "float32")),
        max_records=4, chunk_size=32,
    )
    sp = StreamingParser(Parser(cfg), partition_bytes=64, max_carry_bytes=1024,
                         engine=engine)
    out = sp.parse_all(_source(data, 50))
    assert sp.stats.records == 1
    got = bytes(out["b"]["data"][out["b"]["offsets"][0]: out["b"]["offsets"][1]])
    assert got.decode() == big
    np.testing.assert_allclose(out["c"]["values"], [1.25])
    # every partition before the last carried everything it had seen
    assert sp.stats.max_carry >= len(data) - 64


def test_device_engine_capacity_overflow_raises():
    sp = StreamingParser(_small_parser(), 32, max_carry_bytes=32)
    data = b'1,"' + b"y" * 500 + b'"\n'
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def test_device_engine_exact_fill_flush_delimiter_raises():
    sp = StreamingParser(_small_parser(), 32, max_carry_bytes=32)
    data = b"y" * sp.capacity  # one delimiter-free record, exactly capacity
    with pytest.raises(ValueError, match="record longer than capacity"):
        list(sp.parse_stream(_source(data, 16)))


def test_device_engine_exact_capacity_terminated_ok():
    """A terminated record exactly filling the capacity is NOT an overflow
    (no flush delimiter needed) — the case a host-side conservative
    carry+take+1 check would false-positive on."""
    sp = StreamingParser(_small_parser(), 32, max_carry_bytes=32)
    payload = b"1," + b"a" * (sp.capacity - 3) + b"\n"
    assert len(payload) == sp.capacity
    parts = list(sp.parse_stream([payload]))
    # the record straddles every partition, completing only in the last
    assert [n for _, n in parts] == [0, 0, 1]
    assert sp.stats.records == 1
    host = StreamingParser(_small_parser(), 32, max_carry_bytes=32, engine="host")
    assert [n for _, n in host.parse_stream([payload])] == [0, 0, 1]


def test_invalid_partition_bytes_raises():
    """partition_bytes < 1 must fail fast at construction (a zero-byte
    partition would otherwise loop the device engine forever on empty
    takes)."""
    for bad in (0, -5):
        with pytest.raises(ValueError, match="partition_bytes"):
            StreamingParser(_small_parser(), bad)
        with pytest.raises(ValueError, match="partition_bytes"):
            StreamSession(_small_parser(), bad)


def test_flush_with_trailing_pad_bytes_matches_host():
    """An unterminated final record followed by source PAD bytes: the flush
    delimiter is judged on the last payload byte but written after the PAD
    tail (where the host oracle writes it) — the engines must stay
    bit-identical, delimiter placement included."""
    data = b"1,ab" + b"\x00" * 6
    dev = StreamingParser(_small_parser(), 256, max_carry_bytes=64)
    host = StreamingParser(_small_parser(), 256, max_carry_bytes=64, engine="host")
    pd = list(dev.parse_stream([data]))
    ph = list(host.parse_stream([data]))
    assert len(pd) == len(ph) == 1
    assert pd[0][1] == ph[0][1] == 1
    _assert_results_equal(pd[0][0], ph[0][0], label="pad-tail-flush: ")
    _assert_stats_equal(dev.stats, host.stats, label="pad-tail-flush: ")


def test_flush_pad_tail_exact_fill_raises_both_engines():
    """Payload + PAD tail exactly filling the capacity with the tail record
    unterminated: the flush delimiter has no slot (it goes after the PAD
    tail, like the host oracle's) — both engines must raise, not silently
    diverge."""
    for engine in ("device", "host"):
        sp = StreamingParser(_small_parser(), 32, max_carry_bytes=32, engine=engine)
        data = b"1," + b"a" * (sp.capacity - 4) + b"\x00\x00"
        assert len(data) == sp.capacity
        with pytest.raises(ValueError, match="record longer than capacity"):
            list(sp.parse_stream([data]))


def test_stream_stats_semantics(rng):
    """bytes_in counts each source byte exactly once; bytes_reparsed counts
    the carry re-parses; their sum is the device-side parse traffic."""
    rows, data = random_csv_table(rng, 30, ("int32", "str"), quote_prob=0.5)
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=64, chunk_size=16)
    for engine in ("device", "host"):
        sp = StreamingParser(Parser(cfg), 64, max_carry_bytes=256, engine=engine)
        list(sp.parse_stream(_source(data, 29)))
        assert sp.stats.bytes_in == len(data), engine
        assert sp.stats.records == len(rows), engine
        # every partition except possibly the first re-parses the previous
        # carry; with 64-byte partitions of multi-field rows there must be
        # some straddling record
        assert sp.stats.bytes_reparsed > 0, engine
        assert sp.stats.bytes_reparsed <= sp.stats.partitions * sp.max_carry_bytes, engine


@pytest.mark.parametrize("backend", ["reference", "pallas", "pallas-fused"])
def test_multistream_batched_vs_sequential(rng, backend):
    """S concurrent streams in one batched session are bit-identical, per
    stream per partition, to S sequential single-stream runs — including
    ragged lengths (streams finish at different rounds) and an empty
    stream in the batch."""
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=32,
                       chunk_size=32, **_backend_kw(backend))
    datas = []
    for n_rows in (18, 7, 0):
        if n_rows:
            _, d = random_csv_table(rng, n_rows, DTYPES, quote_prob=0.6,
                                    newline_prob=0.3)
        else:
            d = b""
        datas.append(d)

    sess = StreamSession(Parser(cfg), partition_bytes=96, max_carry_bytes=512,
                         n_streams=len(datas))
    batched = {s: [] for s in range(len(datas))}
    for s, result, n in sess.parse_streams([[d] for d in datas]):
        batched[s].append((result, n))

    for s, d in enumerate(datas):
        sp = StreamingParser(Parser(cfg), 96, max_carry_bytes=512)
        seq = list(sp.parse_stream([d]))
        assert len(seq) == len(batched[s]), (s, len(seq), len(batched[s]))
        for i, ((rq, nq), (rb, nb)) in enumerate(zip(seq, batched[s])):
            assert nq == nb, (s, i)
            _assert_results_equal(rq, rb, label=f"{backend}/stream{s}/part{i}: ")
        _assert_stats_equal(sp.stats, sess.stats[s], label=f"{backend}/stream{s}: ")


def test_multistream_overflow_typed_result_names_stream():
    """A batched lane overflow is a per-lane typed result, not a session
    exception: the failed lane yields a StreamOverflow (a ValueError
    subclass carrying stream/n_bytes/capacity and the historical message)
    and the session completes."""
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),),
                       max_records=4, chunk_size=16)
    sess = StreamSession(Parser(cfg), 32, max_carry_bytes=32, n_streams=2)
    ok = b"1\n2\n"
    bad = b'"' + b"y" * 500 + b'"\n'
    overflows = [(s, r) for s, r, _ in sess.parse_streams([[ok], [bad]])
                 if isinstance(r, StreamOverflow)]
    assert len(overflows) == 1
    s, err = overflows[0]
    assert s == err.stream == 1
    assert err.capacity == sess.capacity and err.n_bytes > sess.capacity
    assert isinstance(err, ValueError)
    import re
    assert re.search(r"record longer than capacity.*stream 1", str(err))
    assert sess.stats[1].failed and not sess.stats[0].failed
    assert sess.stats[0].records == 2


@pytest.mark.parametrize("backend", ["reference", "pallas-fused"])
def test_multistream_overflow_isolation(rng, backend):
    """THE fault-isolation regression (ISSUE 7): stream 1 of 4 overflows
    mid-stream; streams 0/2/3 must parse to completion bit-identical to
    their solo runs — results, counts, and stats — with the failed lane
    reporting exactly one typed StreamOverflow, its stats finalized
    (failed=True, overflowing round's bytes counted, no partitions), and
    the session left reusable (idle) for the next batch."""
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=32,
                       chunk_size=32, **_backend_kw(backend))
    datas = []
    for n_rows in (15, 9, 4):
        _, d = random_csv_table(rng, n_rows, DTYPES, quote_prob=0.6,
                                newline_prob=0.3)
        datas.append(d)
    # one record wider than capacity, landing a few partitions in
    bad = datas[1][:50] + b'9,"' + b"y" * 3000 + b'",1.5,2020-01-01\n'
    sources = [datas[0], bad, datas[1], datas[2]]

    sess = StreamSession(Parser(cfg), partition_bytes=96, max_carry_bytes=512,
                         n_streams=4)
    batched = {s: [] for s in range(4)}
    faults = []
    for s, result, n in sess.parse_streams([[d] for d in sources]):
        if isinstance(result, StreamOverflow):
            faults.append((s, result))
        else:
            batched[s].append((result, n))
    assert faults and [s for s, _ in faults] == [1]
    assert faults[0][1].stream == 1
    # partitions before the overflow round still came through normally;
    # nothing for stream 1 arrives after the fault (lane retired)
    n_before_fault = len(batched[1])

    for s in (0, 2, 3):
        sp = StreamingParser(Parser(cfg), 96, max_carry_bytes=512)
        solo = list(sp.parse_stream([sources[s]]))
        assert len(solo) == len(batched[s]), s
        for i, ((rq, nq), (rb, nb)) in enumerate(zip(solo, batched[s])):
            assert nq == nb, (s, i)
            _assert_results_equal(rq, rb, label=f"{backend}/isol{s}/part{i}: ")
        _assert_stats_equal(sp.stats, sess.stats[s], label=f"{backend}/isol{s}: ")
    st1 = sess.stats[1]
    assert st1.failed
    assert st1.partitions == n_before_fault  # pre-fault rounds counted ...
    assert 0 < st1.bytes_in <= len(bad)      # ... and the overflowing round's
    assert st1.bytes_in > st1.partitions * 96  # bytes too (work was dispatched)

    # lane reclaim: the session is idle again and every lane — including
    # the failed one — parses a fresh batch normally.
    again = {s: 0 for s in range(4)}
    for s, result, n in sess.parse_streams([[datas[2]]] * 4):
        assert not isinstance(result, StreamOverflow), s
        again[s] += n
    assert all(v == 4 for v in again.values()), again
    assert sess.call_stats[1].records == 4 and not sess.call_stats[1].failed


def test_session_reentry_guard_and_reset():
    """A parse_streams generator abandoned mid-stream (caller break) leaves
    the session 'dirty': re-entry is a clear error, reset() restores it,
    and a concurrent second generator on an active session is refused."""
    data = b"1,aa\n2,bb\n3,cc\n4,dd\n" * 4
    sess = StreamSession(_small_parser(), partition_bytes=8, max_carry_bytes=64)
    gen = sess.parse_streams([[data]])
    next(gen)                     # at least one round dispatched
    # a second generator while the first is open must be refused
    with pytest.raises(RuntimeError, match="active"):
        next(sess.parse_streams([[data]]))
    gen.close()                   # abnormal exit: dispatched round pending
    with pytest.raises(RuntimeError, match="dirty"):
        next(sess.parse_streams([[data]]))
    sess.reset()
    out = [n for _s, _r, n in sess.parse_streams([[data]])]
    assert sum(out) == 16         # full clean run after reset

    # an exception inside the consumer loop behaves like break
    gen = sess.parse_streams([[data]])
    try:
        for _ in gen:
            raise KeyboardInterrupt
    except KeyboardInterrupt:
        pass
    gen.close()
    with pytest.raises(RuntimeError, match="dirty"):
        next(sess.parse_streams([[data]]))
    sess.reset()
    assert sum(n for _s, _r, n in sess.parse_streams([[data]])) == 16


def test_streaming_parser_break_then_reuse():
    """The legacy single-stream wrapper stays permissive: breaking out of
    parse_stream and starting a new one must work (it resets the session
    under the hood)."""
    data = b"1,aa\n2,bb\n3,cc\n4,dd\n"
    sp = StreamingParser(_small_parser(), partition_bytes=6, max_carry_bytes=64)
    for _ in sp.parse_stream([data]):
        break
    total = sum(n for _r, n in sp.parse_stream([data]))
    assert total == 4


@pytest.mark.parametrize("engine", ["device", "host"])
def test_flush_delim_accounting(engine):
    """The synthetic flush delimiter is parsed but is not a source byte:
    it lands in stats.flush_delims, never in bytes_in, so device-parsed
    bytes are exactly bytes_in + bytes_reparsed + flush_delims."""
    unterminated = b"1,aa\n2,bb\n3,cc"      # flush appends one delimiter
    terminated = b"1,aa\n2,bb\n3,cc\n"      # ends on a delimiter: none needed
    for data, want in ((unterminated, 1), (terminated, 0)):
        sp = StreamingParser(_small_parser(), 6, max_carry_bytes=64,
                             engine=engine)
        list(sp.parse_stream([data]))
        assert sp.stats.records == 3, engine
        assert sp.stats.bytes_in == len(data), engine
        assert sp.stats.flush_delims == want, (engine, data)
    # PAD-only tail: no payload to terminate, no delimiter appended
    sp = StreamingParser(_small_parser(), 256, max_carry_bytes=64, engine=engine)
    list(sp.parse_stream([b"1,aa\n" + b"\x00" * 8]))
    assert sp.stats.flush_delims == 0
    # quoted newline at the very end: the record is unterminated (mid-
    # quote) but both engines judge on the raw byte VALUE, which equals
    # the delimiter — no append, and the engines agree (the malformed
    # tail is flagged by validation, not closed by a delimiter)
    sp = StreamingParser(_small_parser(), 256, max_carry_bytes=64, engine=engine)
    list(sp.parse_stream([b'1,aa\n2,"bb\n']))
    assert sp.stats.flush_delims == 0


def test_flush_delim_accounting_batched(rng):
    """flush_delims matches the solo runs stream-by-stream in a batched
    session (the host mirror predicts the device's judgement per lane)."""
    _, d0 = random_csv_table(rng, 8, ("int32", "str"))
    sources = [d0, d0.rstrip(b"\n"), b""]
    sess = StreamSession(_small_parser(), partition_bytes=16,
                         max_carry_bytes=128, n_streams=3)
    for _ in sess.parse_streams([[d] for d in sources]):
        pass
    for s, d in enumerate(sources):
        sp = StreamingParser(_small_parser(), 16, max_carry_bytes=128)
        list(sp.parse_stream([d]))
        _assert_stats_equal(sp.stats, sess.stats[s], label=f"delim/{s}: ")
    assert sess.stats[0].flush_delims == 0
    assert sess.stats[1].flush_delims == 1


def test_stream_session_no_per_partition_host_sync(monkeypatch):
    """The acceptance contract for the carry path: between dispatches the
    engine performs NO implicit device→host transfer (``int(...)`` /
    ``.item()`` / ``np.asarray``) — enforced by jax's transfer guard — and
    its one explicit per-round fetch trails the dispatch by one partition
    (the Fig. 7 dispatch-ahead overlap)."""
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=32, chunk_size=16)
    data = b"".join(b"%d,abcdefgh\n" % i for i in range(40))
    sp = StreamingParser(Parser(cfg), 100, max_carry_bytes=128)
    assert len(data) % sp.partition_bytes != 0  # no trailing empty-flush round

    # warm-up outside the guard: compilation may legitimately inspect values
    list(sp.parse_stream([data]))

    session = sp._session
    dispatches = []
    real_step = session._step

    def counting_step(*args):
        dispatches.append(1)
        return real_step(*args)

    fetches = []  # dispatch count observed at each fetch
    real_get = streaming_mod._device_get

    def counting_get(x):
        fetches.append(len(dispatches))
        return real_get(x)

    monkeypatch.setattr(session, "_step", counting_step)
    monkeypatch.setattr(streaming_mod, "_device_get", counting_get)

    with jax.transfer_guard_device_to_host("disallow"):
        parts = [n for _result, n in sp.parse_stream([data])]

    assert len(parts) > 3
    assert sum(parts) == 40
    assert len(dispatches) == len(parts)   # one dispatch per partition
    assert len(fetches) == len(parts)      # one explicit scalar fetch per round
    # dispatch-ahead: the fetch of round i happens only after round i+1 was
    # dispatched (the last round has no successor)
    for i, seen in enumerate(fetches[:-1]):
        assert seen >= i + 2, f"fetch of round {i} ran before dispatch {i + 2}"


def test_stream_session_reuse_and_jit_cache(rng):
    """A session is reusable across parse_streams calls: carry state resets,
    stats accumulate, and the compiled step is reused (no recompilation in
    the steady state)."""
    rows, data = random_csv_table(rng, 12, ("int32", "str"))
    cfg = ParserConfig(dfa=make_csv_dfa(),
                       schema=Schema.of(("a", "int32"), ("b", "str")),
                       max_records=32, chunk_size=16)
    sess = StreamSession(Parser(cfg), 64, max_carry_bytes=128)
    first = [(np.asarray(r.css), n) for _s, r, n in sess.parse_streams([[data]])]
    compiled_once = sess._step._cache_size()
    second = [(np.asarray(r.css), n) for _s, r, n in sess.parse_streams([[data]])]
    assert sess._step._cache_size() == compiled_once  # no recompilation
    assert len(first) == len(second)
    for (ca, na), (cb, nb) in zip(first, second):
        assert na == nb and np.array_equal(ca, cb)
    assert sess.stats[0].records == 2 * len(rows)
