"""The benchmark-driven autotuner (repro/tune/): cache robustness,
coordinate-descent determinism under a stubbed clock, the bit-identity
rejection gate (a backend that alters outputs can never enter the cache),
cache-driven knob resolution through ``ParserConfig(autotune=True)``
(precedence: explicit knob > cache > heuristic default), the serve-tier
ladder plumbing, and a tiny-budget end-to-end tune on both backends.

Every test that touches resolution isolates the cache chain: the user
cache is pointed at a tmp path via ``$REPRO_TUNE_CACHE`` and the chain
memo is dropped around the test, so developer machines' real caches (and
the committed seed cache, unless a test wants it) can't leak in.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core import backends as backends_mod
from repro.core import stages as stages_mod
from repro.tune import cache as cache_mod
from repro.tune import measure as measure_mod
from repro.tune import resolve as resolve_mod
from repro.tune import space as space_mod
from repro.tune import tuner

SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"))
DATA = b"1,x,1.5\n2,yy,2.5\n3,zzz,-4.0\n" * 6


def _cfg(backend="reference", **kw):
    kw.setdefault("max_records", 64)
    kw.setdefault("chunk_size", 32)
    return ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, backend=backend,
                        **kw)


@pytest.fixture
def isolated_cache(tmp_path, monkeypatch):
    """Point the user cache at an empty tmp file; drop the chain memo on
    entry and exit.  Yields the cache path (not yet existing)."""
    path = str(tmp_path / "tune_cache.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    cache_mod.reset()
    yield path
    cache_mod.reset()


def _seed_user_cache(path, cfg, knobs=None, stream=None):
    """Write a cache file resolvable by ``cfg``'s tuning key."""
    digest, echo = cache_mod.tune_key(cfg)
    entry = {"key": echo}
    if knobs is not None:
        entry["knobs"] = knobs
    if stream is not None:
        entry["stream"] = stream
    c = cache_mod.TuneCache(path)
    c.store(digest, entry)
    c.save()
    cache_mod.reset()
    return digest


# -- cache file robustness ---------------------------------------------------

def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "c.json")
    c = cache_mod.TuneCache(path)
    c.store("d1", {"knobs": {"use_matmul_scan": True}})
    c.save()
    reloaded = cache_mod.TuneCache(path)
    assert len(reloaded) == 1
    assert reloaded.lookup("d1")["knobs"] == {"use_matmul_scan": True}
    assert reloaded.lookup("missing") is None


def test_cache_section_merge(tmp_path):
    """A stream-only refresh keeps the knob section and vice versa."""
    c = cache_mod.TuneCache(str(tmp_path / "c.json"))
    c.store("d1", {"knobs": {"use_matmul_scan": True}})
    c.store("d1", {"stream": {"partition_bytes": 4096}})
    e = c.lookup("d1")
    assert e["knobs"] == {"use_matmul_scan": True}
    assert e["stream"] == {"partition_bytes": 4096}


def test_cache_lookup_is_a_copy(tmp_path):
    c = cache_mod.TuneCache(str(tmp_path / "c.json"))
    c.store("d1", {"knobs": {"window_rows": 128}})
    c.lookup("d1")["knobs"]["window_rows"] = 999
    assert c.lookup("d1")["knobs"]["window_rows"] == 128


@pytest.mark.parametrize("payload", [
    "not json at all {",
    json.dumps([1, 2, 3]),
    json.dumps({"version": 999, "entries": {"d": {}}}),
    json.dumps({"version": cache_mod.VERSION, "entries": "bogus"}),
])
def test_cache_corrupt_or_mismatched_is_empty(tmp_path, payload):
    """Missing / corrupt / version-mismatched cache files are EMPTY caches,
    never exceptions — the resolver falls back to heuristics."""
    path = tmp_path / "c.json"
    path.write_text(payload)
    c = cache_mod.TuneCache(str(path))
    assert len(c) == 0
    assert c.lookup("anything") is None


def test_cache_missing_file_is_empty(tmp_path):
    assert len(cache_mod.TuneCache(str(tmp_path / "nope.json"))) == 0


# -- search space ------------------------------------------------------------

def test_space_knobs_per_backend():
    """Pallas-only knobs never reach the reference backend's sweep (or its
    resolver), and both backends tune the shared knobs."""
    ref = backends_mod.get_backend("reference")
    pl = backends_mod.get_backend("pallas")
    ref_names = {k.name for k in space_mod.knobs_for(ref)}
    pl_names = {k.name for k in space_mod.knobs_for(pl)}
    assert "partition_impl" in ref_names and "partition_impl" in pl_names
    assert "use_matmul_scan" in ref_names
    assert "block_chunks" in pl_names and "block_chunks" not in ref_names
    assert "window_rows" in pl_names and "window_rows" not in ref_names
    # fused-pipeline knobs only exist where a fused executor exists
    assert ("fuse_pipeline" in pl_names) == (pl.execute is not None)
    assert "fuse_pipeline" not in ref_names


def test_apply_assignment_never_consults_cache():
    """Candidate configs under measurement resolve exactly their
    assignment — ``autotune`` is forced off."""
    cfg = _cfg(autotune=True)
    out = space_mod.apply_assignment(cfg, {"use_matmul_scan": True})
    assert out.autotune is False
    assert out.use_matmul_scan is True


# -- measurement core --------------------------------------------------------

def test_measure_best_keeps_best_round():
    """Injectable timer: best-of is the per-label min across rounds, with
    labels interleaved (round-robin) rather than run back to back."""
    ticks = iter([0.0, 5.0,   10.0, 11.0,    # round 1: a=5, b=1
                  20.0, 22.0, 30.0, 33.0])   # round 2: a=2, b=3
    out = measure_mod.measure_best(
        {"a": lambda: np.int32(1), "b": lambda: np.int32(2)},
        rounds=2, warmup=0, timer=lambda: next(ticks))
    assert out["a"].seconds == 2.0
    assert out["b"].seconds == 1.0
    with pytest.raises(ValueError):
        measure_mod.measure_best({"a": lambda: 1}, rounds=0)


def test_parse_signature_covers_values_and_validation():
    p = Parser(_cfg())
    sig = measure_mod.parse_signature(p.parse(DATA))
    # css + 7 geometry/carry fields + 3 planes per column + validation
    assert len(sig) >= 8 + 3 * len(SCHEMA.columns)
    assert measure_mod.signatures_equal(sig, list(sig))
    bent = list(sig)
    bent[0] = bent[0] + 1
    assert not measure_mod.signatures_equal(sig, bent)


# -- coordinate descent ------------------------------------------------------

def _stub_measure(preferred):
    """A measure_fn whose clock deterministically prefers labels containing
    any of ``preferred`` (and is otherwise stable) — descent becomes a
    pure function of the space."""
    def fn(thunks):
        out = {}
        for label, thunk in thunks.items():
            thunk()  # outputs still computed, like the real core
            fast = any(s in label for s in preferred)
            out[label] = measure_mod.Measured(0.5 if fast else 1.0, None)
        return out
    return fn


def test_descent_is_deterministic_under_stub_clock(isolated_cache):
    """Same space + same stub timings → the exact same assignment, twice;
    the stubbed winners are picked coordinate by coordinate."""
    cache = cache_mod.TuneCache(isolated_cache)
    reports = [
        tuner.tune_parse(
            _cfg(), DATA, budget=64, cache=cache,
            measure_fn=_stub_measure(("argsort", "use_matmul_scan=True",
                                      "tuned")))
        for _ in range(2)
    ]
    assert reports[0].assignment == reports[1].assignment
    assert reports[0].assignment["partition_impl"] == "argsort"
    assert reports[0].assignment["use_matmul_scan"] is True
    # the cached entry mirrors the report
    entry = cache.lookup(reports[0].digest)
    assert entry["knobs"] == reports[0].assignment
    assert entry["score"]["n_bytes"] == len(DATA)


def test_descent_budget_caps_candidates(isolated_cache):
    """The budget stops the sweep mid-space; the partial assignment is
    still returned and cached (a partial tune is a valid tune)."""
    cache = cache_mod.TuneCache(isolated_cache)
    rep = tuner.tune_parse(
        _cfg(), DATA, budget=2, cache=cache,
        measure_fn=_stub_measure(("argsort",)))
    assert rep.budget_exhausted
    assert rep.evaluated <= 2 + 1  # incumbents are measured regardless
    assert cache.lookup(rep.digest) is not None


def test_final_head_to_head_demotes_noise_winners(isolated_cache):
    """A clock that flips preference in the final defaults-vs-tuned group
    demotes the sweep's 'winner' back to the all-defaults assignment."""
    calls = {"n": 0}

    def flipping(thunks):
        out = {}
        for label, thunk in thunks.items():
            thunk()
            if "defaults" in label or "tuned" in label:
                # the final group: defaults win
                fast = label == "defaults"
            else:
                fast = "argsort" in label or "True" in label
            out[label] = measure_mod.Measured(0.5 if fast else 1.0, None)
        calls["n"] += 1
        return out

    rep = tuner.tune_parse(_cfg(), DATA, budget=64, cache=None,
                           measure_fn=flipping)
    backend = backends_mod.get_backend("reference")
    assert rep.assignment == space_mod.defaults_for(backend)
    assert rep.seconds == rep.baseline_seconds


# -- the bit-identity gate ---------------------------------------------------

def test_identity_gate_rejects_output_altering_backend(isolated_cache):
    """A backend whose int32 conversion is off by one: every candidate
    mismatches the reference oracle, nothing is timed, nothing cached."""
    ref = backends_mod.get_backend("reference")

    def bent_int(css, offset, length, cfg):
        p = ref.parse_field["int32"](css, offset, length, cfg)
        return p._replace(value=p.value + 1)

    backends_mod.register_backend(dataclasses.replace(
        ref, name="bent", parse_field=dict(ref.parse_field, int32=bent_int)))
    try:
        cache = cache_mod.TuneCache(isolated_cache)
        rep = tuner.tune_parse(_cfg(backend="bent"), DATA, budget=8,
                               cache=cache)
        assert rep.trials and all(t.rejected for t in rep.trials)
        assert all("mismatch" in t.rejected for t in rep.trials)
        assert len(cache) == 0
        assert rep.seconds == float("inf")
    finally:
        del backends_mod.BACKENDS["bent"]


# -- cache-driven resolution (ParserConfig(autotune=True)) -------------------

def test_autotune_cold_cache_is_a_noop(isolated_cache):
    """Cold cache: autotune=True resolves nothing — byte-identical plans
    to the pre-autotuner behaviour."""
    cfg = _cfg(autotune=True)
    plain = _cfg()
    for k in space_mod.SPACE:
        assert getattr(cfg, k.name, None) == getattr(plain, k.name, None)


def test_autotune_resolves_cached_knobs(isolated_cache):
    digest = _seed_user_cache(
        isolated_cache, _cfg(),
        knobs={"partition_impl": "argsort", "use_matmul_scan": True})
    cfg = _cfg(autotune=True)
    assert cache_mod.tune_key(cfg)[0] == digest  # knob fields excluded
    assert cfg.partition_impl == "argsort"
    assert cfg.use_matmul_scan is True


def test_explicit_knob_beats_cache(isolated_cache):
    _seed_user_cache(isolated_cache, _cfg(),
                     knobs={"partition_impl": "argsort"})
    cfg = _cfg(autotune=True, partition_impl="scatter2")
    assert cfg.partition_impl == "scatter2"


def test_stale_cache_value_falls_back_to_heuristic(isolated_cache):
    """'kernel' is not a reference-backend partition impl; a cache entry
    claiming it (stale / hand-edited / foreign device) resolves nothing."""
    _seed_user_cache(isolated_cache, _cfg(),
                     knobs={"partition_impl": "kernel", "window_rows": 128})
    cfg = _cfg(autotune=True)
    assert cfg.partition_impl == "auto"     # heuristic default survives
    assert cfg.window_rows == 0             # pallas-only knob never applies


def test_autotune_drives_execute_path(isolated_cache):
    """fuse_pipeline from the cache flows into ParsePlan.execute_path —
    the staged-vs-fused tier choice is cache-driven end to end."""
    base = _cfg(backend="pallas")
    _seed_user_cache(isolated_cache, base, knobs={"fuse_pipeline": True})
    cfg = _cfg(backend="pallas", autotune=True)
    assert cfg.fuse_pipeline is True
    plan = stages_mod.plan_parse(cfg, backends_mod.get_backend("pallas"))
    assert plan.execute_path == "fused"
    # and False pins staged even if a heuristic would later prefer fused
    _seed_user_cache(isolated_cache, base, knobs={"fuse_pipeline": False})
    cfg2 = _cfg(backend="pallas", autotune=True)
    plan2 = stages_mod.plan_parse(cfg2, backends_mod.get_backend("pallas"))
    assert plan2.execute_path == "staged"


def test_autotuned_outputs_bit_identical(isolated_cache):
    """Resolution changes schedules, never outputs: tuned and default
    configs parse bit-identically."""
    _seed_user_cache(
        isolated_cache, _cfg(),
        knobs={"partition_impl": "argsort", "use_matmul_scan": True})
    sig_plain = measure_mod.parse_signature(Parser(_cfg()).parse(DATA))
    sig_tuned = measure_mod.parse_signature(
        Parser(_cfg(autotune=True)).parse(DATA))
    assert measure_mod.signatures_equal(sig_plain, sig_tuned)


# -- serve-tier ladder -------------------------------------------------------

def test_tuned_serve_tiers_validation(isolated_cache):
    cfg = _cfg()
    # cold cache → default
    assert resolve_mod.tuned_serve_tiers(cfg, (1, 4)) == (1, 4)
    for bad in ([], [4, 1], [1, "x"], [0, 2], "14"):
        _seed_user_cache(isolated_cache, cfg, stream={"serve_tiers": bad})
        assert resolve_mod.tuned_serve_tiers(cfg, (1, 4)) == (1, 4)
    _seed_user_cache(isolated_cache, cfg, stream={"serve_tiers": [1, 2, 8]})
    assert resolve_mod.tuned_serve_tiers(cfg, (1, 4)) == (1, 2, 8)


def test_tuned_stream_partition_bytes(isolated_cache):
    cfg = _cfg()
    assert resolve_mod.tuned_stream_partition_bytes(cfg, 4096) == 4096
    _seed_user_cache(isolated_cache, cfg, stream={"partition_bytes": 1 << 16})
    assert resolve_mod.tuned_stream_partition_bytes(cfg, 4096) == 1 << 16


def test_service_resolves_per_group_ladder(isolated_cache):
    """ParseService(tiers=None) pulls each tenant group's measured ladder
    from the cache at submit; an explicit ladder disables resolution."""
    from repro.serve import ParseService

    _seed_user_cache(isolated_cache, _cfg(), stream={"serve_tiers": [1, 2]})
    svc = ParseService(max_queued_partitions=64, start=False)
    t = svc.submit(_cfg(), b"1,x,1.5\n", partition_bytes=256)
    assert svc.group_tiers(t.group) == (1, 2)
    assert svc.tier_for(2, t.group) == 2
    assert svc.tier_for(5, t.group) == 2   # top tier caps oversized groups
    svc.step()
    svc.close()

    explicit = ParseService(tiers=(1, 16), max_queued_partitions=64,
                            start=False)
    t2 = explicit.submit(_cfg(), b"1,x,1.5\n", partition_bytes=256)
    assert explicit.group_tiers(t2.group) == (1, 16)
    explicit.close()


# -- tiny-budget end-to-end --------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_tiny_budget_e2e_smoke(isolated_cache, backend):
    """A real (non-stubbed) tune with a 3-candidate budget: measures,
    caches, and the cached knobs resolve through autotune=True."""
    cache = cache_mod.TuneCache(isolated_cache)
    rep = tuner.tune_parse(_cfg(backend=backend), DATA, budget=3, rounds=1,
                           warmup=0, cache=cache)
    assert rep.seconds < float("inf")
    assert rep.evaluated <= 3 + 1
    entry = cache.lookup(rep.digest)
    assert entry is not None and entry["score"]["us_per_call"] > 0
    cache_mod.reset()  # the autotune below must see the fresh file
    cfg = _cfg(backend=backend, autotune=True)
    be = backends_mod.get_backend(backend)
    for k in space_mod.knobs_for(be):
        v = getattr(cfg, k.name)
        assert v == k.default or k.valid(be, v)


def test_tune_stream_writes_section(isolated_cache):
    cache = cache_mod.TuneCache(isolated_cache)
    datas = [b"1,x,1.5\n2,y,2.5\n" * 8] * 2
    sec = tuner.tune_stream(
        _cfg(), datas, partition_candidates=(256, 512), tiers=(1, 2),
        cache=cache, repeats=1)
    assert sec["partition_bytes"] in (256, 512)
    assert sec["serve_tiers"] and sec["serve_tiers"][0] == 1
    entry = cache.lookup(cache_mod.tune_key(_cfg())[0])
    assert entry["stream"]["partition_bytes"] == sec["partition_bytes"]


# -- the committed seed cache ------------------------------------------------

def test_seed_cache_resolves_formats_staged(isolated_cache):
    """The committed interpret-CPU seed cache encodes the BENCH-observed
    megakernel regressions: clf / jsonl / zone resolve to the staged tier
    on the pallas backend (csv is the fused win and is deliberately not
    pinned here).  ``isolated_cache`` points the user cache at an empty
    tmp file, so this reads the seed layer alone."""
    from repro.configs.parse_formats import tuned_parser_config

    if not os.path.exists(cache_mod.seed_cache_path()):
        pytest.skip("seed cache not built")
    pl = backends_mod.get_backend("pallas")
    for fmt in ("clf", "jsonl", "zone"):
        cfg = tuned_parser_config(fmt, max_records=1 << 10, backend="pallas")
        assert cfg.autotune is True
        plan = stages_mod.plan_parse(cfg, pl)
        assert plan.execute_path == "staged", (
            f"{fmt}: seed cache should resolve the megakernel OFF "
            f"(fuse_pipeline={cfg.fuse_pipeline})")
