"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from jaxpr_utils import gathers_outside_pallas as _gathers_outside_pallas
from repro.core import make_csv_dfa, make_log_dfa, make_simple_dfa

# ---------------------------------------------------------------------------
# dfa_scan
# ---------------------------------------------------------------------------

DFAS = {"csv": make_csv_dfa(), "clf": make_log_dfa(), "simple": make_simple_dfa()}


@pytest.mark.parametrize("dfa_name", list(DFAS))
@pytest.mark.parametrize("n_chunks,chunk_bytes,block", [
    (64, 32, 64), (256, 64, 128), (128, 31, 32), (512, 16, 256),
])
def test_dfa_scan_chunk_vectors(rng, dfa_name, n_chunks, chunk_bytes, block):
    from repro.kernels.dfa_scan import ops, ref
    dfa = DFAS[dfa_name]
    alphabet = np.frombuffer(b',"\n# ab[]\t', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=n_chunks * chunk_bytes)]
        .reshape(n_chunks, chunk_bytes)
    )
    got = ops.chunk_vectors(chunks, dfa, block_chunks=block)
    want = ref.chunk_vectors(chunks, dfa)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dfa_name", list(DFAS))
def test_dfa_scan_replay(rng, dfa_name):
    from repro.kernels.dfa_scan import ops, ref
    dfa = DFAS[dfa_name]
    alphabet = np.frombuffer(b',"\n#xy z', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=256 * 48)].reshape(256, 48)
    )
    starts = jnp.asarray(rng.integers(0, dfa.n_states, size=256), jnp.int32)
    c_k, e_k = ops.replay(chunks, starts, dfa, block_chunks=64)
    c_r, e_r = ref.replay(chunks, starts, dfa)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


def test_dfa_scan_end_to_end_matches_pipeline(rng):
    from repro.kernels.dfa_scan import ops
    from repro.core.transition import transition_pipeline
    dfa = DFAS["csv"]
    alphabet = np.frombuffer(b',"\nabc', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=512 * 64)].reshape(512, 64)
    )
    cls_k, _ = ops.parse_classes(chunks, dfa)
    cls_j, _, _ = transition_pipeline(chunks, dfa)
    np.testing.assert_array_equal(np.asarray(cls_k), np.asarray(cls_j))


# ---------------------------------------------------------------------------
# numparse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [8, 11, 16])
@pytest.mark.parametrize("rows,block", [(512, 128), (1024, 512)])
def test_numparse_int(rng, width, rows, block):
    from repro.kernels.numparse import ops as k_ops
    from repro.kernels.numparse import ref as k_ref
    # mixture of valid ints, junk, empties
    strs = []
    for _ in range(rows):
        u = rng.random()
        if u < 0.6:
            strs.append(str(int(rng.integers(-10**8, 10**8))))
        elif u < 0.75:
            strs.append("x1y")
        elif u < 0.85:
            strs.append("")
        else:
            strs.append("+%d" % int(rng.integers(0, 10**6)))
    byts = np.zeros((rows, width), np.uint8)
    lens = np.zeros((rows,), np.int32)
    for i, s in enumerate(strs):
        bs = s.encode()[:width]
        byts[i, : len(bs)] = np.frombuffer(bs, np.uint8)
        lens[i] = len(bs)
    got_v, got_ok = k_ops.parse_int_fields(jnp.asarray(byts), jnp.asarray(lens), block_rows=block)
    want_v, want_ok = k_ref.parse_int_fields(jnp.asarray(byts), jnp.asarray(lens))
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    ok = np.asarray(got_ok)
    np.testing.assert_array_equal(np.asarray(got_v)[ok], np.asarray(want_v)[ok])
    # cross-check against python int() (on the width-truncated field the
    # kernel actually saw)
    for i, s in enumerate(strs):
        if ok[i]:
            assert int(np.asarray(got_v)[i]) == int(s[:width]), (i, s)


def _pack_rows(strs, width):
    byts = np.zeros((len(strs), width), np.uint8)
    lens = np.zeros((len(strs),), np.int32)
    for i, s in enumerate(strs):
        bs = s.encode()[:width]
        byts[i, : len(bs)] = np.frombuffer(bs, np.uint8)
        lens[i] = len(bs)
    return jnp.asarray(byts), jnp.asarray(lens)


def test_numparse_int_overflow(rng):
    """Magnitude overflow clears ok on the kernel exactly like the jnp ref."""
    from repro.kernels.numparse import ops as k_ops
    from repro.kernels.numparse import ref as k_ref
    strs = ["2147483647", "-2147483647", "2147483648", "-2147483648",
            "9999999999", "0000000001", "00000000000042", "12345678901"]
    strs += [str(int(v)) for v in rng.integers(2**31 - 100, 2**31 + 100, size=24)]
    byts, lens = _pack_rows(strs, 16)
    got_v, got_ok = k_ops.parse_int_fields(byts, lens, block_rows=len(strs))
    want_v, want_ok = k_ref.parse_int_fields(byts, lens)
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    ok = np.asarray(got_ok)
    np.testing.assert_array_equal(np.asarray(got_v)[ok], np.asarray(want_v)[ok])
    for s, o in zip(strs, ok):
        assert bool(o) == (abs(int(s)) <= 2**31 - 1), s


@pytest.mark.parametrize("width", [16, 24])
@pytest.mark.parametrize("rows,block", [(512, 128), (256, 256)])
def test_numparse_float(rng, width, rows, block):
    """Float kernel is bit-identical to the jnp reference — values AND ok."""
    from repro.kernels.numparse import ops as k_ops
    from repro.kernels.numparse import ref as k_ref
    strs = []
    for _ in range(rows):
        u = rng.random()
        if u < 0.45:
            strs.append(f"{rng.normal() * 10.0 ** int(rng.integers(-6, 7)):.6g}")
        elif u < 0.6:
            strs.append(f"{rng.integers(-1000, 1000)}e{rng.integers(-40, 41)}")
        elif u < 0.7:
            strs.append(rng.choice([".", "+.5", "-.", "3.", "1e", "1e+", "1.2.3",
                                    "1e39", "-1e-39", "+", ""]))
        elif u < 0.8:
            strs.append("x%.2f" % rng.random())
        else:
            strs.append(str(int(rng.integers(-10**9, 10**9))))
    byts, lens = _pack_rows(strs, width)
    got_v, got_ok = k_ops.parse_float_fields(byts, lens, block_rows=block)
    want_v, want_ok = k_ref.parse_float_fields(byts, lens)
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    ok = np.asarray(got_ok)
    # bit-for-bit on parsed values (inf from overflow included)
    np.testing.assert_array_equal(np.asarray(got_v)[ok], np.asarray(want_v)[ok])


@pytest.mark.parametrize("rows,block", [(512, 128), (100, 50)])
def test_numparse_date(rng, rows, block):
    """Date kernel is bit-identical to the jnp reference — values AND ok."""
    from repro.kernels.numparse import ops as k_ops
    from repro.kernels.numparse import ref as k_ref
    strs = []
    for _ in range(rows):
        u = rng.random()
        y, m, d = rng.integers(1902, 2038), rng.integers(1, 13), rng.integers(1, 32)
        if u < 0.5:
            strs.append(f"{y:04d}-{m:02d}-{d:02d}")
        elif u < 0.8:
            hh, mm, ss = rng.integers(0, 25), rng.integers(0, 61), rng.integers(0, 61)
            sep = " " if rng.random() < 0.8 else "T"
            strs.append(f"{y:04d}-{m:02d}-{d:02d}{sep}{hh:02d}:{mm:02d}:{ss:02d}")
        else:
            strs.append(rng.choice(["", "junk", "2024-1-01", "2024/01/01",
                                    "2024-01-01x00:00:00", "2024-00-10"]))
    byts, lens = _pack_rows(strs, 19)
    got_v, got_ok = k_ops.parse_date_fields(byts, lens, block_rows=block)
    want_v, want_ok = k_ref.parse_date_fields(byts, lens)
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(got_ok)],
                                  np.asarray(want_v)[np.asarray(got_ok)])


# ---------------------------------------------------------------------------
# numparse — fused gather+convert variants
# ---------------------------------------------------------------------------

def _pack_css(strs):
    """Concatenate field strings into a CSS + (offset, length) index."""
    lens = np.asarray([len(s) for s in strs], np.int32)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    css = np.frombuffer("".join(strs).encode(), np.uint8)
    if css.size == 0:
        css = np.zeros(1, np.uint8)
    return jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens)


def _fused_cases(rng, rows):
    ints, floats, dates = [], [], []
    for _ in range(rows):
        u = rng.random()
        if u < 0.15:
            junk = rng.choice(["", "x1y", "+", ".", "1e", "9" * 12, "2024-13-01"])
            ints.append(junk); floats.append(junk); dates.append(junk)
            continue
        ints.append(str(int(rng.integers(-(2**33), 2**33))))
        floats.append(f"{rng.normal() * 10.0 ** int(rng.integers(-6, 7)):.6g}")
        y, m, d = rng.integers(1970, 2038), rng.integers(1, 13), rng.integers(1, 32)
        dates.append(f"{y:04d}-{m:02d}-{d:02d}" if rng.random() < 0.5 else
                     f"{y:04d}-{m:02d}-{d:02d} {rng.integers(0,24):02d}:"
                     f"{rng.integers(0,60):02d}:{rng.integers(0,60):02d}")
    return ints, floats, dates


@pytest.mark.parametrize("rows,block", [(500, 128), (512, 512), (33, 16)])
def test_numparse_fused_matches_unfused_and_typeconv(rows, block):
    """The fused (css, offset, length) kernels are bit-identical to the
    unfused gather+kernel path AND to the jnp typeconv oracle — value,
    valid and empty alike."""
    from repro.core import typeconv
    from repro.kernels.numparse import ops as k_ops
    # local generator: the session `rng` fixture's stream is order-sensitive
    ints, floats, dates = _fused_cases(np.random.default_rng(rows + block), rows)
    cases = [
        (ints, k_ops.parse_int_column_fused, k_ops.parse_int_column,
         lambda c, o, l: typeconv.parse_int(c, o, l, width=11)),
        (floats, k_ops.parse_float_column_fused, k_ops.parse_float_column,
         lambda c, o, l: typeconv.parse_float(c, o, l, width=24)),
        (dates, k_ops.parse_date_column_fused, k_ops.parse_date_column,
         typeconv.parse_date),
    ]
    for strs, fused, unfused, oracle in cases:
        css, offs, lens = _pack_css(strs)
        got = fused(css, offs, lens, block_rows=block)
        # vs the unfused kernel: bit-identical on everything (shared arith).
        want = unfused(css, offs, lens, block_rows=block)
        for f in ("value", "valid", "empty"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                err_msg=f"{fused.__name__} vs unfused: {f}")
        # vs typeconv: valid/empty exact; values where valid (the garbage
        # value of an *invalid* field is unspecified across Horner variants
        # — stages.materialize normalises it to 0 before anyone sees it).
        ref = oracle(css, offs, lens)
        np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(ref.valid),
                                      err_msg=f"{fused.__name__} vs typeconv: valid")
        np.testing.assert_array_equal(np.asarray(got.empty), np.asarray(ref.empty),
                                      err_msg=f"{fused.__name__} vs typeconv: empty")
        ok = np.asarray(got.valid)
        np.testing.assert_array_equal(np.asarray(got.value)[ok],
                                      np.asarray(ref.value)[ok],
                                      err_msg=f"{fused.__name__} vs typeconv: value")


def test_numparse_fused_field_at_css_end():
    """Fields touching the last CSS byte must not read out of bounds (the
    fused kernels width-pad the buffer; the unfused gather clamps)."""
    from repro.kernels.numparse import ops as k_ops
    strs = ["123", "-45", "678"]
    css, offs, lens = _pack_css(strs)
    got = k_ops.parse_int_column_fused(css, offs, lens)
    want = k_ops.parse_int_column(css, offs, lens)
    for f in ("value", "valid", "empty"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)))


def test_numparse_fused_issues_no_xla_gather():
    """Acceptance bar for the fusion: between the field index and type
    conversion the pallas backend issues no XLA-level take/gather — the
    fused kernels own the CSS indexing.  The unfused path is the positive
    control proving the detector sees the gather it is supposed to kill."""
    import jax
    from repro.core import ParserConfig, Schema, get_backend, make_csv_dfa

    be = get_backend("pallas")
    css = jnp.zeros(257, jnp.uint8)
    off = jnp.zeros(64, jnp.int32)
    ln = jnp.zeros(64, jnp.int32)
    schema = Schema.of(("i", "int32"), ("f", "float32"), ("d", "date"))

    # default config = windowed fused path; window_rows=-1 = whole-CSS fused
    for window_rows in (0, -1):
        fused_cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema,
                                 max_records=64, backend="pallas",
                                 fuse_typeconv=True, window_rows=window_rows)
        for dtype in ("int32", "float32", "date"):
            jx = jax.make_jaxpr(
                lambda c, o, l: be.parse_field[dtype](c, o, l, fused_cfg)
            )(css, off, ln)
            assert not _gathers_outside_pallas(jx.jaxpr), (window_rows, dtype)

    unfused_cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=64,
                               backend="pallas", fuse_typeconv=False)
    jx = jax.make_jaxpr(
        lambda c, o, l: be.parse_field["int32"](c, o, l, unfused_cfg)
    )(css, off, ln)
    assert _gathers_outside_pallas(jx.jaxpr)  # detector sanity check


# ---------------------------------------------------------------------------
# flashattn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (2, 4, 4, 256, 256, 64, True, None),
    (1, 8, 2, 128, 256, 64, False, None),   # GQA, cross-attn style
    (1, 4, 1, 256, 256, 32, True, 128),     # MQA + sliding window
    (2, 2, 2, 384, 384, 128, True, None),
])
def test_flashattn_vs_ref(rng, dtype, b, hq, hkv, sq, skv, d, causal, window):
    from repro.kernels.flashattn import ops as f_ops
    from repro.kernels.flashattn import ref as f_ref
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    got = f_ops.flash_attention(q, k, v, causal=causal, window=window, block_q=128, block_kv=128)
    want = f_ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flashattn_block_shape_sweep(rng):
    from repro.kernels.flashattn import ops as f_ops
    from repro.kernels.flashattn import ref as f_ref
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    want = f_ref.flash_attention(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = f_ops.flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dfa_name", list(DFAS))
def test_dfa_scan_replay_fused_summaries(rng, dfa_name):
    """Fused replay+summary kernel == separate replay + chunk_summaries."""
    from repro.core import offsets as offs_mod
    from repro.core.transition import byte_groups, replay as jnp_replay
    from repro.kernels.dfa_scan import ops
    import jax.numpy as jnp

    dfa = DFAS[dfa_name]
    alphabet = np.frombuffer(b',"\n#xy z', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=256 * 32)].reshape(256, 32)
    )
    starts = jnp.zeros((256,), jnp.int32) + dfa.start_state

    cls_k, ends_k, summ = ops.replay_fused(chunks, starts, dfa, block_chunks=64)
    groups = byte_groups(chunks, dfa)
    cls_r, ends_r, _ = jnp_replay(groups, starts, dfa)
    np.testing.assert_array_equal(np.asarray(cls_k), np.asarray(cls_r))
    np.testing.assert_array_equal(np.asarray(ends_k), np.asarray(ends_r))

    ref = offs_mod.chunk_summaries(cls_r)
    np.testing.assert_array_equal(np.asarray(summ[:, 0]), np.asarray(ref.rec_count))
    np.testing.assert_array_equal(np.asarray(summ[:, 1]), np.asarray(ref.col_tag))
    np.testing.assert_array_equal(np.asarray(summ[:, 2]), np.asarray(ref.col_off))


@pytest.mark.parametrize("n_chunks", [96, 100])  # 100 % 32 != 0: pad path
def test_dfa_scan_parse_contexts(rng, n_chunks):
    """Fused §3.1+§3.2 entry == jnp pipeline + chunk_summaries, including
    chunk counts that do not divide block_chunks."""
    from repro.core import offsets as offs_mod
    from repro.core.transition import transition_pipeline
    from repro.kernels.dfa_scan import ops

    dfa = DFAS["csv"]
    alphabet = np.frombuffer(b',"\nabc', np.uint8)
    chunks = jnp.asarray(
        alphabet[rng.integers(0, len(alphabet), size=n_chunks * 32)]
        .reshape(n_chunks, 32)
    )
    cls_k, ends_k, summ = ops.parse_contexts(chunks, dfa, block_chunks=32)
    cls_j, ends_j, _ = transition_pipeline(chunks, dfa)
    np.testing.assert_array_equal(np.asarray(cls_k), np.asarray(cls_j))
    np.testing.assert_array_equal(np.asarray(ends_k), np.asarray(ends_j))
    ref = offs_mod.chunk_summaries(cls_j)
    np.testing.assert_array_equal(np.asarray(summ[:, 0]), np.asarray(ref.rec_count))
    np.testing.assert_array_equal(np.asarray(summ[:, 1]), np.asarray(ref.col_tag))
    np.testing.assert_array_equal(np.asarray(summ[:, 2]), np.asarray(ref.col_off))
