"""Format conformance: every registered format × every backend × every
tagging mode × oneshot/streaming, bit-for-bit against its pure-Python
oracle (the tentpole acceptance bar for the format registry).

For each format in ``repro.core.formats`` the matrix is:

  * backends — ``reference``, staged ``pallas``, and the whole-pipeline
    megakernel (``pallas-fused``); pallas results must equal reference
    bit-for-bit (``_assert_results_equal``: CSS, field index, values,
    masks, validation),
  * tagging — every mode the format's spec declares (tagged/inline/vector
    for all built-ins),
  * drivers — oneshot ``Parser.parse`` and multi-partition
    ``StreamingParser`` with mid-record splits,

and the reference output is checked field-by-field against the format's
sequential oracle (``tests/oracles/``): record count, exact string bytes
through the CSS, int/float/date validity+values, empty masks.

The canonical inputs are small but adversarial per dialect: quoted
delimiters and embedded newlines (csv/tsv), comment lines (csv+comment,
zone), double-bracket scopes (clf), nested containers and raw escapes
(jsonl), multi-line parenthesized records and whitespace-run collapsing
(zone).  Deep random coverage lives in tests/test_fuzz_differential.py;
this suite pins the *dialects*.
"""
import numpy as np
import pytest

from repro.core import Parser, formats
from repro.core.streaming import StreamingParser
from tests import oracles  # noqa: F401 — import attaches oracles to the registry
from tests.test_backend_parity import _assert_results_equal
from tests.test_fuzz_differential import (
    check_float_value,
    oracle_date,
    oracle_float_valid,
    oracle_int,
)

BACKENDS = ("reference", "pallas", "pallas-fused")

# One hand-written input per format, exercising that dialect's corners.
# Every record carries exactly n_cols fields unless the dialect itself
# mints extras (zone's paren trailing-empty — the schema clamp drops them).
CANONICAL = {
    "csv": (b'1,"a,b",3.5,2024-02-29\n'
            b'-7,"he""llo",.25,2023-01-01 12:30:00\n'
            b',wor#ld,1e3,not-a-date\n'
            b'2147483648,"line\nbreak",+0.5,2024-12-31\n'),
    "csv+comment": (b'# header comment\n'
                    b'1,"a,b",3.5,2024-02-29\n'
                    b'-7,x,.25,2023-01-01 12:30:00\n'
                    b'# mid-table comment\n'
                    b',world,1e3,not-a-date\n'),
    "tsv": (b'1\t"a\tb"\t3.5\t2024-02-29\n'
            b'-7\t"he""llo"\t.25\t2023-01-01 12:30:00\n'
            b'\two,rld\t1e3\tnot-a-date\n'),
    "simple": b'1,2.5\n-22,1e3\n,+.25\n9999999999,junk\n',
    "clf": (b'h1 [01/Jan/2024 10:00:00] "GET /a b" 200\n'
            b'h2.example [02/Feb "x] "POST /c\nd" -7\n'
            b'h3 [t] "r" 404\n'),
    "jsonl": (b'{"id": 7, "name": "alpha", "score": 1.5}\n'
              b'{"id": -3, "name": "a,b:c", "score": 2e3}\n'
              b'\n'
              b'{"id": 007, "name": {"nested": [1, 2]}, "score": .5}\n'
              b'{"id": 2147483648, "name": "es\\"c", "score": x}\n'),
    "zone": (b'example.com 3600 IN A 1.2.3.4\n'
             b'www\t600\tIN\tCNAME\texample.com; trailing comment\n'
             b'; full-line comment\n'
             b'\n'
             b'sub 7200 ( IN\n   TXT ) hello\n'
             b'par 100 IN TXT ( d1 d2 )\n'
             b'host 99x IN A 5.6.7.8\n'),
}

_CACHE = {}


def parser_for(name, backend, tagging):
    key = (name, backend, tagging)
    if key not in _CACHE:
        fused = backend == "pallas-fused"
        be = "pallas" if fused else backend
        _CACHE[key] = Parser(formats.parser_config(
            name, max_records=64, chunk_size=32, backend=be, tagging=tagging,
            fuse_pipeline=fused,
            # pin the radix partition kernel on pallas so conformance covers
            # the kernel path (interpret-mode "auto" picks the jnp pass)
            partition_impl="kernel" if be == "pallas" else "auto"))
        if fused:
            assert _CACHE[key].plan.execute_path == "fused"
    return _CACHE[key]


def _check_against_oracle(res, parser, records):
    """Reference output vs the oracle's list-of-records-of-field-bytes."""
    schema = parser.cfg.schema
    assert int(res.validation.n_records) == len(records)
    assert bool(res.validation.ok)
    arrow = parser.to_arrow(res)
    for c, col in enumerate(schema.columns):
        parsed = res.values[col.name]
        valid = np.asarray(parsed.valid)
        empty = np.asarray(parsed.empty)
        values = np.asarray(parsed.value)
        a = arrow[col.name]
        for r, row in enumerate(records):
            # oracle fields beyond n_cols are the schema clamp's discard
            field = row[c] if c < len(row) else b""
            s = field.decode("latin-1")
            assert bool(empty[r]) == (field == b""), (col.name, r, field)
            if col.dtype == "int32":
                want_ok, want = oracle_int(s)
                assert bool(valid[r]) == want_ok, (col.name, r, s)
                if want_ok:
                    assert int(values[r]) == want, (col.name, r, s)
            elif col.dtype == "float32":
                want_ok = oracle_float_valid(s)
                assert bool(valid[r]) == want_ok, (col.name, r, s)
                if want_ok:
                    check_float_value(s, values[r])
            elif col.dtype == "date":
                want_ok, want = oracle_date(s)
                assert bool(valid[r]) == want_ok, (col.name, r, s)
                if want_ok:
                    assert int(values[r]) == want, (col.name, r, s)
            else:  # str round-trips exactly through the CSS
                got = bytes(a["data"][a["offsets"][r]: a["offsets"][r + 1]])
                assert got == field, (col.name, r, field, got)


def _matrix():
    return [(name, tagging)
            for name in formats.available_formats()
            for tagging in formats.get_format(name).tagging_modes]


def test_canonical_covers_registry():
    """A newly registered format must bring a canonical input (and, via
    tests/oracles, an oracle) or conformance fails loudly."""
    assert set(CANONICAL) == set(formats.available_formats())
    for name in formats.available_formats():
        assert formats.get_format(name).oracle is not None, name


@pytest.mark.parametrize("name,tagging", _matrix())
def test_format_oneshot(name, tagging):
    data = CANONICAL[name]
    records = formats.get_format(name).oracle(data)
    assert records, name  # canonical inputs parse to at least one record
    res = {be: parser_for(name, be, tagging).parse(data) for be in BACKENDS}
    _assert_results_equal(res["reference"], res["pallas"],
                          label=f"{name}/{tagging}: ")
    _assert_results_equal(res["reference"], res["pallas-fused"],
                          label=f"{name}/{tagging} fused: ")
    _check_against_oracle(res["reference"],
                          parser_for(name, "reference", tagging), records)


@pytest.mark.parametrize("name", sorted(CANONICAL))
def test_format_streaming(name):
    """Multi-partition streaming: mid-record splits (including inside
    quotes/brackets/parens/nested containers) must carry correctly on all
    backends, and totals must match the oracle."""
    spec = formats.get_format(name)
    data = CANONICAL[name] * 4
    records = spec.oracle(data)
    outs = {}
    for be in BACKENDS:
        sp = StreamingParser(parser_for(name, be, spec.tagging),
                             partition_bytes=96, max_carry_bytes=256)
        outs[be] = list(sp.parse_stream([data]))
        assert sp.stats.partitions > 1, name
        assert sp.stats.records == len(records), (name, be)
    for be in ("pallas", "pallas-fused"):
        assert len(outs[be]) == len(outs["reference"])
        for (r, n_r), (q, n_q) in zip(outs["reference"], outs[be]):
            assert n_r == n_q
            _assert_results_equal(r, q, label=f"{name}/{be} stream: ")
    assert sum(n for _, n in outs["reference"]) == len(records)


def test_parser_config_rejects_unsupported_tagging():
    spec = formats.get_format("csv")
    restricted = formats.FormatSpec(
        name="csv-tagged-only", make_dfa=spec.make_dfa,
        default_schema=spec.default_schema, tagging_modes=("tagged",))
    formats.register_format(restricted)
    try:
        with pytest.raises(ValueError, match="does not support tagging"):
            formats.parser_config("csv-tagged-only", tagging="vector")
    finally:
        formats._REGISTRY.pop("csv-tagged-only")


def test_register_rejects_malformed_dfa():
    """Registration runs Dfa.validate_tables — a table whose group 0 is not
    the record delimiter (prepare/streaming contract) must be rejected."""
    import dataclasses

    from repro.core import make_simple_dfa

    def bad():
        dfa = make_simple_dfa()
        em = dfa.emission.copy()
        em[:, 0] = 0  # record-delim group never emits RECORD_DELIM
        return dataclasses.replace(dfa, emission=em)

    with pytest.raises((ValueError, AssertionError)):
        formats.register_format(formats.FormatSpec(
            name="bad", make_dfa=bad,
            default_schema=formats.get_format("simple").default_schema))
    assert "bad" not in formats.available_formats()
