"""Partition kernel validation (kernels/partition): bit-identical to every
jnp partition impl, stable, and drop-in across the end-to-end pipeline.

A stable partition's permutation is unique, so the kernel must agree with
``partition_argsort`` / ``partition_scatter`` / ``partition_scatter2``
*exactly* — perm, col_start and col_count — for any tag stream, including
ones that do not divide the kernel's block sizes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from repro.core import partition as partition_mod
from repro.kernels.partition import ops as part_ops
from repro.kernels.partition import partition as part_kernels
from repro.kernels.partition import ref as part_ref
from tests.test_backend_parity import _assert_results_equal

# n exercises: < one block, exact block multiples, straggler blocks, and
# grid-step (block_rows) padding; c covers tiny and paper-sized widths.
SIZES = [(1, 1), (513, 5), (4096, 17), (4100, 2), (9999, 8)]


@pytest.mark.parametrize("n,c", SIZES)
def test_kernel_matches_all_jnp_impls(n, c):
    # local generator: the session `rng` fixture's stream is order-sensitive
    rng = np.random.default_rng(n * 31 + c)
    tags = jnp.asarray(rng.integers(0, c + 1, size=n), jnp.int32)  # incl. sentinel
    got = part_ops.partition_tags(tags, c)
    oracle = part_ref.partition_tags(tags, c)
    for name, impl in {**partition_mod.PARTITION_IMPLS, "ref": lambda t, k: oracle}.items():
        want = impl(tags, c)
        np.testing.assert_array_equal(
            np.asarray(got.perm), np.asarray(want.perm), err_msg=f"perm vs {name}")
        np.testing.assert_array_equal(
            np.asarray(got.col_start), np.asarray(want.col_start),
            err_msg=f"col_start vs {name}")
        np.testing.assert_array_equal(
            np.asarray(got.col_count), np.asarray(want.col_count),
            err_msg=f"col_count vs {name}")


def test_kernel_nondefault_blocks():
    """Straggler tags + straggler blocks under tiny block sizes."""
    n, c = 1000, 4
    rng = np.random.default_rng(7)
    tags = jnp.asarray(rng.integers(0, c + 1, size=n), jnp.int32)
    want = partition_mod.partition_argsort(tags, c)
    for bn, br in [(64, 2), (1000, 1), (2048, 4)]:
        got = part_ops.partition_tags(tags, c, block_tags=bn, block_rows=br)
        np.testing.assert_array_equal(
            np.asarray(got.perm), np.asarray(want.perm), err_msg=f"bn={bn},br={br}")
        np.testing.assert_array_equal(
            np.asarray(got.col_count), np.asarray(want.col_count),
            err_msg=f"bn={bn},br={br}")


def test_kernel_degenerate_streams():
    c = 3
    for tags_np in (np.zeros(300, np.int32),            # all one column
                    np.full(300, c, np.int32),          # all sentinel (dropped)
                    np.arange(300, dtype=np.int32) % (c + 1)):
        tags = jnp.asarray(tags_np)
        got = part_ops.partition_tags(tags, c)
        want = partition_mod.partition_scatter(tags, c)
        np.testing.assert_array_equal(np.asarray(got.perm), np.asarray(want.perm))
        np.testing.assert_array_equal(
            np.asarray(got.col_count), np.asarray(want.col_count))


def test_partition_blocks_counts_and_rel():
    """The kernel's carry totals match the tag histogram and its relative
    destinations are exactly each tag's # of earlier same-column tags."""
    n, c, bn = 2048, 6, 256
    rng = np.random.default_rng(11)
    tags_np = rng.integers(0, c + 1, size=n).astype(np.int32)
    tags = jnp.asarray(tags_np)
    rel, count = part_kernels.partition_blocks(tags.reshape(n // bn, bn), c,
                                               block_rows=4)
    np.testing.assert_array_equal(
        np.asarray(count), np.asarray(partition_mod.column_histogram(tags, c)))
    want_rel = np.empty(n, np.int32)
    seen = np.zeros(c + 1, np.int32)
    for i, t in enumerate(tags_np):
        want_rel[i] = seen[t]
        seen[t] += 1
    np.testing.assert_array_equal(np.asarray(rel).reshape(-1), want_rel)


@pytest.mark.parametrize("tagging", ["tagged", "inline", "vector"])
def test_end_to_end_kernel_partition_parity(tagging):
    """The kernel partition must produce ParseResults identical to a jnp
    impl in both tagged and terminated materialization modes.  (That this
    extends to *every* impl follows from the unit-level perm/start/count
    parity above — identical Partitioned outputs imply identical parses —
    so e2e only needs the kernel wiring itself, keeping tier-1 cheap.)"""
    data = (b'1,"a,b",3.5,2024-02-29\n'
            b'-42,"he""llo",0.25,2023-02-29\n'
            b',world,1e3,2024-04-31\n'
            b'7,x,,2024-12-31 23:59:59\n')
    schema = Schema.of(("i", "int32"), ("s", "str"), ("f", "float32"),
                       ("d", "date"))

    def parse(partition_impl):
        cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=16,
                           chunk_size=16, tagging=tagging, backend="pallas",
                           partition_impl=partition_impl)
        return Parser(cfg).parse(data)

    _assert_results_equal(parse("argsort"), parse("kernel"),
                          label=f"{tagging}/kernel: ")


def test_reference_backend_rejects_kernel_impl():
    schema = Schema.of(("a", "int32"))
    with pytest.raises(ValueError, match="partition_impl"):
        ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=4,
                     partition_impl="kernel")


# ---------------------------------------------------------------------------
# property: stability (equal col_tags keep input order)
# ---------------------------------------------------------------------------

def test_kernel_partition_stable_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    # n from a fixed set (distinct shapes recompile the jit'd kernel);
    # boundary-straddling sizes for bn=128, br=4.
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from([1, 5, 127, 128, 129, 500, 512, 513]),
           st.sampled_from([1, 3, 8]))
    def check(seed, n, c):
        rng = np.random.default_rng(seed)
        tags = jnp.asarray(rng.integers(0, c + 1, size=n), jnp.int32)
        got = part_ops.partition_tags(tags, c, block_tags=128, block_rows=4)
        perm = np.asarray(got.perm)
        tags_np = np.asarray(tags)
        # permutation correctness
        assert sorted(perm.tolist()) == list(range(n))
        # partition: tags appear in nondecreasing column order
        assert (np.diff(tags_np[perm]) >= 0).all()
        # stability: within every column, source indices stay increasing
        for col in range(c + 1):
            src = perm[tags_np[perm] == col]
            if src.size > 1:
                assert (np.diff(src) > 0).all()
        # histogram bookkeeping matches the permutation
        start, count = np.asarray(got.col_start), np.asarray(got.col_count)
        np.testing.assert_array_equal(count, np.bincount(tags_np, minlength=c + 1))
        np.testing.assert_array_equal(start, np.concatenate([[0], np.cumsum(count)[:-1]]))

    check()
