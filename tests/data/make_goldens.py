"""Golden-corpus definitions + generator for tests/test_golden_corpus.py.

Each corpus entry is a hand-written fixture under ``tests/data/`` — a CSV,
JSON-Lines, DNS-zone or CLF file — plus a ``.npz`` of the reference
backend's exact columnar outputs (values, ``valid``/``empty`` masks, CSS,
field index, record count).  The goldens pin the parser's observable §3.3
behaviour *per registered format* so refactors that silently change
conversions or a dialect's delimiting — either backend — fail the
regression test.

Regenerate (only when a semantic change is *intended*):

    PYTHONPATH=src python tests/data/make_goldens.py
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro.core import Parser, Schema, formats

DATA_DIR = pathlib.Path(__file__).resolve().parent

# corpus name -> (format registry name, fixture file)
GOLDEN_FORMATS = {
    "mixed_basic": ("csv", "mixed_basic.csv"),
    "numeric_edges": ("csv", "numeric_edges.csv"),
    "date_edges": ("csv", "date_edges.csv"),
    "jsonl_basic": ("jsonl", "jsonl_basic.jsonl"),
    "zone_basic": ("zone", "zone_basic.zone"),
    "clf_basic": ("clf", "clf_basic.log"),
}

GOLDEN_SCHEMAS = {
    "mixed_basic": Schema.of(("i", "int32"), ("s", "str"),
                             ("f", "float32"), ("d", "date")),
    "numeric_edges": Schema.of(("a", "int32"), ("b", "int32"),
                               ("x", "float32"), ("y", "float32")),
    "date_edges": Schema.of(("d1", "date"), ("d2", "date"), ("note", "str")),
    # format-native corpora pin the registry's canonical schemas
    "jsonl_basic": formats.get_format("jsonl").default_schema,
    "zone_basic": formats.get_format("zone").default_schema,
    "clf_basic": formats.get_format("clf").default_schema,
}


def build_parser(name: str, backend: str = "reference") -> Parser:
    # "pallas-fused" is a pseudo-backend for the golden sweep: the pallas
    # backend with the whole-pipeline megakernel (fuse_pipeline=True).
    fused = backend == "pallas-fused"
    be = "pallas" if fused else backend
    fmt, _ = GOLDEN_FORMATS[name]
    return Parser(formats.parser_config(
        fmt, schema=GOLDEN_SCHEMAS[name],
        max_records=32, chunk_size=64, backend=be, fuse_pipeline=fused,
        # pin the radix partition kernel on pallas so golden regressions
        # cover the kernel path (interpret-mode "auto" picks the jnp pass)
        partition_impl="kernel" if be == "pallas" else "auto",
    ))


def golden_arrays(name: str, backend: str = "reference"):
    p = build_parser(name, backend)
    _, fixture = GOLDEN_FORMATS[name]
    res = p.parse((DATA_DIR / fixture).read_bytes())
    out = {
        "css": np.asarray(res.css),
        "col_start": np.asarray(res.col_start),
        "col_count": np.asarray(res.col_count),
        "field_offset": np.asarray(res.field_offset),
        "field_length": np.asarray(res.field_length),
        "n_records": np.asarray(res.validation.n_records),
    }
    for col, parsed in res.values.items():
        out[f"{col}.value"] = np.asarray(parsed.value)
        out[f"{col}.valid"] = np.asarray(parsed.valid)
        out[f"{col}.empty"] = np.asarray(parsed.empty)
    return out


def generate():
    for name in sorted(GOLDEN_SCHEMAS):
        arrays = golden_arrays(name)
        np.savez(DATA_DIR / f"{name}.npz", **arrays)
        print(f"{name}: {int(arrays['n_records'])} records -> {name}.npz")


if __name__ == "__main__":
    generate()
