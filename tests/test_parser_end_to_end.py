"""End-to-end parser vs Python's csv module (the gold-standard oracle),
across tagging modes, partition impls, chunk sizes, and skewed inputs."""
import csv as pycsv
import io

import numpy as np
import pytest

from repro.core import Parser, ParserConfig, Schema, make_csv_dfa
from tests.conftest import random_csv_table

DTYPES = ("int32", "str", "float32", "date")
SCHEMA = Schema.of(("a", "int32"), ("b", "str"), ("c", "float32"), ("d", "date"))


def _check_against_oracle(rows, result, parser, n_cols):
    arrow = parser.to_arrow(result)
    n = int(result.validation.n_records)
    assert n == len(rows)
    for r, row in enumerate(rows):
        # int column
        v = arrow["a"]
        if row[0] == "":
            assert not _bit(v["validity"], r)
        else:
            assert _bit(v["validity"], r), (r, row)
            assert int(v["values"][r]) == int(row[0])
        # str column
        s = arrow["b"]
        got = bytes(s["data"][s["offsets"][r]: s["offsets"][r + 1]]).decode()
        assert got == row[1], (r, got, row[1])
        # float column
        f = arrow["c"]
        if row[2] == "":
            assert not _bit(f["validity"], r)
        else:
            assert _bit(f["validity"], r)
            np.testing.assert_allclose(f["values"][r], np.float32(float(row[2])), rtol=2e-6)
        # date column
        d = arrow["d"]
        if row[3] == "":
            assert not _bit(d["validity"], r)
        else:
            import datetime as dt
            fmt = "%Y-%m-%d %H:%M:%S" if len(row[3]) > 10 else "%Y-%m-%d"
            ts = dt.datetime.strptime(row[3], fmt).replace(tzinfo=dt.timezone.utc).timestamp()
            assert int(d["values"][r]) == int(ts)


def _bit(packed, i):
    return bool((packed[i // 8] >> (i % 8)) & 1)


@pytest.mark.parametrize("partition_impl", ["scatter", "argsort"])
@pytest.mark.parametrize("chunk", [31, 64])
def test_random_tables_tagged(rng, partition_impl, chunk):
    rows, data = random_csv_table(rng, 40, DTYPES)
    cfg = ParserConfig(
        dfa=make_csv_dfa(), schema=SCHEMA, max_records=64,
        chunk_size=chunk, partition_impl=partition_impl,
    )
    p = Parser(cfg)
    res = p.parse(data)
    assert bool(res.validation.ok)
    _check_against_oracle(rows, res, p, 4)


@pytest.mark.parametrize("tagging", ["inline", "vector"])
def test_alternative_tagging_modes(rng, tagging):
    rows, data = random_csv_table(rng, 30, DTYPES, empty_prob=0.15)
    cfg = ParserConfig(
        dfa=make_csv_dfa(), schema=SCHEMA, max_records=64, tagging=tagging,
    )
    p = Parser(cfg)
    res = p.parse(data)
    assert bool(res.validation.ok)
    _check_against_oracle(rows, res, p, 4)


def test_matmul_scan_path(rng):
    rows, data = random_csv_table(rng, 20, DTYPES)
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=32,
                       use_matmul_scan=True)
    p = Parser(cfg)
    res = p.parse(data)
    assert bool(res.validation.ok)
    _check_against_oracle(rows, res, p, 4)


def test_skewed_record(rng):
    """Paper Fig. 11 (right): one huge record among normal ones must not
    break anything (robustness to skew)."""
    big = "x" * 20000 + ',y"z' * 100
    rows = [["1", "small", "2.0", "2021-01-01"],
            ["2", big, "3.0", "2021-01-02"],
            ["3", "small2", "4.0", "2021-01-03"]]
    buf = io.StringIO()
    pycsv.writer(buf, lineterminator="\n").writerows(rows)
    data = buf.getvalue().encode()
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=SCHEMA, max_records=8)
    p = Parser(cfg)
    res = p.parse(data)
    assert bool(res.validation.ok)
    _check_against_oracle(rows, res, p, 4)


def test_comments_and_crlf():
    dfa = make_csv_dfa(comment=b"#")
    schema = Schema.of(("a", "int32"), ("b", "str"))
    data = b"# leading comment\r\n1,foo\r\n# mid\r\n2,bar\r\n"
    p = Parser(ParserConfig(dfa=dfa, schema=schema, max_records=8))
    res = p.parse(data)
    assert bool(res.validation.ok)
    assert int(res.validation.n_records) == 2
    arrow = p.to_arrow(res)
    assert list(arrow["a"]["values"][:2]) == [1, 2]
    got = bytes(arrow["b"]["data"][arrow["b"]["offsets"][0]: arrow["b"]["offsets"][1]])
    assert got == b"foo"


def test_invalid_input_flags():
    p = Parser(ParserConfig(dfa=make_csv_dfa(), schema=Schema.of(("a", "str"),), max_records=8))
    res = p.parse(b'"unterminated quote\n')  # EOF inside quotes
    assert not bool(res.validation.ok)
    res2 = p.parse(b'ab"cd\n')  # quote mid-unquoted-field -> INV
    assert not bool(res2.validation.no_invalid)


def test_ragged_records_and_column_count():
    schema = Schema.of(("a", "str"), ("b", "str"), ("c", "str"))
    p = Parser(ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8,
                            validate_columns=True))
    res = p.parse(b"1,Apples\n2\n3,4,5\n")  # paper §4.1's ragged example
    assert int(res.validation.n_records) == 3
    assert int(res.validation.min_columns) == 1
    assert int(res.validation.max_columns) == 3
    assert not bool(res.validation.ok)  # not all records have 3 columns
    rec_ok = np.asarray(res.validation.record_ok[:3])
    np.testing.assert_array_equal(rec_ok, [False, False, True])


def test_streaming_state_carry():
    """initial_state threading: a partition cut inside a quoted field parses
    correctly when seeded with the previous partition's end state."""
    schema = Schema.of(("a", "str"), ("b", "str"))
    cfg = ParserConfig(dfa=make_csv_dfa(), schema=schema, max_records=8, chunk_size=8)
    p = Parser(cfg)
    part1 = b'x,"abc\n'      # ends INSIDE the quoted field -> state ENC
    part2 = b'def"\ny,z\n'
    # prepare() would append a record delimiter; raw carry tests pad manually.
    import jax.numpy as jnp
    raw1 = np.frombuffer(part1.ljust(8, b"\x00"), np.uint8).reshape(-1, 8)
    r1 = p.parse_chunks(jnp.asarray(raw1))
    end1 = r1.end_state
    raw2 = np.frombuffer(part2.ljust(16, b"\x00"), np.uint8).reshape(-1, 8)
    r2 = p.parse_chunks(jnp.asarray(raw2), initial_state=end1)
    # the "def" bytes must be classified as data continuing the quoted field:
    # if carry were ignored they'd open a fresh record at column 0.
    assert int(r2.validation.n_records) == 2
