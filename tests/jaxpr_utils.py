"""Shared jaxpr inspection helpers for the no-XLA-gather and
no-HBM-round-trip acceptance tests."""

# Data-movement primitives that stand for an HBM round-trip when they appear
# *between* pallas kernels at the XLA level: gathers/scatters materialise a
# reordered copy of their operand in HBM.  (Scatter covers every .at[] mode —
# set/add/min/max lower to scatter variants.)
_ROUNDTRIP_PRIMS = (
    "gather",
    "scatter",
    "scatter-add",
    "scatter_add",
    "scatter-min",
    "scatter_min",
    "scatter-max",
    "scatter_max",
    "scatter-mul",
    "scatter_mul",
)


def gathers_outside_pallas(jaxpr, acc=None):
    """Collect gather eqns reachable without descending into pallas_call."""
    acc = [] if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name == "gather":
            acc.append(eqn)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    gathers_outside_pallas(inner, acc)
    return acc


def _max_elems(eqn):
    """Largest operand/output element count of ``eqn`` (0 if shapeless)."""
    best = 0
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
        if shape is None:
            continue
        n = 1
        for d in shape:
            n *= int(d)
        best = max(best, n)
    return best


def hbm_roundtrips_outside_pallas(jaxpr, min_elems, acc=None):
    """Collect gather/scatter-family eqns outside every pallas_call whose
    largest operand or output holds ``>= min_elems`` elements.

    This is the whole-pipeline-fusion acceptance detector: the fused path
    may keep tiny bookkeeping gathers at the XLA level (the O(C·S) §3.1
    scan composition, the O(S) accept-mask lookup), but any input-sized
    permutation — the staged path's tag arrays, partition scatter, or
    perm-inversion scatter — shows up here as a large gather/scatter and
    fails the pin.  ``min_elems`` is sized by the caller relative to the
    partition (e.g. ``N // 2``) so the detector is robust to small
    bookkeeping while still catching any (N,)- or (R,)-sized round-trip.
    """
    acc = [] if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name in _ROUNDTRIP_PRIMS and _max_elems(eqn) >= min_elems:
            acc.append(eqn)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    hbm_roundtrips_outside_pallas(inner, min_elems, acc)
    return acc
