"""Shared jaxpr inspection helpers for the no-XLA-gather acceptance tests."""


def gathers_outside_pallas(jaxpr, acc=None):
    """Collect gather eqns reachable without descending into pallas_call."""
    acc = [] if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name == "gather":
            acc.append(eqn)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    gathers_outside_pallas(inner, acc)
    return acc
