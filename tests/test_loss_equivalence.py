"""Chunked cross entropy must equal the dense-logits loss bit-for-near."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model


def test_chunked_loss_equals_dense(rng):
    base = dataclasses.replace(get_config("qwen2-1.5b", reduced=True),
                               param_dtype=jnp.float32)
    b, s = 2, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, base.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(
            np.where(rng.random((b, s)) < 0.1, -1,
                     rng.integers(0, base.vocab, (b, s))), jnp.int32),
    }
    dense = build_model(base)
    params = dense.init(jax.random.PRNGKey(0))
    l_dense, (nll_d, _) = jax.jit(dense.loss)(params, batch)

    for chunk in (8, 16, 32):
        chunked = build_model(dataclasses.replace(base, logit_chunk=chunk))
        l_chunk, (nll_c, _) = jax.jit(chunked.loss)(params, batch)
        np.testing.assert_allclose(float(nll_c), float(nll_d), rtol=1e-6)

    # gradients agree too (the backward path is the memory-relevant part)
    g_d = jax.jit(jax.grad(lambda p: dense.loss(p, batch)[0]))(params)
    chunked = build_model(dataclasses.replace(base, logit_chunk=16))
    g_c = jax.jit(jax.grad(lambda p: chunked.loss(p, batch)[0]))(params)
    for a, b_ in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_c)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-5, atol=1e-7)
