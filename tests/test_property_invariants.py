"""Hypothesis property tests on system invariants:

  * typeconv round-trips (int/float/date) against Python's parsers
  * partition stability + permutation correctness
  * segmented-Horner == fixed-width-gather int parsing
  * chunked SSD == sequential recurrence across shapes
"""
import datetime as dt

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import partition as partition_mod
from repro.core import typeconv


def _pack(strs, width=None):
    lens = np.asarray([len(s) for s in strs], np.int32)
    width = width or (int(lens.max()) if len(strs) else 1)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    css = np.frombuffer("".join(strs).encode(), np.uint8)
    if css.size == 0:
        css = np.zeros(1, np.uint8)
    return jnp.asarray(css), jnp.asarray(offs), jnp.asarray(lens), width


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-(10**8), 10**8), min_size=1, max_size=40))
def test_int_roundtrip(values):
    strs = [str(v) for v in values]
    css, offs, lens, w = _pack(strs)
    parsed = typeconv.parse_int(css, offs, lens, width=max(w, 1))
    assert bool(parsed.valid.all())
    np.testing.assert_array_equal(np.asarray(parsed.value), np.asarray(values, np.int32))


@settings(max_examples=40, deadline=None)
@given(st.lists(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False).map(lambda v: f"{v:.5g}"),
    min_size=1, max_size=30,
))
def test_float_roundtrip(strs):
    css, offs, lens, w = _pack(strs)
    parsed = typeconv.parse_float(css, offs, lens, width=max(w, 1))
    assert bool(parsed.valid.all()), strs
    np.testing.assert_allclose(
        np.asarray(parsed.value), np.asarray([float(s) for s in strs], np.float32),
        rtol=3e-6, atol=1e-30,
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_date_roundtrip(ts):
    d = dt.datetime.fromtimestamp(ts, dt.timezone.utc).replace(microsecond=0)
    s = d.strftime("%Y-%m-%d %H:%M:%S")
    css, offs, lens, _ = _pack([s])
    parsed = typeconv.parse_date(css, offs, lens)
    assert bool(parsed.valid[0])
    assert int(parsed.value[0]) == int(d.timestamp())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10**8), min_size=1, max_size=25))
def test_segmented_equals_gather(values):
    strs = [str(v) for v in values]
    css, offs, lens, w = _pack(strs)
    fid = jnp.asarray(np.repeat(np.arange(len(strs)), np.asarray(lens)), jnp.int32)
    fstart = np.zeros(int(np.asarray(lens).sum()) or 1, bool)
    fstart[np.asarray(offs)[: len(strs)]] = True
    seg = typeconv.parse_int_segmented(css, jnp.asarray(fstart), fid, len(strs))
    gat = typeconv.parse_int(css, offs, lens, width=max(w, 1))
    both = np.asarray(seg.valid) & np.asarray(gat.valid)
    np.testing.assert_array_equal(np.asarray(seg.value)[both], np.asarray(gat.value)[both])
    # reconciled digit semantics: when the gather width covers every field
    # (it does here), the two paths agree on validity exactly
    np.testing.assert_array_equal(np.asarray(seg.valid), np.asarray(gat.valid))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-(10**13), 10**13), min_size=1, max_size=30))
def test_int_overflow_clears_valid(values):
    """|v| > INT32_MAX ⇒ valid=False on both int paths; within range the
    parsed value round-trips — no silent Horner wrap anywhere."""
    strs = [str(v) for v in values]
    css, offs, lens, w = _pack(strs)
    want_valid = np.asarray([abs(v) <= 2**31 - 1 for v in values])
    gat = typeconv.parse_int(css, offs, lens, width=max(w, 1))
    fid = jnp.asarray(np.repeat(np.arange(len(strs)), np.asarray(lens)), jnp.int32)
    fstart = np.zeros(int(np.asarray(lens).sum()) or 1, bool)
    fstart[np.asarray(offs)[: len(strs)]] = True
    seg = typeconv.parse_int_segmented(css, jnp.asarray(fstart), fid, len(strs))
    np.testing.assert_array_equal(np.asarray(gat.valid), want_valid)
    np.testing.assert_array_equal(np.asarray(seg.valid), want_valid)
    want = np.asarray([v for v in values if abs(v) <= 2**31 - 1], np.int64)
    np.testing.assert_array_equal(np.asarray(gat.value)[want_valid], want)
    np.testing.assert_array_equal(np.asarray(seg.value)[want_valid], want)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 500), st.integers(2, 8))
def test_partition_impls_agree_and_stable(seed, n, c):
    rng = np.random.default_rng(seed)
    tags = jnp.asarray(rng.integers(0, c + 1, size=n), jnp.int32)  # incl. sentinel
    a = partition_mod.partition_argsort(tags, c)
    b = partition_mod.partition_scatter(tags, c)
    d = partition_mod.partition_scatter2(tags, c)
    np.testing.assert_array_equal(np.asarray(a.perm), np.asarray(d.perm))
    np.testing.assert_array_equal(np.asarray(a.perm), np.asarray(b.perm))
    np.testing.assert_array_equal(np.asarray(a.col_start), np.asarray(b.col_start))
    # stability: positions within a column are increasing source indices
    perm = np.asarray(a.perm)
    tags_np = np.asarray(tags)
    for col in range(c + 1):
        src = perm[tags_np[perm] == col]
        assert (np.diff(src) > 0).all() if src.size > 1 else True


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([8, 16, 32]),
       st.sampled_from([4, 8]), st.sampled_from([4, 16]))
def test_ssd_chunked_equals_recurrence(seed, s, h, n):
    from repro.models import ssm as S
    rng = np.random.default_rng(seed)
    b, p = 2, 4
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dtv = jnp.asarray(rng.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.2, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    y_ref, st_ref = S.ssd_reference(x, dtv, a, bm, cm)
    for chunk in (4, 8, s):
        if s % chunk:
            continue
        y, st_f = S.ssd_chunked(x, dtv, a, bm, cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(st_f), np.asarray(st_ref), atol=2e-4, rtol=2e-4)
