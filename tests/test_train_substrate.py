"""Training substrate: optimizer maths, grad accumulation equivalence,
checkpoint/restart (with failure injection), data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import FailureInjector, StragglerMonitor, run_training


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    cfg = opt_mod.OptimizerConfig(name=name, lr=0.1, warmup_steps=0,
                                  total_steps=300, weight_decay=0.0)
    opt = opt_mod.make_optimizer(cfg)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] + p["b"] - target) ** 2)

    step = jax.jit(lambda p, s, i: opt.update(jax.grad(loss)(p), s, p, i))
    l0 = float(loss(params))
    for i in range(200):
        params, state = step(params, state, jnp.int32(i))
    assert float(loss(params)) < l0 * 0.05, (name, float(loss(params)), l0)


def test_grad_accumulation_equivalence():
    """microbatches=4 must produce the same update as microbatches=1 (mean
    losses over the batch commute with accumulation)."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.train.train_step import TrainConfig, init_train_state, make_train_step

    cfg = get_config("qwen2-1.5b", reduced=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    model = build_model(cfg)
    ocfg = opt_mod.OptimizerConfig(lr=1e-2, warmup_steps=0, grad_clip=1e9)
    opt = opt_mod.make_optimizer(ocfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
    }
    s1 = jax.jit(make_train_step(model, opt, TrainConfig(optimizer=ocfg, microbatches=1)))
    s4 = jax.jit(make_train_step(model, opt, TrainConfig(optimizer=ocfg, microbatches=4)))
    out1, m1 = s1(state, batch)
    out4, m4 = s4(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(out1.params), jax.tree.leaves(out4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(5, state, extra={"note": "hello"})
    ckpt.save(10, state)
    ckpt.save(15, state)  # keep=2 → step 5 garbage-collected
    assert ckpt.all_steps() == [10, 15]
    restored, meta = ckpt.restore(15, state)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_training_resumes_after_injected_failure(tmp_path):
    """Kill training mid-run; rerunning must resume from the checkpoint and
    finish with identical final state as an uninterrupted run."""
    def make_step():
        def step(state, batch):
            new = {"w": state["w"] + batch["x"].sum()}
            return new, {"loss": -state["w"], "nll": state["w"] * 0,
                         "aux": state["w"] * 0, "grad_norm": state["w"] * 0,
                         "lr": state["w"] * 0}
        return step

    def data_factory(start):
        def gen():
            i = start
            while True:
                yield {"x": jnp.full((2,), float(i + 1))}
                i += 1
        return gen()

    init = {"w": jnp.zeros((), jnp.float32)}
    logs = []

    # uninterrupted oracle
    final_ref, _ = run_training(
        make_step(), init, data_factory, total_steps=20, ckpt=None,
        log_fn=lambda s: None,
    )

    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(
            make_step(), init, data_factory, total_steps=20,
            ckpt=ckpt, ckpt_every=5, injector=FailureInjector(fail_at_step=12),
            log_fn=logs.append,
        )
    assert ckpt.latest_step() == 10
    final, _ = run_training(
        make_step(), init, data_factory, total_steps=20,
        ckpt=ckpt, ckpt_every=5, log_fn=logs.append,
    )
    assert any("[resume] restored checkpoint at step 10" in l for l in logs)
    np.testing.assert_allclose(float(final["w"]), float(final_ref["w"]))


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not m.observe(0.1)
    assert not m.observe(0.11)
    assert m.observe(1.0)  # 10x slower
    assert m.flagged == 1


def test_pipeline_deterministic_resume(rng):
    from repro.core import Schema
    from repro.data.pipeline import CSVTokenPipeline, PipelineConfig
    from repro.data.synth import YELP_SCHEMA, yelp_like

    data = yelp_like(np.random.default_rng(3), 200)
    schema = Schema.of(*YELP_SCHEMA)
    pc = PipelineConfig(seq_len=64, batch_size=4, partition_bytes=4096,
                        max_carry_bytes=4096, max_records_per_partition=256)

    def src():
        for i in range(0, len(data), 1024):
            yield data[i : i + 1024]

    pipe = CSVTokenPipeline(schema, pc)
    full = list(b["tokens"] for b in pipe.batches(src()))
    assert len(full) >= 4
    pipe2 = CSVTokenPipeline(schema, pc)
    resumed = list(b["tokens"] for b in pipe2.batches(src(), start_step=2))
    np.testing.assert_array_equal(full[2], resumed[0])
    # round-trip: detokenized batches contain real review words
    from repro.data.pipeline import detokenize
    text = detokenize(np.asarray(full[0]).reshape(-1))
    assert b" " in text and len(text) > 50


def test_error_feedback_compression():
    from repro.train.grad_compress import ErrorFeedback
    ef = ErrorFeedback()
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    total_q = jnp.zeros((64, 64), jnp.float32)
    total_g = jnp.zeros((64, 64), jnp.float32)
    for _ in range(20):
        q = ef.apply(g)
        total_q = total_q + q["w"]
        total_g = total_g + g["w"]
    # error feedback keeps the long-run average unbiased
    err = jnp.abs(total_q - total_g).max() / jnp.abs(total_g).max()
    assert float(err) < 0.02
