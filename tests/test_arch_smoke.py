"""Per-architecture smoke tests on REDUCED configs: one forward + one grad
step (shape + finiteness), and decode-vs-forward parity where applicable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model

B, S = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(model.forward)(params, batch)
    exp_s = S + (cfg.n_patches or 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    def loss(p):
        return model.loss(p, batch)[0]

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(l0)), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat), arch
    # one SGD step must change the loss
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype), params, grads)
    l1 = jax.jit(loss)(params2)
    assert float(l1) != float(l0)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode must reproduce the full-sequence forward logits
    (the KV-cache / recurrent-state correctness oracle).  fp32 params so the
    comparison is sharp — bf16 rounding differences between the chunked-SSD
    and recurrent paths are ~1e-2 and would mask real cache bugs."""
    import dataclasses
    # fp32 + no-drop capacity: the forward pass must not drop MoE tokens or
    # decode (dropless gather path) can't match it.
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              param_dtype=jnp.float32, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    n_dec = 8
    batch["tokens"] = batch["tokens"][:, :n_dec]

    logits_full, _ = jax.jit(model.forward)(params, batch)
    pfx = cfg.n_patches or 0
    if pfx:
        logits_full = logits_full[:, pfx:]

    state = model.init_decode_state(B, max_seq=n_dec + pfx)
    if cfg.is_encoder_decoder:
        # prime the cross-attention cache from the encoder output
        from repro.models import transformer as T
        from repro.models import layers as L
        enc = T.encode(params, batch["frames"], cfg, model.sh, None)
        ks, vs = [], []
        for i in range(cfg.n_layers):
            prm = jax.tree.map(lambda a: a[i], params["blocks"])
            b_, t_, _ = enc.shape
            ks.append(L.linear(prm["cross"]["wk"], enc).reshape(b_, t_, cfg.n_kv_heads, cfg.head_dim))
            vs.append(L.linear(prm["cross"]["wv"], enc).reshape(b_, t_, cfg.n_kv_heads, cfg.head_dim))
        state = state._replace(cross_kv={"k": jnp.stack(ks), "v": jnp.stack(vs)})
    if pfx:
        # feed patch positions through decode as embeddings is not supported;
        # decode parity for VLM checked on the token suffix only after a
        # text-only prefix (patches skipped in this smoke test)
        batch.pop("patches")
        logits_full, _ = jax.jit(model.forward)(params, {**batch})
        state = model.init_decode_state(B, max_seq=n_dec)

    step = jax.jit(model.decode_step)
    outs = []
    for t in range(n_dec):
        logits_t, state = step(params, batch["tokens"][:, t], state)
        outs.append(logits_t)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(logits_full, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_param_count_sanity():
    """Analytic param counts agree with actual init on reduced configs."""
    for arch in ("llama3.2-3b", "qwen2-1.5b", "mamba2-370m"):
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(actual - approx) / actual < 0.15, (arch, actual, approx)


def test_full_config_param_counts():
    """Full (non-reduced) configs land near their advertised sizes."""
    expected = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "deepseek-7b": (6e9, 8e9),
        "starcoder2-15b": (13e9, 18e9),
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "mamba2-370m": (3e8, 4.6e8),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "phi3.5-moe-42b-a6.6b": (3.7e10, 4.7e10),
        "internvl2-76b": (6e10, 8.5e10),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    active = kimi.active_param_count()
    assert 2.5e10 <= active <= 4.0e10, f"{active:.3e}"  # ~32B active
