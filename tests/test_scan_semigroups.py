"""Associativity + equivalence properties of ParPaRaw's two semigroups
(paper §3.1 composite, §3.2 abs/rel column offsets)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import offsets as offs
from repro.core import transition as tr

S = 6


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_compose_associative(seed):
    rng = np.random.default_rng(seed)
    a, b, c = (jnp.asarray(rng.integers(0, S, size=S), jnp.int32) for _ in range(3))
    lhs = tr.compose(tr.compose(a, b), c)
    rhs = tr.compose(a, tr.compose(b, c))
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 64))
def test_matmul_scan_equals_gather_scan(seed, n):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.integers(0, S, size=(n, S)), jnp.int32)
    g = tr.exclusive_scan_vectors(vecs, use_matmul=False)
    m = tr.exclusive_scan_vectors(vecs, use_matmul=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(m))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 33))
def test_fold_equals_sequential_fold(seed, n):
    rng = np.random.default_rng(seed)
    vecs_np = rng.integers(0, S, size=(n, S)).astype(np.int32)
    ref = np.arange(S)
    for v in vecs_np:
        ref = v[ref]
    out = tr.fold_vectors(jnp.asarray(vecs_np))
    np.testing.assert_array_equal(np.asarray(out), ref)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_column_offset_op_associative(seed):
    rng = np.random.default_rng(seed)

    def rand():
        return (
            jnp.asarray(rng.integers(0, 2), jnp.int32),
            jnp.asarray(rng.integers(0, 100), jnp.int32),
        )

    a, b, c = rand(), rand(), rand()
    l = offs.combine_col(offs.combine_col(a, b), c)
    r = offs.combine_col(a, offs.combine_col(b, c))
    assert int(l[0]) == int(r[0]) and int(l[1]) == int(r[1])


def _naive_ids(classes: np.ndarray):
    rid = np.zeros(classes.size, np.int32)
    cid = np.zeros(classes.size, np.int32)
    r = c = 0
    for i, cl in enumerate(classes):
        rid[i], cid[i] = r, c
        if cl == 2:  # RECORD_DELIM
            r += 1
            c = 0
        elif cl == 1:  # FIELD_DELIM
            c += 1
    return rid, cid


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 200))
def test_symbol_ids_match_naive(seed, n):
    rng = np.random.default_rng(seed)
    classes = rng.choice([0, 1, 2, 3], size=n, p=[0.6, 0.2, 0.1, 0.1]).astype(np.uint8)
    rid_ref, cid_ref = _naive_ids(classes)
    ids = offs.symbol_ids(jnp.asarray(classes))
    np.testing.assert_array_equal(np.asarray(ids.record_id), rid_ref)
    np.testing.assert_array_equal(np.asarray(ids.column_id), cid_ref)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 16), st.integers(1, 32))
def test_chunked_ids_match_flat(seed, c, k):
    """Two-level (chunk summaries + scan) ids == flat symbol ids.

    This is the exact decomposition the distributed parser uses across
    devices, so equality here is the correctness core of core/distributed."""
    rng = np.random.default_rng(seed)
    classes = rng.choice([0, 1, 2, 3], size=(c, k), p=[0.6, 0.2, 0.1, 0.1]).astype(np.uint8)
    flat = offs.symbol_ids(jnp.asarray(classes.reshape(-1)))
    summ = offs.chunk_summaries(jnp.asarray(classes))
    chunk_offs = offs.scan_chunk_offsets(summ)
    two = offs.symbol_ids_from_chunks(jnp.asarray(classes), chunk_offs)
    np.testing.assert_array_equal(np.asarray(two.record_id), np.asarray(flat.record_id))
    np.testing.assert_array_equal(np.asarray(two.column_id), np.asarray(flat.column_id))
    assert int(two.n_records) == int(flat.n_records)
