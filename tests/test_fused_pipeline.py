"""Whole-pipeline fusion acceptance: the megakernel path
(``fuse_pipeline=True`` on the pallas backend) must be bit-identical to the
staged composition AND emit no input-sized HBM round-trips between the DFA
replay and the typed-column output.

Three layers of pins:

* **parity** — fused vs reference across every DFA × tagging mode, the
  streaming carry hook, and multi-partition streams (all exact,
  ``np.array_equal``; same bar as test_backend_parity).
* **plan metadata** — ``plan_parse`` records the resolved tier + reason on
  ``ParsePlan`` so drivers/benchmarks can report what actually ran; the
  fallback tiers (no fused executor, index-only plan, byte cap) each have
  an explicit pin.
* **jaxpr** — the fused trace contains no gather/scatter-family eqn outside
  a pallas_call touching ≥ N/2 elements (N = partition bytes).  The staged
  path's perm-inversion scatter (kernels/partition/ops.py) is the positive
  control proving the detector sees the round-trip it is supposed to kill.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jaxpr_utils import hbm_roundtrips_outside_pallas
from test_backend_parity import DFAS, INPUTS, SCHEMAS, _assert_results_equal

from repro.core import Parser, ParserConfig
from repro.core import backends as backends_mod
from repro.core import stages as stages_mod
from repro.core.streaming import StreamingParser


def _cfg(dfa_name, *, backend="pallas", fuse_pipeline=True, **kw):
    kw.setdefault("max_records", 16)
    kw.setdefault("chunk_size", 16)
    if backend == "pallas":
        kw.setdefault("partition_impl", "kernel")
    return ParserConfig(dfa=DFAS[dfa_name](), schema=SCHEMAS[dfa_name],
                        backend=backend, fuse_pipeline=fuse_pipeline, **kw)


def _pair(dfa_name, **kw):
    """(reference parser, fused pallas parser) for one grammar."""
    ref = Parser(_cfg(dfa_name, backend="reference", fuse_pipeline=False,
                      partition_impl="auto", **kw))
    fus = Parser(_cfg(dfa_name, **kw))
    assert fus.plan.execute_path == "fused", fus.plan.path_reason
    return ref, fus


# ---------------------------------------------------------------------------
# parity


@pytest.mark.parametrize("dfa_name", sorted(DFAS))
@pytest.mark.parametrize("tagging", ("tagged", "inline", "vector"))
def test_fused_parity(dfa_name, tagging):
    ref, fus = _pair(dfa_name, tagging=tagging)
    data = INPUTS[dfa_name]
    _assert_results_equal(ref.parse(data), fus.parse(data),
                          label=f"{dfa_name}/{tagging} fused: ")


def test_fused_parity_carry_initial_state():
    """The §4.4 streaming hook: a mid-quote initial state must flow through
    the megakernel's replay exactly like the staged scan."""
    ref, fus = _pair("csv")
    chunks = jnp.asarray(ref.prepare(b'b",2,3\n4,"x",5\n'))
    enc = ref.cfg.dfa.state_names.index("ENC")
    r = ref.parse_chunks(chunks, initial_state=jnp.int32(enc))
    q = fus.parse_chunks(chunks, initial_state=jnp.int32(enc))
    _assert_results_equal(r, q, label="fused/ENC: ")


def test_fused_streaming_bit_identity():
    """Multi-partition stream through StreamingParser: the fused path rides
    the same prepend/extract carry hooks, so every partition must match."""
    ref, fus = _pair("csv", max_records=32)
    data = INPUTS["csv"] * 6
    outs = []
    for p in (ref, fus):
        sp = StreamingParser(p, partition_bytes=64, max_carry_bytes=64)
        parts = [(r, n) for r, n in sp.parse_stream([data])]
        assert sp.stats.partitions > 1
        outs.append(parts)
    assert len(outs[0]) == len(outs[1])
    for (r, n_r), (q, n_q) in zip(*outs):
        assert n_r == n_q
        _assert_results_equal(r, q, label="fused stream: ")


# ---------------------------------------------------------------------------
# plan metadata + fallback tiers


def test_plan_records_fused_path():
    cfg = _cfg("csv")
    plan = Parser(cfg).plan
    assert plan.execute_path == "fused"
    assert plan.path_reason == "fuse_pipeline=True"


def test_plan_default_is_staged():
    cfg = _cfg("csv", fuse_pipeline=False)
    plan = Parser(cfg).plan
    assert plan.execute_path == "staged"
    assert "not requested" in plan.path_reason


def test_plan_backend_without_executor_stays_staged():
    """The reference backend has no fused executor: the knob soft-resolves
    to staged with the reason recorded (no error — same tier design as the
    windowed numparse fallbacks)."""
    cfg = _cfg("csv", backend="reference", fuse_pipeline=True,
               partition_impl="auto")
    plan = Parser(cfg).plan
    assert plan.execute_path == "staged"
    assert "no fused executor" in plan.path_reason


def test_plan_index_only_stays_staged():
    """convert=False (the distributed per-shard contract) must not pay for
    in-kernel typed columns it would throw away."""
    cfg = _cfg("csv")
    be = backends_mod.get_backend("pallas")
    plan = stages_mod.plan_parse(cfg, be, convert=False)
    assert plan.execute_path == "staged"
    assert "convert=False" in plan.path_reason


def test_byte_cap_falls_back_to_staged():
    """Partitions above ``fused_max_bytes`` take the staged tier at trace
    time — and still produce identical results."""
    tiny = dataclasses.replace(backends_mod.get_backend("pallas"),
                               name="pallas-tinyfuse", fused_max_bytes=8)
    backends_mod.register_backend(tiny)
    try:
        ref, fus = _pair("csv")
        cfg = dataclasses.replace(fus.cfg, backend="pallas-tinyfuse")
        p = Parser(cfg)
        assert p.plan.execute_path == "fused"  # plan still requests fusion
        chunks = p.prepare(INPUTS["csv"])
        # ... but any realistic partition exceeds the 8-byte cap:
        assert stages_mod.resolved_execute_path(p.plan, tiny, chunks.size) \
            == "staged"
        _assert_results_equal(ref.parse(INPUTS["csv"]), p.parse(INPUTS["csv"]),
                              label="byte-cap: ")
    finally:
        backends_mod.BACKENDS.pop("pallas-tinyfuse", None)


def test_resolved_execute_path_under_cap():
    p = Parser(_cfg("csv"))
    be = backends_mod.get_backend("pallas")
    chunks = p.prepare(INPUTS["csv"])
    assert stages_mod.resolved_execute_path(p.plan, be, chunks.size) == "fused"


# ---------------------------------------------------------------------------
# jaxpr: no HBM round-trips between replay and typed columns


def _trace(parser, chunks):
    be = backends_mod.get_backend(parser.cfg.backend)
    return jax.make_jaxpr(
        lambda c: stages_mod.execute_plan(c, parser.plan, parser.cfg, be)
    )(chunks)


def test_fused_no_hbm_roundtrips():
    """The megakernel path may keep tiny bookkeeping gathers at the XLA
    level (the O(C·S) scan composition, the O(S) accept lookup) but nothing
    input-sized: no tag arrays, no partition scatter, no perm inversion."""
    # small max_records so (R,) arrays sit well under the N/2 threshold too
    fus = Parser(_cfg("csv", max_records=16))
    chunks = jnp.asarray(fus.prepare(INPUTS["csv"]))
    n = int(chunks.size)
    jx = _trace(fus, chunks)
    offenders = hbm_roundtrips_outside_pallas(jx.jaxpr, n // 2)
    assert not offenders, [str(e.primitive) for e in offenders]


def test_staged_positive_control():
    """Detector sanity: the staged pallas path's perm-inversion scatter
    (kernels/partition/ops.py) IS an input-sized HBM round-trip."""
    stg = Parser(_cfg("csv", fuse_pipeline=False, max_records=16))
    chunks = jnp.asarray(stg.prepare(INPUTS["csv"]))
    n = int(chunks.size)
    jx = _trace(stg, chunks)
    assert hbm_roundtrips_outside_pallas(jx.jaxpr, n // 2)
